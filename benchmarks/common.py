"""Shared benchmark plumbing: incremental JSON result cache + table printing.

Every table script computes a list of row-dicts, keyed by a stable ``name``.
Rows are cached in ``benchmarks/results/<table>.json`` as they finish, so an
interrupted sweep resumes, and the final ``python -m benchmarks.run`` replays
cached rows without re-training (pass ``--rerun`` to force).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _path(table: str) -> str:
    if table.startswith("fresh-"):
        # fresh-*.json files are per-run CI artifacts (gitignored) — using
        # one as a regression baseline would gate against whatever the
        # last run produced instead of the committed numbers.
        raise ValueError(
            f"refusing to use {table!r} as a results table: fresh-* files "
            "are uncommitted run artifacts, not baselines (compare "
            f"against {table[len('fresh-'):]!r})")
    return os.path.join(RESULTS_DIR, table + ".json")


def load_rows(table: str) -> Dict[str, dict]:
    p = _path(table)
    if not os.path.exists(p):
        return {}
    with open(p) as f:
        return {r["name"]: r for r in json.load(f)}


def save_rows(table: str, rows: Dict[str, dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(_path(table), "w") as f:
        json.dump(list(rows.values()), f, indent=1)


CACHED_ONLY = False      # benchmarks.run --cached-only: never compute


def run_cached(table: str, names: List[str], compute: Callable[[str], dict],
               rerun: bool = False) -> List[dict]:
    """Compute (or load) one row per name; persist incrementally."""
    rows = {} if rerun else load_rows(table)
    for name in names:
        if name in rows and not rows[name].get("error"):
            continue
        if CACHED_ONLY:
            continue
        t0 = time.time()
        try:
            row = compute(name)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            row = {"name": name, "error": repr(e)[:300]}
        row["name"] = name
        row.setdefault("seconds", round(time.time() - t0, 1))
        rows[name] = row
        save_rows(table, rows)
        print(f"[{table}] {name}: "
              + ", ".join(f"{k}={v}" for k, v in row.items()
                          if k not in ("name", "curve")), flush=True)
    return [rows[n] for n in names if n in rows]


def fmt_table(title: str, rows: List[dict], cols: List[str]) -> str:
    """Markdown table from row dicts."""
    out = [f"\n### {title}\n", "| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            cells.append(f"{v:.2f}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out) + "\n"


def check(claims: List[tuple]) -> List[str]:
    """[(description, bool)] -> printable pass/fail lines."""
    return [("  [ok] " if ok else "  [MISMATCH] ") + desc
            for desc, ok in claims]


# ---------------------------------------------------------------------------
# Benchmark-regression gate (--check mode)
# ---------------------------------------------------------------------------

def compare_to_committed(fresh, committed, *, band_keys: Optional[dict] = None,
                         ignore_keys=frozenset(), _path: str = "$",
                         _key: str = "") -> List[str]:
    """Deep-diff freshly computed benchmark results against the committed
    ``results/*.json``; returns a list of human-readable mismatches (empty
    == no regression).

    Leaves compare EXACTLY by default — wire bytes, collective launch
    counts, bubble fractions and boolean claims are deterministic, and a
    drift IS the regression being gated.  ``band_keys`` maps leaf key
    names (e.g. machine-dependent throughputs) to a relative tolerance:
    ``{"tok_per_s": 0.75}`` accepts fresh within +-75% of committed.
    ``ignore_keys`` skips keys entirely (wall-clock noise).
    """
    band_keys = band_keys or {}
    out: List[str] = []
    if isinstance(committed, dict) and isinstance(fresh, dict):
        for k in sorted(set(committed) | set(fresh)):
            if k in ignore_keys:
                continue
            if k not in fresh:
                out.append(f"{_path}.{k}: missing from fresh results")
            elif k not in committed:
                out.append(f"{_path}.{k}: not in committed results "
                           "(new field — refresh the committed json)")
            else:
                out += compare_to_committed(
                    fresh[k], committed[k], band_keys=band_keys,
                    ignore_keys=ignore_keys, _path=f"{_path}.{k}", _key=k)
        return out
    if isinstance(committed, list) and isinstance(fresh, list):
        if len(committed) != len(fresh):
            return [f"{_path}: {len(fresh)} rows vs committed "
                    f"{len(committed)}"]
        for i, (f, c) in enumerate(zip(fresh, committed)):
            out += compare_to_committed(
                f, c, band_keys=band_keys, ignore_keys=ignore_keys,
                _path=f"{_path}[{i}]", _key=_key)
        return out
    band = band_keys.get(_key)
    numeric = lambda v: (isinstance(v, (int, float))
                         and not isinstance(v, bool))
    if band is not None and numeric(committed) and numeric(fresh):
        if abs(fresh - committed) > band * max(abs(committed), 1e-9):
            out.append(f"{_path}: {fresh} outside +-{band:.0%} of "
                       f"committed {committed}")
    elif fresh != committed:
        # covers type drift on banded keys too (e.g. tok_per_s -> null)
        out.append(f"{_path}: {fresh!r} != committed {committed!r}")
    return out


def run_check(fresh: dict, table: str, band_keys: Optional[dict] = None,
              ignore_keys=frozenset()) -> int:
    """The --check entry point shared by the benchmark mains: diff
    ``fresh`` against the committed ``results/<table>.json``, write the
    fresh numbers to ``results/fresh-<table>.json`` (uploaded as a CI
    artifact), and return a shell exit code (1 on regression)."""
    committed_path = _path(table)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fresh_path = os.path.join(RESULTS_DIR, f"fresh-{table}.json")
    with open(fresh_path, "w") as f:
        json.dump(fresh, f, indent=1)
    if not os.path.exists(committed_path):
        print(f"# [check] no committed {committed_path} — commit one by "
              "running without --check", flush=True)
        return 1
    with open(committed_path) as f:
        committed = json.load(f)
    mismatches = compare_to_committed(fresh, committed,
                                      band_keys=band_keys,
                                      ignore_keys=ignore_keys)
    if mismatches:
        print(f"# [check] {table}: {len(mismatches)} regression(s) vs "
              f"committed {committed_path}:", flush=True)
        for m in mismatches:
            print(f"#   {m}", flush=True)
        return 1
    print(f"# [check] {table}: fresh results match the committed json "
          f"({fresh_path} written for the artifact)", flush=True)
    return 0
