"""Shared benchmark plumbing: incremental JSON result cache + table printing.

Every table script computes a list of row-dicts, keyed by a stable ``name``.
Rows are cached in ``benchmarks/results/<table>.json`` as they finish, so an
interrupted sweep resumes, and the final ``python -m benchmarks.run`` replays
cached rows without re-training (pass ``--rerun`` to force).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _path(table: str) -> str:
    return os.path.join(RESULTS_DIR, table + ".json")


def load_rows(table: str) -> Dict[str, dict]:
    p = _path(table)
    if not os.path.exists(p):
        return {}
    with open(p) as f:
        return {r["name"]: r for r in json.load(f)}


def save_rows(table: str, rows: Dict[str, dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(_path(table), "w") as f:
        json.dump(list(rows.values()), f, indent=1)


CACHED_ONLY = False      # benchmarks.run --cached-only: never compute


def run_cached(table: str, names: List[str], compute: Callable[[str], dict],
               rerun: bool = False) -> List[dict]:
    """Compute (or load) one row per name; persist incrementally."""
    rows = {} if rerun else load_rows(table)
    for name in names:
        if name in rows and not rows[name].get("error"):
            continue
        if CACHED_ONLY:
            continue
        t0 = time.time()
        try:
            row = compute(name)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            row = {"name": name, "error": repr(e)[:300]}
        row["name"] = name
        row.setdefault("seconds", round(time.time() - t0, 1))
        rows[name] = row
        save_rows(table, rows)
        print(f"[{table}] {name}: "
              + ", ".join(f"{k}={v}" for k, v in row.items()
                          if k not in ("name", "curve")), flush=True)
    return [rows[n] for n in names if n in rows]


def fmt_table(title: str, rows: List[dict], cols: List[str]) -> str:
    """Markdown table from row dicts."""
    out = [f"\n### {title}\n", "| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            cells.append(f"{v:.2f}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out) + "\n"


def check(claims: List[tuple]) -> List[str]:
    """[(description, bool)] -> printable pass/fail lines."""
    return [("  [ok] " if ok else "  [MISMATCH] ") + desc
            for desc, ok in claims]
