"""Benchmark driver — one table per paper table (Sec. 3) + wire model +
roofline replay.

Usage:
  PYTHONPATH=src python -m benchmarks.run               # all (cached rows replayed)
  PYTHONPATH=src python -m benchmarks.run --only table2 --rerun
  REPRO_EPOCHS=4 PYTHONPATH=src python -m benchmarks.run --only table1

Training rows are cached in benchmarks/results/*.json (see common.py); a
fresh container recomputes them (~2h CPU for the full suite at
REPRO_EPOCHS=10).  Dry-run/roofline tables replay the JSON written by
``repro.launch.dryrun --json`` if present.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from benchmarks import repro_tables, wire
from benchmarks.common import RESULTS_DIR, check, fmt_table

CNN_COLS = ["name", "acc_off", "acc_on", "seconds"]
LM_COLS = ["name", "eval_loss", "ppl", "eval_loss_off", "ppl_off", "seconds"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "table3", "table4",
                             "table5", "wire", "roofline"])
    ap.add_argument("--rerun", action="store_true")
    ap.add_argument("--cached-only", action="store_true",
                    help="replay cached rows; never train")
    args = ap.parse_args(argv)
    if args.cached_only:
        import benchmarks.common as common
        common.CACHED_ONLY = True
    want = lambda t: args.only in (None, t)
    out = []

    tables = {}
    if want("table1"):
        tables["t1"] = repro_tables.table1(args.rerun)
        out.append(fmt_table(
            "Table 1 — quantization fw[A]-bw[B] (ResNet-ish / synth-CIFAR)",
            tables["t1"], CNN_COLS))
    if want("table2"):
        tables["t2"] = repro_tables.table2(args.rerun)
        out.append(fmt_table("Table 2 — TopK sweep", tables["t2"], CNN_COLS))
    if want("table3"):
        tables["t3"] = repro_tables.table3(args.rerun)
        out.append(fmt_table("Table 3 — error feedback (EF/EF-mixed/EF21)",
                             tables["t3"], CNN_COLS))
    if want("table4"):
        tables["t4"] = repro_tables.table4(args.rerun)
        out.append(fmt_table("Table 4 — AQ-SGD + TopK", tables["t4"],
                             CNN_COLS))
    if want("table5"):
        tables["t5"] = repro_tables.table5(args.rerun)
        out.append(fmt_table("Table 5 — LM fine-tune TopK (index reuse vs "
                             "separate)", tables["t5"], LM_COLS))
    if want("wire"):
        out.append(fmt_table(
            "Wire model — bytes per boundary per step (B=8,S=1024,d=768)",
            wire.rows(), ["name", "fw_MB", "bw_MB", "ratio", "ms_1gbit",
                          "ms_ici"]))
    if want("roofline"):
        from benchmarks.roofline import fmt, terms
        js = sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun*.json")))
        if js:
            rows = []
            for p in js:
                with open(p) as f:
                    rows += [terms(r) for r in json.load(f)]
            out.append("\n### Roofline (from dry-run artifacts)\n\n"
                       + fmt(rows) + "\n")
        else:
            out.append("\n### Roofline: no dryrun JSON found — run "
                       "`python -m repro.launch.dryrun --all --json "
                       "benchmarks/results/dryrun_single.json`\n")

    print("".join(out))

    if args.only is None and all(len(tables.get(k, [])) > 1 for k in
                                 ("t1", "t2", "t3", "t4", "t5")):
        claims = repro_tables.validate(tables["t1"], tables["t2"],
                                       tables["t3"], tables["t4"],
                                       tables["t5"])
        print("### Paper-findings validation (F1-F6)")
        print("\n".join(check(claims)))
        bad = sum(0 if ok else 1 for _, ok in claims)
        print(f"# {len(claims) - bad}/{len(claims)} findings reproduced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
