"""HLO collective-bytes measurement of the REAL compressed pipeline.

The convergence experiments use the paper's simulated-MP boundary (inside
one SPMD program — no inter-stage collective).  This benchmark lowers the
actual ``shard_map`` pipeline (core/pipeline.py) on a production-mesh
stage axis and reads the ``collective-permute`` bytes out of the compiled
HLO for each wire scheme — the paper's compression ratio, visible in the
collective roofline term.

Run:
  PYTHONPATH=src python -m benchmarks.pipeline_wire          # 4-stage, GPT-2ish
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

import json

import jax
import jax.numpy as jnp

from repro.launch.dryrun import collective_bytes


def measure(schemes=("none", "q8", "q4", "topk"), *, stages=4,
            batch=32, seq=1024, d_model=768, d_ff=3072, k_frac=0.10):
    """Returns one report per scheme: collective-permute bytes/step."""
    from repro.core.pipeline import pipeline_forward
    n_dev = jax.device_count()
    data = n_dev // stages
    mesh = jax.make_mesh((stages, data), ("stage", "data"))

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": (jax.random.normal(k1, (stages, d_model, d_ff), jnp.float32)
               * (1 / d_model) ** 0.5).astype(jnp.bfloat16),
        "w2": (jax.random.normal(k2, (stages, d_ff, d_model), jnp.float32)
               * (1 / d_ff) ** 0.5).astype(jnp.bfloat16),
    }

    def stage_fn(p, h):
        return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])

    x = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.bfloat16)
    params_s = jax.eval_shape(lambda: params)

    reports = []
    for scheme in schemes:
        def run(p, xx):
            return pipeline_forward(stage_fn, p, xx, mesh, "stage",
                                    scheme=scheme, k_frac=k_frac)
        lowered = jax.jit(run).lower(params_s, x)
        compiled = lowered.compile()
        coll = collective_bytes(compiled.as_text())
        cp = coll.get("collective-permute", 0)
        reports.append({
            "scheme": scheme, "stages": stages,
            "collective_permute_bytes": cp,
            "all_collectives": coll,
            "ratio_vs_none": None,
        })
    base = reports[0]["collective_permute_bytes"] or 1
    for r in reports:
        r["ratio_vs_none"] = round(base / max(r["collective_permute_bytes"],
                                              1), 2)
    return reports


def main():
    reports = measure()
    for r in reports:
        print(json.dumps(r))
    os.makedirs(os.path.join(os.path.dirname(__file__), "results"),
                exist_ok=True)
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "pipeline_wire.json"), "w") as f:
        json.dump(reports, f, indent=1)


if __name__ == "__main__":
    main()
