"""Bytes-on-wire of the REAL compressed pipeline, forward AND backward.

The differentiable pipeline (repro/transport/pipeline.py) ppermutes a packed
payload forward (activations) and a packed payload backward (activation-
gradients).  This benchmark measures both per wire scheme:

  * exact payload bytes per hop (from the packed pytree's shapes/dtypes),
    ASSERTED against each codec's ``wire_bytes_per_elem`` cost model to
    within per-tensor-scale overhead;
  * collective-permute bytes in the compiled HLO of the forward-only and
    the value_and_grad programs — the compression ratio visible in the
    collective roofline term;
  * a per-SCHEDULE section (gpipe / 1f1b / interleaved): analytic bubble
    fraction, per-microbatch wire bytes across all cuts, and the compiled
    collective-permute LAUNCH count — asserting interleaved's smaller
    bubble and that the fused 1F1B hop at most halves steady-state
    launches.

Run:
  PYTHONPATH=src python -m benchmarks.pipeline_wire          # 4-stage, GPT-2ish
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp

from repro.launch.dryrun import collective_bytes


def payload_bytes(scheme: str, feat_shape, k_frac: float):
    """(fw, bw, fw_model, bw_model) bytes for ONE pipeline hop.

    fw/bw are exact packed-payload bytes (eval_shape, no compute);
    fw_model/bw_model come from the codec cost model (excl. scales).
    """
    from repro.transport.codecs import wire_bytes
    from repro.transport.pipeline import (PipelineTransport,
                                          _policy_for_scheme)
    policy = _policy_for_scheme(scheme, k_frac)
    transport = PipelineTransport(policy, "stage", 4)
    x = jax.ShapeDtypeStruct(feat_shape, jnp.bfloat16)
    fw_payload = jax.eval_shape(
        lambda a: transport._fw_codec.pack(a, policy.fw.k_frac), x)
    fw = wire_bytes(fw_payload)
    n = 1
    for s in feat_shape[1:]:
        n *= s
    if policy.reuse_indices:
        # backward payload is values only (indices already at both ends);
        # its length is the FORWARD pack's k — the reused indices
        k = max(1, int(round(policy.fw.k_frac * n)))
        bw = feat_shape[0] * k * 2
    else:
        bw_payload = jax.eval_shape(
            lambda a: transport._bw_codec.pack(a, policy.bw.k_frac), x)
        bw = wire_bytes(bw_payload)
    fw_model, bw_model = transport.wire_bytes_per_example(n, elem_bytes=2)
    return fw, bw, fw_model * feat_shape[0], bw_model * feat_shape[0]


def feedback_payload_bytes(feedback: str, bw_feedback: str, feat_shape,
                           k_frac: float, num_samples: int = 64):
    """(fw, bw, fw_model, bw_model) bytes for one hop under an
    error-feedback mode (TopK compressors, paper Tables 3-4).

    The compensated message costs the SAME wire bytes as the plain
    compressor — EF packs x+e (one payload), EF-mixed packs two half-K
    payloads, EF21/AQ-SGD pack the delta — which is asserted against the
    feedback-free codec cost model below.
    """
    from repro.core.policy import BoundaryPolicy
    from repro.core.compressors import topk
    from repro.transport.codecs import wire_bytes
    from repro.transport.pipeline import PipelineTransport
    import jax.numpy as jnp
    policy = BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                            feedback=feedback, bw_feedback=bw_feedback)
    transport = PipelineTransport(policy, "stage", 4)
    x = jax.ShapeDtypeStruct(feat_shape, jnp.bfloat16)
    fw = wire_bytes(transport.fw_payload_struct(x))
    bw = wire_bytes(transport.bw_payload_struct(x))
    n = 1
    for s in feat_shape[1:]:
        n *= s
    fw_model, bw_model = transport.wire_bytes_per_example(n, elem_bytes=2)
    return fw, bw, fw_model * feat_shape[0], bw_model * feat_shape[0]


def measure_feedback(modes=(("none", "none"), ("ef", "ef"),
                            ("ef21", "ef21"), ("efmixed", "efmixed"),
                            ("aqsgd", "none")), *, batch=8, seq=256,
                     d_model=256, stages=4, k_frac=0.10,
                     check: bool = True):
    """Per-feedback-mode fw+bw payload bytes (AQ-SGD message vs plain
    TopK), asserted against the codec cost models: error compensation is
    wire-cost-free."""
    mb_feat = (batch // stages, seq, d_model)
    reports = []
    for fb, bw_fb in modes:
        fw, bw, fw_model, bw_model = feedback_payload_bytes(
            fb, bw_fb, mb_feat, k_frac)
        if check:
            # slack: per-tensor scales + the max(1, round(k/2 * n))
            # rounding of EF-mixed's two half-K payloads
            slack = 64 + 0.005 * max(fw_model, 1)
            assert abs(fw - fw_model) <= slack, (fb, fw, fw_model)
            slack = 64 + 0.005 * max(bw_model, 1)
            assert abs(bw - bw_model) <= slack, (bw_fb, bw, bw_model)
        reports.append({
            "feedback": fb, "bw_feedback": bw_fb, "scheme": "topk",
            "k_frac": k_frac, "fw_payload_bytes": fw,
            "bw_payload_bytes": bw, "fw_model_bytes": round(fw_model),
            "bw_model_bytes": round(bw_model),
        })
    return reports


def measure_schedules(*, stages=4, batch=16, seq=32, d_model=64, d_ff=128,
                      mb=8, v=2, scheme="q8", k_frac=0.10,
                      check: bool = True):
    """Per-schedule report (ISSUE 3): analytic bubble fraction, collective-
    permute LAUNCH count of the compiled fw+bw program, and fw+bw payload
    bytes per microbatch (per-hop payload x wire cuts).

    The scan body lowers ONCE into the while loop, so the HLO launch count
    IS the per-steady-state-tick launch count (x2: one fw loop, one bw
    loop, plus O(1) ops outside).  Asserted here:

      * interleaved (v) bubble fraction < GPipe's — (S-1)/(v*mb+S-1) vs
        (S-1)/(mb+S-1);
      * the fused 1F1B hop at most HALVES steady-state collective
        launches vs the same schedule unfused (q8 payloads: the codes +
        min + scale leaves ride one byte buffer instead of three
        collectives per direction).
    """
    import dataclasses
    from repro.launch.dryrun import collective_counts
    from repro.transport.pipeline import pipeline_apply
    from repro.transport.schedules import get_schedule
    n_dev = jax.device_count()
    assert n_dev >= stages, (n_dev, stages)
    mesh = jax.make_mesh((stages,), ("stage",))
    key = jax.random.PRNGKey(0)

    def stage_fn(p, h):
        return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])

    def params_struct(n_slices):
        return {
            "w1": jax.ShapeDtypeStruct((n_slices, d_model, d_ff),
                                       jnp.bfloat16),
            "w2": jax.ShapeDtypeStruct((n_slices, d_ff, d_model),
                                       jnp.bfloat16),
        }

    x = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.bfloat16)

    def launches(sched, n_slices):
        def loss(p, xx):
            out = pipeline_apply(stage_fn, p, xx, mesh, "stage",
                                 scheme=scheme, k_frac=k_frac,
                                 microbatches=mb, schedule=sched)
            return jnp.sum(out.astype(jnp.float32) ** 2)
        hlo = jax.jit(jax.grad(loss)).lower(
            params_struct(n_slices), x).compile().as_text()
        return collective_counts(hlo).get("collective-permute", 0)

    mb_feat = (batch // mb, seq, d_model)
    fw_hop, bw_hop, _, _ = payload_bytes(scheme, mb_feat, k_frac)
    configs = [
        (get_schedule("gpipe"), stages),
        (get_schedule("1f1b"), stages),
        (get_schedule("interleaved", v), stages * v),
    ]
    reports = []
    for sched, n_slices in configs:
        rep = sched.describe(mb, stages)
        rep.update({
            "scheme": scheme, "stages": stages, "microbatches": mb,
            "collective_permute_launches": launches(sched, n_slices),
            "fw_payload_bytes_per_hop": fw_hop,
            "bw_payload_bytes_per_hop": bw_hop,
            "fw_wire_bytes_per_microbatch":
                fw_hop * sched.wire_cuts(stages),
            "bw_wire_bytes_per_microbatch":
                bw_hop * sched.wire_cuts(stages),
        })
        reports.append(rep)
    unfused = dataclasses.replace(get_schedule("1f1b"), fused_wire=False)
    unfused_launches = launches(unfused, stages)
    reports[1]["collective_permute_launches_unfused"] = unfused_launches
    if check:
        by = {r["schedule"]: r for r in reports}
        assert (by["interleaved"]["bubble_fraction"]
                < by["gpipe"]["bubble_fraction"]), reports
        fused_launches = by["1f1b"]["collective_permute_launches"]
        assert fused_launches * 2 <= unfused_launches, (
            fused_launches, unfused_launches)
    return reports


def measure(schemes=("none", "q8", "q4", "topk", "topk_reuse"), *, stages=4,
            batch=8, seq=256, d_model=256, d_ff=1024, k_frac=0.10,
            check: bool = True):
    """One report per scheme: exact fw/bw payload bytes per hop (checked
    against the codec cost model) + compiled-HLO collective-permute bytes
    for the forward and the grad program."""
    from repro.transport.pipeline import pipeline_apply
    n_dev = jax.device_count()
    assert n_dev >= stages, (n_dev, stages)
    mesh = jax.make_mesh((stages,), ("stage",))

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": (jax.random.normal(k1, (stages, d_model, d_ff), jnp.float32)
               * (1 / d_model) ** 0.5).astype(jnp.bfloat16),
        "w2": (jax.random.normal(k2, (stages, d_ff, d_model), jnp.float32)
               * (1 / d_ff) ** 0.5).astype(jnp.bfloat16),
    }

    def stage_fn(p, h):
        return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])

    x = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.bfloat16)
    params_s = jax.eval_shape(lambda: params)
    mb_feat = (batch // stages, seq, d_model)

    reports = []
    for scheme in schemes:
        def run(p, xx):
            return pipeline_apply(stage_fn, p, xx, mesh, "stage",
                                  scheme=scheme, k_frac=k_frac)

        def loss(p, xx):
            return jnp.sum(run(p, xx).astype(jnp.float32) ** 2)

        fw_hlo = collective_bytes(
            jax.jit(run).lower(params_s, x).compile().as_text()
        ).get("collective-permute", 0)
        grad_hlo = collective_bytes(
            jax.jit(jax.grad(loss)).lower(params_s, x).compile().as_text()
        ).get("collective-permute", 0)

        fw, bw, fw_model, bw_model = payload_bytes(scheme, mb_feat, k_frac)
        if check:
            # cost model holds to within per-tensor-scale overhead
            # (min/scale scalars, one q4 pad nibble column)
            slack = 64 + 0.005 * max(fw_model, 1)
            assert abs(fw - fw_model) <= slack, (scheme, fw, fw_model)
            slack = 64 + 0.005 * max(bw_model, 1)
            assert abs(bw - bw_model) <= slack, (scheme, bw, bw_model)

        reports.append({
            "scheme": scheme, "stages": stages, "k_frac": k_frac,
            "fw_payload_bytes": fw, "bw_payload_bytes": bw,
            "fw_model_bytes": round(fw_model), "bw_model_bytes": round(bw_model),
            "hlo_fw_collective_permute_bytes": fw_hlo,
            "hlo_fwbw_collective_permute_bytes": grad_hlo,
            "fw_ratio_vs_none": None, "bw_ratio_vs_none": None,
        })
    base_fw = reports[0]["fw_payload_bytes"] or 1
    base_bw = reports[0]["bw_payload_bytes"] or 1
    for r in reports:
        r["fw_ratio_vs_none"] = round(base_fw / max(r["fw_payload_bytes"], 1),
                                      2)
        r["bw_ratio_vs_none"] = round(base_bw / max(r["bw_payload_bytes"], 1),
                                      2)
    return reports


def main():
    reports = measure()
    for r in reports:
        print(json.dumps(r))
    fb_reports = measure_feedback()
    for r in fb_reports:
        print(json.dumps(r))
    sched_reports = measure_schedules()
    for r in sched_reports:
        print(json.dumps(r))
    os.makedirs(os.path.join(os.path.dirname(__file__), "results"),
                exist_ok=True)
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "pipeline_wire.json"), "w") as f:
        json.dump({"schemes": reports, "feedback": fb_reports,
                   "schedules": sched_reports}, f, indent=1)


if __name__ == "__main__":
    main()
