"""Bytes-on-wire of the REAL compressed pipeline, forward AND backward.

The differentiable pipeline (repro/transport/pipeline.py) ppermutes a packed
payload forward (activations) and a packed payload backward (activation-
gradients).  This benchmark measures both per wire scheme:

  * exact payload bytes per hop (from the packed pytree's shapes/dtypes),
    ASSERTED against each codec's ``wire_bytes_per_elem`` cost model to
    within per-tensor-scale overhead;
  * collective-permute bytes in the compiled HLO of the forward-only and
    the value_and_grad programs — the compression ratio visible in the
    collective roofline term;
  * a per-SCHEDULE section (gpipe / 1f1b / interleaved): analytic bubble
    fraction, per-microbatch wire bytes across all cuts, and the compiled
    collective-permute LAUNCH count — asserting interleaved's smaller
    bubble and that the fused 1F1B hop at most halves steady-state
    launches.

Run:
  PYTHONPATH=src python -m benchmarks.pipeline_wire          # 4-stage, GPT-2ish
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # 8 devices: the 4-stage pipeline sections use 4, the 3D
    # (data=2, stage=2, tensor=2) ring audit needs all 8
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json

import jax
import jax.numpy as jnp

from repro.launch.dryrun import collective_bytes


def payload_bytes(scheme: str, feat_shape, k_frac: float):
    """(fw, bw, fw_model, bw_model) bytes for ONE pipeline hop.

    fw/bw are exact packed-payload bytes (eval_shape, no compute);
    fw_model/bw_model come from the codec cost model (excl. scales).
    """
    from repro.transport.codecs import wire_bytes
    from repro.transport.pipeline import (PipelineTransport,
                                          _policy_for_scheme)
    policy = _policy_for_scheme(scheme, k_frac)
    transport = PipelineTransport(policy, "stage", 4)
    x = jax.ShapeDtypeStruct(feat_shape, jnp.bfloat16)
    fw_payload = jax.eval_shape(
        lambda a: transport._fw_codec.pack(a, policy.fw.k_frac), x)
    fw = wire_bytes(fw_payload)
    n = 1
    for s in feat_shape[1:]:
        n *= s
    if policy.reuse_indices:
        # backward payload is values only (indices already at both ends);
        # its length is the FORWARD pack's k — the reused indices
        k = max(1, int(round(policy.fw.k_frac * n)))
        bw = feat_shape[0] * k * 2
    else:
        bw_payload = jax.eval_shape(
            lambda a: transport._bw_codec.pack(a, policy.bw.k_frac), x)
        bw = wire_bytes(bw_payload)
    fw_model, bw_model = transport.wire_bytes_per_example(n, elem_bytes=2)
    return fw, bw, fw_model * feat_shape[0], bw_model * feat_shape[0]


def feedback_payload_bytes(feedback: str, bw_feedback: str, feat_shape,
                           k_frac: float, num_samples: int = 64):
    """(fw, bw, fw_model, bw_model) bytes for one hop under an
    error-feedback mode (TopK compressors, paper Tables 3-4).

    The compensated message costs the SAME wire bytes as the plain
    compressor — EF packs x+e (one payload), EF-mixed packs two half-K
    payloads, EF21/AQ-SGD pack the delta — which is asserted against the
    feedback-free codec cost model below.
    """
    from repro.core.policy import BoundaryPolicy
    from repro.core.compressors import topk
    from repro.transport.codecs import wire_bytes
    from repro.transport.pipeline import PipelineTransport
    import jax.numpy as jnp
    policy = BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                            feedback=feedback, bw_feedback=bw_feedback)
    transport = PipelineTransport(policy, "stage", 4)
    x = jax.ShapeDtypeStruct(feat_shape, jnp.bfloat16)
    fw = wire_bytes(transport.fw_payload_struct(x))
    bw = wire_bytes(transport.bw_payload_struct(x))
    n = 1
    for s in feat_shape[1:]:
        n *= s
    fw_model, bw_model = transport.wire_bytes_per_example(n, elem_bytes=2)
    return fw, bw, fw_model * feat_shape[0], bw_model * feat_shape[0]


def measure_feedback(modes=(("none", "none"), ("ef", "ef"),
                            ("ef21", "ef21"), ("efmixed", "efmixed"),
                            ("aqsgd", "none")), *, batch=8, seq=256,
                     d_model=256, stages=4, k_frac=0.10,
                     check: bool = True):
    """Per-feedback-mode fw+bw payload bytes (AQ-SGD message vs plain
    TopK), asserted against the codec cost models: error compensation is
    wire-cost-free."""
    mb_feat = (batch // stages, seq, d_model)
    reports = []
    for fb, bw_fb in modes:
        fw, bw, fw_model, bw_model = feedback_payload_bytes(
            fb, bw_fb, mb_feat, k_frac)
        if check:
            # slack: per-tensor scales + the max(1, round(k/2 * n))
            # rounding of EF-mixed's two half-K payloads
            slack = 64 + 0.005 * max(fw_model, 1)
            assert abs(fw - fw_model) <= slack, (fb, fw, fw_model)
            slack = 64 + 0.005 * max(bw_model, 1)
            assert abs(bw - bw_model) <= slack, (bw_fb, bw, bw_model)
        reports.append({
            "feedback": fb, "bw_feedback": bw_fb, "scheme": "topk",
            "k_frac": k_frac, "fw_payload_bytes": fw,
            "bw_payload_bytes": bw, "fw_model_bytes": round(fw_model),
            "bw_model_bytes": round(bw_model),
        })
    return reports


def measure_schedules(*, stages=4, batch=16, seq=32, d_model=64, d_ff=128,
                      mb=8, v=2, scheme="q8", k_frac=0.10,
                      check: bool = True):
    """Per-schedule report (ISSUE 3): analytic bubble fraction, collective-
    permute LAUNCH count of the compiled fw+bw program, and fw+bw payload
    bytes per microbatch (per-hop payload x wire cuts).

    The scan body lowers ONCE into the while loop, so the HLO launch count
    IS the per-steady-state-tick launch count (x2: one fw loop, one bw
    loop, plus O(1) ops outside).  Asserted here:

      * interleaved (v) bubble fraction < GPipe's — (S-1)/(v*mb+S-1) vs
        (S-1)/(mb+S-1);
      * the fused 1F1B hop at most HALVES steady-state collective
        launches vs the same schedule unfused (q8 payloads: the codes +
        min + scale leaves ride one byte buffer instead of three
        collectives per direction).
    """
    import dataclasses
    from repro.launch.dryrun import collective_counts
    from repro.transport.pipeline import pipeline_apply
    from repro.transport.schedules import get_schedule
    n_dev = jax.device_count()
    assert n_dev >= stages, (n_dev, stages)
    mesh = jax.make_mesh((stages,), ("stage",))
    key = jax.random.PRNGKey(0)

    def stage_fn(p, h):
        return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])

    def params_struct(n_slices):
        return {
            "w1": jax.ShapeDtypeStruct((n_slices, d_model, d_ff),
                                       jnp.bfloat16),
            "w2": jax.ShapeDtypeStruct((n_slices, d_ff, d_model),
                                       jnp.bfloat16),
        }

    x = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.bfloat16)

    def launches(sched, n_slices):
        def loss(p, xx):
            out = pipeline_apply(stage_fn, p, xx, mesh, "stage",
                                 scheme=scheme, k_frac=k_frac,
                                 microbatches=mb, schedule=sched)
            return jnp.sum(out.astype(jnp.float32) ** 2)
        hlo = jax.jit(jax.grad(loss)).lower(
            params_struct(n_slices), x).compile().as_text()
        return collective_counts(hlo).get("collective-permute", 0)

    mb_feat = (batch // mb, seq, d_model)
    fw_hop, bw_hop, _, _ = payload_bytes(scheme, mb_feat, k_frac)
    configs = [
        (get_schedule("gpipe"), stages),
        (get_schedule("1f1b"), stages),
        (get_schedule("interleaved", v), stages * v),
    ]
    reports = []
    for sched, n_slices in configs:
        rep = sched.describe(mb, stages)
        rep.update({
            "scheme": scheme, "stages": stages, "microbatches": mb,
            "collective_permute_launches": launches(sched, n_slices),
            "fw_payload_bytes_per_hop": fw_hop,
            "bw_payload_bytes_per_hop": bw_hop,
            "fw_wire_bytes_per_microbatch":
                fw_hop * sched.wire_cuts(stages),
            "bw_wire_bytes_per_microbatch":
                bw_hop * sched.wire_cuts(stages),
        })
        reports.append(rep)
    unfused = dataclasses.replace(get_schedule("1f1b"), fused_wire=False)
    unfused_launches = launches(unfused, stages)
    reports[1]["collective_permute_launches_unfused"] = unfused_launches
    if check:
        by = {r["schedule"]: r for r in reports}
        assert (by["interleaved"]["bubble_fraction"]
                < by["gpipe"]["bubble_fraction"]), reports
        fused_launches = by["1f1b"]["collective_permute_launches"]
        assert fused_launches * 2 <= unfused_launches, (
            fused_launches, unfused_launches)
    return reports


def measure(schemes=("none", "q8", "q4", "topk", "topk_reuse"), *, stages=4,
            batch=8, seq=256, d_model=256, d_ff=1024, k_frac=0.10,
            check: bool = True):
    """One report per scheme: exact fw/bw payload bytes per hop (checked
    against the codec cost model) + compiled-HLO collective-permute bytes
    for the forward and the grad program."""
    from repro.transport.pipeline import pipeline_apply
    n_dev = jax.device_count()
    assert n_dev >= stages, (n_dev, stages)
    mesh = jax.make_mesh((stages,), ("stage",))

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": (jax.random.normal(k1, (stages, d_model, d_ff), jnp.float32)
               * (1 / d_model) ** 0.5).astype(jnp.bfloat16),
        "w2": (jax.random.normal(k2, (stages, d_ff, d_model), jnp.float32)
               * (1 / d_ff) ** 0.5).astype(jnp.bfloat16),
    }

    def stage_fn(p, h):
        return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])

    x = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.bfloat16)
    params_s = jax.eval_shape(lambda: params)
    mb_feat = (batch // stages, seq, d_model)

    reports = []
    for scheme in schemes:
        def run(p, xx):
            return pipeline_apply(stage_fn, p, xx, mesh, "stage",
                                  scheme=scheme, k_frac=k_frac)

        def loss(p, xx):
            return jnp.sum(run(p, xx).astype(jnp.float32) ** 2)

        fw_hlo = collective_bytes(
            jax.jit(run).lower(params_s, x).compile().as_text()
        ).get("collective-permute", 0)
        grad_hlo = collective_bytes(
            jax.jit(jax.grad(loss)).lower(params_s, x).compile().as_text()
        ).get("collective-permute", 0)

        fw, bw, fw_model, bw_model = payload_bytes(scheme, mb_feat, k_frac)
        if check:
            # cost model holds to within per-tensor-scale overhead
            # (min/scale scalars, one q4 pad nibble column)
            slack = 64 + 0.005 * max(fw_model, 1)
            assert abs(fw - fw_model) <= slack, (scheme, fw, fw_model)
            slack = 64 + 0.005 * max(bw_model, 1)
            assert abs(bw - bw_model) <= slack, (scheme, bw, bw_model)

        reports.append({
            "scheme": scheme, "stages": stages, "k_frac": k_frac,
            "fw_payload_bytes": fw, "bw_payload_bytes": bw,
            "fw_model_bytes": round(fw_model), "bw_model_bytes": round(bw_model),
            "hlo_fw_collective_permute_bytes": fw_hlo,
            "hlo_fwbw_collective_permute_bytes": grad_hlo,
            "fw_ratio_vs_none": None, "bw_ratio_vs_none": None,
        })
    base_fw = reports[0]["fw_payload_bytes"] or 1
    base_bw = reports[0]["bw_payload_bytes"] or 1
    for r in reports:
        r["fw_ratio_vs_none"] = round(base_fw / max(r["fw_payload_bytes"], 1),
                                      2)
        r["bw_ratio_vs_none"] = round(base_bw / max(r["bw_payload_bytes"], 1),
                                      2)
    return reports


def measure_dp(codecs=("none", "q8", "q4", "topk"), *, dp=2, stages=2,
               d_model=64, d_ff=128, k_frac=0.10, check: bool = True):
    """Per-dp-codec report for the compressed DP gradient all-reduce
    (transport/collectives.py) on the 2D ``(data, stages)`` mesh:

      * exact fused payload bytes per ring hop (from the packed payload
        shapes, per-leaf per-tensor scales and the q4 pad/ragged-TopK
        paths included), ASSERTED against the codec's
        ``wire_bytes_per_elem`` cost model;
      * wire bytes per reduce per replica = ``(dp - 1)`` hops x payload;
      * collective-permute LAUNCH counts of the compiled reduce, fused
        (one uint8 buffer per hop) vs unfused (one launch per payload
        leaf per hop) — asserting the fusion at most halves launches
        whenever payloads are multi-leaf;
      * for q8: the DATA-RING launch count inside a full 2D DPxPP train
        step, split from the stage ring by the collective's
        source-target pairs (the ``collective_counts(by_pairs=True)``
        audit from launch/dryrun.py).
    """
    from repro.launch.dryrun import collective_counts
    from repro.launch.mesh import make_dp_pipeline_mesh
    from repro.transport.collectives import (dp_wire_report, init_dp_state,
                                             make_grad_all_reduce)
    from repro.transport.pipeline import pipeline_apply
    mesh = make_dp_pipeline_mesh(dp, stages)
    grads_like = {
        "w1": jax.ShapeDtypeStruct((stages, d_model, d_ff), jnp.float32),
        "w2": jax.ShapeDtypeStruct((stages, d_ff, d_model), jnp.float32),
        "gamma": jax.ShapeDtypeStruct((33,), jnp.float32),   # odd/ragged
    }
    grads_dp = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dp, *s.shape), s.dtype), grads_like)

    def launches(codec, fused):
        fn = make_grad_all_reduce(mesh, "data", codec, k_frac=k_frac,
                                  fused=fused)
        st = init_dp_state(grads_like, dp, "none")
        hlo = jax.jit(fn).lower(
            grads_dp, jax.eval_shape(lambda: st)).compile().as_text()
        return collective_counts(hlo).get("collective-permute", 0)

    def dp_ring_pairs():
        """The data-axis ring's source-target pair signature on this
        mesh: within each stage column, replica r sends to r+1."""
        dev = mesh.devices
        pairs = set()
        for j in range(stages):
            for r in range(dp):
                pairs.add((int(dev[r, j].id), int(dev[(r + 1) % dp, j].id)))
        return pairs

    def train_step_ring_launches():
        """collective-permute launches along the DATA axis inside one
        compiled 2D train step (toy pipeline + fused q8 DP reduce)."""
        reduce_fn = make_grad_all_reduce(mesh, "data", "q8", k_frac=k_frac)

        def stage_fn(p, h):
            return h + (jax.nn.gelu((h @ p["w1"]).astype(jnp.float32))
                        .astype(jnp.bfloat16) @ p["w2"])

        def step(params, dp_state, x):
            pdp = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (dp, *a.shape)), params)

            def loss(p):
                y = pipeline_apply(stage_fn, p, x, mesh, "stage",
                                   scheme="q8", dp_axis="data")
                return jnp.sum(y.astype(jnp.float32) ** 2)
            g = jax.grad(loss)(pdp)
            return reduce_fn(g, dp_state)

        params = {
            "w1": jax.ShapeDtypeStruct((stages, d_model, d_ff),
                                       jnp.bfloat16),
            "w2": jax.ShapeDtypeStruct((stages, d_ff, d_model),
                                       jnp.bfloat16),
        }
        st = init_dp_state(params, dp, "none")
        x = jax.ShapeDtypeStruct((8, d_model), jnp.bfloat16)
        hlo = jax.jit(step).lower(
            params, jax.eval_shape(lambda: st), x).compile().as_text()
        ring = dp_ring_pairs()
        data_ring, stage_ring = 0, 0
        for key, n in collective_counts(hlo, by_pairs=True).items():
            op, _, pairs_s = key.partition("|")
            if op != "collective-permute" or not pairs_s.startswith("{"):
                continue
            pairs = {tuple(int(v) for v in p.split(","))
                     for p in pairs_s[2:-2].split("},{")}
            if pairs <= ring:
                data_ring += n
            else:
                stage_ring += n
        return data_ring, stage_ring

    reports = []
    for codec in codecs:
        rep = dp_wire_report(grads_like, codec, k_frac=k_frac, dp=dp)
        rep["collective_permute_launches"] = launches(codec, True)
        rep["collective_permute_launches_unfused"] = launches(codec, False)
        if check:
            # cost model holds to within per-leaf scale overhead (+ the
            # q4 pad nibble / TopK k-rounding per ragged leaf)
            slack = 16 * rep["n_param_leaves"] \
                + 0.005 * max(rep["model_bytes"], 1)
            assert abs(rep["payload_bytes_per_hop"]
                       - rep["model_bytes"]) <= slack, rep
            assert rep["collective_permute_launches"] == dp - 1, rep
            if rep["n_payload_leaves"] > rep["n_param_leaves"]:
                assert (rep["collective_permute_launches"] * 2
                        <= rep["collective_permute_launches_unfused"]), rep
        reports.append(rep)
    data_ring, stage_ring = train_step_ring_launches()
    reports.append({
        "dp_codec": "q8", "section": "2d_train_step_audit", "dp": dp,
        "stages": stages,
        "data_ring_collective_permute_launches": data_ring,
        "stage_ring_collective_permute_launches": stage_ring,
    })
    if check:
        # the fused DP reduce adds exactly dp-1 data-axis launches to the
        # whole train step; the stage ring keeps its own (scan-looped) hops
        assert data_ring == dp - 1, reports[-1]
        assert stage_ring >= 1, reports[-1]
    return reports


def measure_tp(codecs=("none", "q8", "q4", "topk"), *, tp=2, batch=4,
               seq=256, d_model=256, d_ff=512, k_frac=0.10,
               check: bool = True):
    """Per-tp-codec report for the compressed tensor-parallel collectives
    (transport/tp_collectives.py) on the ``tensor`` ring:

      * exact packed payload bytes of one sequence shard per ring hop
        (``tp_wire_report``), ASSERTED against the codec's
        ``wire_bytes_per_elem`` cost model;
      * collective-permute LAUNCH count of one compiled ``tp_apply``
        forward with a single gather+scatter site — the fused framing
        rings ONE buffer per hop, so the count is exactly
        ``2 * (tp - 1)``;
      * a 2x2x2 ``(data, stage, tensor)`` train step:
        ``collective_counts(by_pairs=True)`` buckets every permute
        launch into the three rings via ``obs.probes.ring_pairs`` —
        asserting the rings never mix (no unclassified launches) and
        each carries its own traffic.
    """
    from repro.launch.dryrun import collective_counts
    from repro.launch.mesh import make_3d_mesh, make_tensor_mesh
    from repro.obs.probes import ring_pairs
    from repro.transport.collectives import (init_dp_state,
                                             make_grad_all_reduce)
    from repro.transport.pipeline import pipeline_apply
    from repro.transport.tp_collectives import (TPCollectives, tp_apply,
                                                tp_wire_report)
    mesh = make_tensor_mesh(tp)
    feat = (batch, seq, d_model)
    # GLOBAL weight shapes: tp_apply/pipeline_apply slice the sharded dim
    params_s = {
        "w1": jax.ShapeDtypeStruct((d_model, d_ff), jnp.bfloat16),
        "w2": jax.ShapeDtypeStruct((d_ff, d_model), jnp.bfloat16),
    }
    x_s = jax.ShapeDtypeStruct(feat, jnp.bfloat16)

    def launches(codec):
        tpc = TPCollectives(mesh, "tensor", codec=codec, k_frac=k_frac)

        def stage_fn(p, h, resid, mirror):
            full = tpc.gather(h)[0]
            part = (jax.nn.gelu((full @ p["w1"]).astype(jnp.float32))
                    .astype(jnp.bfloat16) @ p["w2"])
            return h + tpc.scatter(part), resid, mirror

        def run(p, xx):
            y, _ = tp_apply(stage_fn, p, xx, tpc,
                            param_dims={"w1": 1, "w2": 0}, sites=1)
            return y

        hlo = jax.jit(run).lower(params_s, x_s).compile().as_text()
        return collective_counts(hlo).get("collective-permute", 0)

    reports = []
    for codec in codecs:
        rep = tp_wire_report(feat, tp, codec, k_frac=k_frac, sites=1)
        rep["collective_permute_launches_fw"] = launches(codec)
        if check:
            # cost model holds to within per-tensor-scale overhead
            slack = 64 + 0.005 * max(rep["model_bytes"], 1)
            assert abs(rep["payload_bytes_per_hop"]
                       - rep["model_bytes"]) <= slack, rep
            assert rep["collective_permute_launches_fw"] == 2 * (tp - 1), rep
        reports.append(rep)

    # -- 2x2x2 three-ring separation audit ---------------------------------
    dp, stages = 2, 2
    mesh3 = make_3d_mesh(dp, stages, tp)
    tpc3 = TPCollectives(mesh3, "tensor", codec="q8", k_frac=k_frac)

    def stage3_fn(p, h):
        full = tpc3.gather(h)[0]
        part = (jax.nn.gelu((full @ p["w1"]).astype(jnp.float32))
                .astype(jnp.bfloat16) @ p["w2"])
        return h + tpc3.scatter(part)

    reduce_fn = make_grad_all_reduce(
        mesh3, "data", "q8", k_frac=k_frac,
        tp_axis="tensor", tp_dims={"w1": 3, "w2": 2})

    def step(params, dp_state, x):
        pdp = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (dp, *a.shape)), params)

        def loss(p):
            # tp_param_dims index the FULL (dp, stage, ...) leaves
            y = pipeline_apply(stage3_fn, p, x, mesh3, "stage",
                               scheme="q8", k_frac=k_frac, dp_axis="data",
                               tp_axis="tensor",
                               tp_param_dims={"w1": 3, "w2": 2}, seq_dim=1)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        g = jax.grad(loss)(pdp)
        return reduce_fn(g, dp_state)

    params3 = {
        "w1": jax.ShapeDtypeStruct((stages, d_model, d_ff), jnp.bfloat16),
        "w2": jax.ShapeDtypeStruct((stages, d_ff, d_model), jnp.bfloat16),
    }
    st = init_dp_state(params3, dp, "none")
    x3 = jax.ShapeDtypeStruct((8, 32, d_model), jnp.bfloat16)
    hlo = jax.jit(step).lower(
        params3, jax.eval_shape(lambda: st), x3).compile().as_text()
    rings = {ax: ring_pairs(mesh3, ax)
             for ax in ("data", "stage", "tensor")}
    by_ring = {ax: 0 for ax in rings}
    layout, unclassified = 0, 0
    for key, n in collective_counts(hlo, by_pairs=True).items():
        op, _, pairs_s = key.partition("|")
        if op != "collective-permute" or not pairs_s.startswith("{"):
            continue
        pairs = {tuple(int(v) for v in p.split(","))
                 for p in pairs_s[2:-2].split("},{")}
        for ax, ring in rings.items():
            if pairs <= ring:
                by_ring[ax] += n
                break
        else:
            if any(s == t for s, t in pairs):
                # a device-order remap GSPMD inserts to reshard between
                # program regions (self-pairs: rings never self-send)
                layout += n
            else:
                unclassified += n
    audit = {
        "tp_codec": "q8", "section": "3d_train_step_audit",
        "dp": dp, "stages": stages, "tp": tp,
        "data_ring_collective_permute_launches": by_ring["data"],
        "stage_ring_collective_permute_launches": by_ring["stage"],
        "tensor_ring_collective_permute_launches": by_ring["tensor"],
        "layout_collective_permute_launches": layout,
        "unclassified_collective_permute_launches": unclassified,
    }
    if check:
        # the fused DP reduce is exactly dp-1 data hops; the stage scan
        # and the per-stage TP gathers/scatters keep their own rings; no
        # WIRE launch straddles two rings (layout remaps aside)
        assert by_ring["data"] == dp - 1, audit
        assert by_ring["stage"] >= 1, audit
        assert by_ring["tensor"] >= 2, audit
        assert unclassified == 0, audit
    reports.append(audit)
    return reports


def measure_telemetry(schemes=("none", "q8", "q4", "topk", "topk_reuse"),
                      *, stages=4, batch=8, seq=256, d_model=256,
                      k_frac=0.10, steps=10, check: bool = True):
    """§Telemetry: (a) the tracer's per-boundary "pipeline.wire" payload
    bytes agree EXACTLY with this benchmark's own cost-model numbers
    (:func:`payload_bytes` — two independent derivations of the same
    eval_shape facts), per scheme; (b) tracing a jitted step costs <= 3%
    wall time (the wire events fire at TRACE time, so steady state only
    pays the host-side span bookkeeping).  Timing fields are excluded
    from --check (wall-clock noise); the agreement booleans are exact."""
    from repro.obs import trace
    from repro.transport.pipeline import (PipelineTransport,
                                          _policy_for_scheme, wire_telemetry)
    from repro.transport.schedules import as_schedule
    mb_feat = (batch // stages, seq, d_model)
    sched = as_schedule("gpipe", None)
    reports = []
    for scheme in schemes:
        fw, bw, _, _ = payload_bytes(scheme, mb_feat, k_frac)
        policy = _policy_for_scheme(scheme, k_frac)
        transport = PipelineTransport(policy, "stage", stages,
                                      fused=sched.fused_wire)
        tel = wire_telemetry(transport, sched, mb_feat, jnp.bfloat16,
                             microbatches=stages)
        agree = (tel["fw_payload_bytes_per_hop"] == fw
                 and tel["bw_payload_bytes_per_hop"] == bw)
        if check:
            assert agree, (scheme, tel, fw, bw)
        reports.append({
            "scheme": scheme, "telemetry_fw_bytes":
                tel["fw_payload_bytes_per_hop"],
            "telemetry_bw_bytes": tel["bw_payload_bytes_per_hop"],
            "cost_model_fw_bytes": fw, "cost_model_bw_bytes": bw,
            "agree_exactly": agree,
        })

    # -- enabled-tracing overhead on a real jitted pipeline step ------------
    from repro.transport.pipeline import pipeline_apply
    import time
    mesh = jax.make_mesh((stages,), ("stage",))
    params = {"w": jnp.full((stages, 1, 1), 1.0, jnp.bfloat16)}

    def run(p, xx):
        return pipeline_apply(lambda sp, h: h * sp["w"], p, xx, mesh,
                              "stage", scheme="q8", k_frac=k_frac)

    # a small step keeps the whole section fast; the span's ~µs cost is
    # RELATIVELY largest against a small step, so the gate is conservative
    x = jnp.ones((batch, 32, 64), jnp.bfloat16)
    fn = jax.jit(run)
    jax.block_until_ready(fn(params, x))                 # compile + warm

    def timed(enabled: bool) -> float:
        (trace.enable if enabled else trace.disable)()
        t0 = time.perf_counter()
        for step in range(steps):
            with trace.span("train.step", cat="train", step=step):
                jax.block_until_ready(fn(params, x))
        return time.perf_counter() - t0

    # interleaved off/on pairs: ambient machine load hits both halves of
    # a pair about equally, so the BEST pair ratio isolates the span's
    # ~µs bookkeeping from scheduler noise on a busy runner
    pairs = [(timed(False), timed(True)) for _ in range(5)]
    trace.disable()
    off = min(o for o, _ in pairs)
    on = min(n for _, n in pairs)
    ratio = min(n / o for o, n in pairs)
    overhead = ratio - 1.0
    # 3% relative plus a 5ms absolute floor for very fast steps
    ok = ratio <= 1.03 or on <= off + 0.005
    if check:
        assert ok, (on, off, overhead, pairs)
    reports.append({
        "scheme": "overhead", "steps": steps,
        "seconds_off": round(off, 4), "seconds_on": round(on, 4),
        "overhead_pct": round(100.0 * overhead, 2),
        "within_3pct": ok,
    })
    return reports


def measure_policy_audit(*, stages=4, batch=8, k_frac=0.10,
                         spec="q4@size>=65536;q8@size>=16384;none",
                         check: bool = True):
    """Per-boundary audit of an adaptive rule policy (core/policy.py).

    Resolves the spec against a HETEROGENEOUS stack — per-example cut
    sizes shrink with depth, like a pooling CNN — so a single size rule
    legitimately picks different codecs at different cuts.  One row per
    boundary: which rule fired, the resolved fw/bw compressors, and the
    exact packed payload bytes that codec puts on the wire there.
    """
    from repro.core.policy import parse_policy_rules
    from repro.transport.codecs import codec_for, wire_bytes
    feats = [(256, 512), (128, 256), (32, 128)]   # per-example (seq, d)
    sizes = [s * d for s, d in feats]
    rules = parse_policy_rules(spec, num_stages=stages)
    policy = rules.resolve(sizes)
    rows = []
    for i, (feat, size) in enumerate(zip(feats, sizes)):
        bp = policy.at(i)
        x = jax.ShapeDtypeStruct((batch // stages, *feat), jnp.bfloat16)
        fw = wire_bytes(jax.eval_shape(
            lambda a, c=bp.fw: codec_for(c).pack(a, c.k_frac), x))
        bw = wire_bytes(jax.eval_shape(
            lambda a, c=bp.bw: codec_for(c).pack(a, c.k_frac), x))
        rows.append({
            "boundary": i, "size_per_example": size,
            "fw_rule": rules.pick(size, i, "fw").name,
            "bw_rule": rules.pick(size, i, "bw").name,
            "fw_codec": bp.fw.name, "bw_codec": bp.bw.name,
            "fw_payload_bytes": fw, "bw_payload_bytes": bw,
        })
    if check:
        # the point of the rule engine: one spec, distinct per-cut codecs
        assert len({r["fw_codec"] for r in rows}) >= 2, rows
        # and shallower (bigger) cuts never pack FEWER bytes/elem than
        # deeper ones under a monotone size spec
        bpe = [r["fw_payload_bytes"] / r["size_per_example"] for r in rows]
        assert all(a <= b + 1e-6 for a, b in zip(bpe, bpe[1:])), rows
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="regression gate: recompute and compare against "
                         "the committed results/pipeline_wire.json (wire "
                         "bytes and launch counts exact); exit 1 on drift")
    args = ap.parse_args(argv)
    reports = measure()
    for r in reports:
        print(json.dumps(r))
    fb_reports = measure_feedback()
    for r in fb_reports:
        print(json.dumps(r))
    sched_reports = measure_schedules()
    for r in sched_reports:
        print(json.dumps(r))
    dp_reports = measure_dp()
    for r in dp_reports:
        print(json.dumps(r))
    tp_reports = measure_tp()
    for r in tp_reports:
        print(json.dumps(r))
    audit_reports = measure_policy_audit()
    for r in audit_reports:
        print(json.dumps(r))
    tel_reports = measure_telemetry()
    for r in tel_reports:
        print(json.dumps(r))
    fresh = {"schemes": reports, "feedback": fb_reports,
             "schedules": sched_reports, "dp": dp_reports,
             "tp": tp_reports, "policy_audit": audit_reports,
             "telemetry": tel_reports}
    if args.check:
        from benchmarks.common import run_check
        # payload bytes and launch counts are jax-version-stable (payloads
        # come from eval_shape of OUR packing; launch counts are the fused
        # claim being gated).  Whole-program HLO collective BYTES also sum
        # XLA's internal fusion choices, so they get a band instead of
        # exact equality — a compiler upgrade shouldn't red the CI lane.
        return run_check(
            fresh, "pipeline_wire",
            band_keys={"hlo_fw_collective_permute_bytes": 0.25,
                       "hlo_fwbw_collective_permute_bytes": 0.25},
            ignore_keys={"seconds_off", "seconds_on", "overhead_pct"})
    os.makedirs(os.path.join(os.path.dirname(__file__), "results"),
                exist_ok=True)
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "pipeline_wire.json"), "w") as f:
        json.dump(fresh, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
