"""Paper reproduction experiments — Tables 1-5 (Sec. 3).

Scaled-down but protocol-faithful: same compression modes, same MP degree 4
(3 boundaries), eval with compression ON and OFF, warm-start rows, single
seed (the paper reports best-of-5; we report one run and validate the
*qualitative* findings F1-F6 from DESIGN.md).

All tables share the uncompressed baseline run (and its weights, for the
"warmup N" rows), cached under benchmarks/results/.
"""
from __future__ import annotations

import os
from typing import Optional


from benchmarks.common import RESULTS_DIR, run_cached
from repro.checkpoint import io as ckpt
from repro.core.policy import (CompressionPolicy, NO_POLICY, aqsgd_policy,
                               ef_policy, quant_policy, topk_policy)
from repro.data.synthetic import ImageClassData, LMData
from repro.models.config import ModelConfig
from repro.train.loop import (pretrain_lm, run_cnn_experiment,
                              run_lm_experiment)

EPOCHS = int(os.environ.get("REPRO_EPOCHS", "10"))
WARM_EPOCHS = max(2, EPOCHS // 5)          # paper's "warmup 20" of 100 epochs
_DATA: Optional[ImageClassData] = None
_LMDATA: Optional[LMData] = None


def cnn_data() -> ImageClassData:
    global _DATA
    if _DATA is None:
        _DATA = ImageClassData()
    return _DATA


def lm_data() -> LMData:
    # corpus sized so pretraining GENERALIZES: at vocab 256 the order-2
    # transition table has 65k contexts (~2 visits each at this budget
    # -> the model can only memorize: train loss 0.2 / held-out 10.4);
    # vocab 64 gives 4k contexts x ~30 visits -> real structure learning.
    global _LMDATA
    if _LMDATA is None:
        _LMDATA = LMData(num_train=2048, num_test=256, vocab=64)
    return _LMDATA


def _ckpt(name: str) -> str:
    return os.path.join(RESULTS_DIR, name + ".npz")


def policy(b) -> CompressionPolicy:
    return CompressionPolicy(num_stages=4, boundary=b)


# ---------------------------------------------------------------------------
# Shared baselines
# ---------------------------------------------------------------------------

def baseline_cnn(rerun: bool = False) -> dict:
    """Full-length uncompressed baseline (row 1 of every CNN table)."""
    def compute(_):
        r = run_cnn_experiment(NO_POLICY, epochs=EPOCHS, data=cnn_data())
        ckpt.save(_ckpt("cnn_baseline"), r.params)
        return {"acc_off": r.acc_off, "acc_on": r.acc_on,
                "curve": r.train_curve}
    return run_cached("baseline_cnn", ["no-compression"], compute, rerun)[0]


def warm_params(rerun: bool = False):
    """Uncompressed weights after WARM_EPOCHS (the paper's warmup rows)."""
    def compute(_):
        r = run_cnn_experiment(NO_POLICY, epochs=WARM_EPOCHS,
                               data=cnn_data())
        ckpt.save(_ckpt("cnn_warm"), r.params)
        return {"acc_on": r.acc_on}
    run_cached("baseline_warm", ["warm"], compute, rerun)
    import jax
    from repro.models import cnn
    like = jax.eval_shape(
        lambda: cnn.init_params(jax.random.PRNGKey(0), width=16))
    params, _ = ckpt.restore(_ckpt("cnn_warm"), like)
    return params


def _cnn_row(pol: CompressionPolicy, warm: bool = False,
             rerun: bool = False, lr: Optional[float] = None,
             epochs: Optional[int] = None):
    def compute(name):
        from repro.optim.optimizers import OptimizerConfig
        wp = warm_params(rerun) if warm else None
        eps = epochs or EPOCHS
        opt = None
        if lr is not None:
            steps = eps * (cnn_data().num_train // 100)
            opt = OptimizerConfig(kind="sgd", lr=lr, momentum=0.9,
                                  weight_decay=5e-4, schedule="cosine",
                                  t_max=steps)
        r = run_cnn_experiment(pol, epochs=eps, data=cnn_data(),
                               warmup_params=wp, opt=opt)
        return {"acc_off": r.acc_off, "acc_on": r.acc_on,
                "curve": r.train_curve}
    return compute


# ---------------------------------------------------------------------------
# Table 1: quantization fw[A]-bw[B]
# ---------------------------------------------------------------------------

T1_MODES = {                       # paper Table 1
    "fw4-bw8": (4, 8), "fw4-bw6": (4, 6), "fw4-bw4": (4, 4),
    "fw4-bw2": (4, 2), "fw2-bw8": (2, 8), "fw2-bw6": (2, 6),
    "fw2-bw4": (2, 4),
}


def table1(rerun: bool = False):
    rows = [dict(baseline_cnn(rerun), name="no-compression")]
    def compute(name):
        a, b = T1_MODES[name]
        return _cnn_row(policy(quant_policy(a, b)))(name)
    rows += run_cached("table1_quant", list(T1_MODES), compute, rerun)
    return rows


# ---------------------------------------------------------------------------
# Table 2: TopK sweep
# ---------------------------------------------------------------------------

T2_KS = {"top50": 0.50, "top30": 0.30, "top20": 0.20, "top10": 0.10,
         "top5": 0.05, "top2": 0.02}


def table2(rerun: bool = False):
    rows = [dict(baseline_cnn(rerun), name="no-compression")]
    def compute(name):
        return _cnn_row(policy(topk_policy(T2_KS[name])))(name)
    rows += run_cached("table2_topk", list(T2_KS), compute, rerun)
    return rows


# ---------------------------------------------------------------------------
# Table 3: error feedback (EF / EF-mixed / EF21), TopK compressors
# ---------------------------------------------------------------------------

T3_MODES = {
    "ef-top10-warm":      (ef_policy(0.10, "ef"), True),
    "efmixed-top10-warm": (ef_policy(0.10, "efmixed"), True),
    "ef21-top5":          (ef_policy(0.05, "ef21"), False),
    "ef21-top10":         (ef_policy(0.10, "ef21"), False),
    "ef21-top10-warm":    (ef_policy(0.10, "ef21"), True),
}

# EF-family feedback learns through a mostly-stale message in the early
# phase (the buffer is another batch's activations), so its transient is
# several-fold longer than plain TopK's — at the tables-1/2 budget
# (10 epochs, lr 0.02 cosine) every EF row sits at chance.  The EF table
# therefore runs the PAPER's lr (0.01, Sec 3.1) with a doubled epoch
# budget, plus a plain-top10 control at identical settings so F4 compares
# like-for-like.  Diagnosis chain recorded in EXPERIMENTS.md §Repro notes.
T3_LR = 0.01
T3_EPOCHS = 2 * EPOCHS


def table3(rerun: bool = False):
    rows = [dict(baseline_cnn(rerun), name="no-compression")]
    def compute(name):
        if name == "top10-lr001":            # plain-TopK control at same lr
            return _cnn_row(policy(topk_policy(0.10)), lr=T3_LR,
                            epochs=T3_EPOCHS)(name)
        bp, warm = T3_MODES[name]
        return _cnn_row(policy(bp), warm=warm, lr=T3_LR,
                        epochs=T3_EPOCHS)(name)
    rows += run_cached("table3_ef", ["top10-lr001"] + list(T3_MODES),
                       compute, rerun)
    return rows


# ---------------------------------------------------------------------------
# Table 4: AQ-SGD (per-example buffer, activations only) + TopK
# ---------------------------------------------------------------------------

T4_KS = {"aqsgd-top50-warm": 0.50, "aqsgd-top30-warm": 0.30,
         "aqsgd-top20-warm": 0.20, "aqsgd-top10-warm": 0.10}


def table4(rerun: bool = False):
    rows = [dict(baseline_cnn(rerun), name="no-compression")]
    def compute(name):
        return _cnn_row(policy(aqsgd_policy(T4_KS[name])), warm=True)(name)
    rows += run_cached("table4_aqsgd", list(T4_KS), compute, rerun)
    return rows


# ---------------------------------------------------------------------------
# Table 5: LM fine-tuning, TopK with index reuse vs separate masks
# ---------------------------------------------------------------------------

LM_CFG = ModelConfig(
    arch_id="tiny-gpt2ish", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=256,
    pos_embed="rope", norm="layernorm", mlp="gelu", tie_embeddings=True,
    max_seq=64, source="scaled-down GPT-2 (paper Sec. 3.2 protocol)")

T5_MODES = {
    "lm-top50": (0.50, True), "lm-top30": (0.30, True),
    "lm-top20": (0.20, True), "lm-top10": (0.10, True),
    "lm-top10-separate": (0.10, False),
}


def _lm_pretrained(rerun: bool = False):
    def compute(_):
        # long enough to be genuinely structured, short enough not to
        # memorize (the paper fine-tunes the fully pretrained GPT-2)
        params, loss = pretrain_lm(LM_CFG, steps=1000, data=lm_data())
        ckpt.save(_ckpt("lm_pretrained"), params)
        return {"train_loss": loss}
    run_cached("baseline_lm", ["pretrain"], compute, rerun)
    import jax
    from repro.models import transformer
    like = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), LM_CFG))
    params, _ = ckpt.restore(_ckpt("lm_pretrained"), like)
    return params


def table5(rerun: bool = False):
    import math
    from benchmarks import common
    if common.CACHED_ONLY and not os.path.exists(_ckpt("lm_pretrained")):
        return run_cached("table5_lm", [], lambda n: {}, False)
    pre = _lm_pretrained(rerun)

    from repro.optim.optimizers import OptimizerConfig
    ft_opt = OptimizerConfig(kind="adamw", lr=3e-4, weight_decay=0.01,
                             schedule="constant", grad_clip=1.0)

    def compute(name):
        if name == "no-compression":
            pol = NO_POLICY
        else:
            k, reuse = T5_MODES[name]
            pol = policy(topk_policy(k, reuse_indices=reuse))
        r = run_lm_experiment(LM_CFG, pol, pretrained_params=pre,
                              epochs=2, data=lm_data(), opt=ft_opt)
        return {"eval_loss": r.loss_on, "eval_loss_off": r.loss_off,
                "ppl": math.exp(min(r.loss_on, 20.0)),
                "ppl_off": math.exp(min(r.loss_off, 20.0))}
    names = ["no-compression"] + list(T5_MODES)
    return run_cached("table5_lm", names, compute, rerun)


# ---------------------------------------------------------------------------
# Findings validation (DESIGN.md F1-F6 vs paper's claims)
# ---------------------------------------------------------------------------

def validate(t1, t2, t3, t4, t5):
    by = lambda rows: {r["name"]: r for r in rows}
    b1, b2, b3, b4, b5 = by(t1), by(t2), by(t3), by(t4), by(t5)
    g = lambda d, n, k: d.get(n, {}).get(k, float("nan"))
    claims = [
        ("F1 gradients more quant-sensitive: fw2-bw8 (on) beats fw4-bw2 (on)",
         g(b1, "fw2-bw8", "acc_on") > g(b1, "fw4-bw2", "acc_on") + 2),
        ("F1b fw4-bw8 ~ baseline (within 5pp, compressed eval)",
         abs(g(b1, "fw4-bw8", "acc_on") - g(b1, "no-compression", "acc_on")) < 5),
        ("F2 top10 (on) within 6pp of baseline; top2 (on) clearly worse",
         (g(b2, "top10", "acc_on") > g(b2, "no-compression", "acc_on") - 6)
         and (g(b2, "top2", "acc_on") < g(b2, "top10", "acc_on"))),
        ("F3 strong TopK: compressed eval beats uncompressed eval by >5pp "
         "(top5)", g(b2, "top5", "acc_on") > g(b2, "top5", "acc_off") + 5),
        ("F3b quant fw2: compressed eval beats uncompressed eval (fw2-bw8)",
         g(b1, "fw2-bw8", "acc_on") > g(b1, "fw2-bw8", "acc_off")),
        ("F4 EF21+top10 does not beat plain top10 (on) by >2pp (same lr)",
         g(b3, "ef21-top10", "acc_on") < g(b3, "top10-lr001", "acc_on") + 2),
        ("F4b EF21 model serves UNCOMPRESSED with no quality drop "
         "(off >= on - 1pp)",
         g(b3, "ef21-top10", "acc_off")
         >= g(b3, "ef21-top10", "acc_on") - 1.0),
        ("F5 AQ-SGD+top10 does not beat plain top10 (on)",
         g(b4, "aqsgd-top10-warm", "acc_on") < g(b2, "top10", "acc_on") + 2),
        ("F5b AQ-SGD degrades as K shrinks (top50 >= top10)",
         g(b4, "aqsgd-top50-warm", "acc_on")
         >= g(b4, "aqsgd-top10-warm", "acc_on") - 1),
        ("F6 LM: top10 separate masks much worse than index reuse",
         g(b5, "lm-top10-separate", "eval_loss") > g(b5, "lm-top10", "eval_loss") + 0.3),
        ("F6b LM: compression level ladder monotone-ish (top50 <= top10 loss)",
         g(b5, "lm-top50", "eval_loss") <= g(b5, "lm-top10", "eval_loss") + 0.05),
    ]
    return claims
