"""Roofline analysis from dry-run artifacts (DESIGN.md / EXPERIMENTS.md
§Roofline).

Reads the JSON written by ``repro.launch.dryrun --json`` and derives, per
(arch x shape x policy):

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)

cost_analysis() reports PER-DEVICE program flops/bytes for an SPMD module,
so chips only divides the collective sum (whose bytes we parse from the
optimized HLO of one device program and which are already per-device).
The dominant term is the bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

PEAK_FLOPS = 197e12     # bf16 per chip (TPU v5e)
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


def terms(report: dict) -> dict:
    """Three roofline terms (seconds) + bottleneck + useful-FLOPs ratio.

    cost_analysis on the compiled scanned program counts each lax.scan body
    ONCE, so its flops/bytes undercount by roughly the layer-group count.
    The dry-run therefore also records ``flops_unrolled_global`` — exact
    global flops from an unrolled lowering.  We derive the undercount
    factor F from the flops and apply it to the compiled bytes and
    collective sums (layers are homogeneous, so flop- and byte-undercount
    track each other; the optimizer's outside-the-loop traffic makes this
    a slight over-correction — noted in EXPERIMENTS.md caveats).
    """
    if report.get("skipped") or report.get("error"):
        return report
    devices = report["devices"]
    flops_c = report["flops"]                     # per-device, body-once
    if report.get("flops_unrolled_global") and \
            report.get("flops_scanned_global"):
        # scan undercount factor measured on the GLOBAL (pre-partition)
        # lowering, applied to the compiled per-device numbers — keeps the
        # partitioner's actual work split (incl. replicated decode work)
        f_corr = max(1.0, report["flops_unrolled_global"]
                     / max(report["flops_scanned_global"], 1.0))
    else:
        f_corr = 1.0
    flops_dev = flops_c * f_corr
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = report["bytes"] * f_corr / HBM_BW
    t_coll = report["collective_bytes"] * f_corr / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    useful = report["model_flops"] / report["flops_unrolled_global"] \
        if report.get("flops_unrolled_global") else (
            report["model_flops"] / (flops_dev * devices) if flops_dev
            else 0.0)
    out = dict(report)
    out.update(t_compute_ms=1e3 * t_compute, t_memory_ms=1e3 * t_memory,
               t_collective_ms=1e3 * t_coll, bottleneck=dominant,
               scan_corr_factor=round(f_corr, 1),
               useful_flops_ratio=round(useful, 3),
               step_lower_bound_ms=1e3 * max(t_compute, t_memory, t_coll))
    return out


def fmt(rows: List[dict]) -> str:
    hdr = ("| arch | shape | policy | mesh | compute ms | memory ms | "
           "collective ms | bottleneck | useful-FLOPs |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                         f"| skipped: {r.get('reason','')} | - |")
            continue
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                         "| ERROR | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('policy','none')} "
            f"| {r['mesh']} | {r['t_compute_ms']:.1f} | {r['t_memory_ms']:.1f} "
            f"| {r['t_collective_ms']:.1f} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def _gb(x):
    return f"{x / 1e9:.2f}"


def fmt_dryrun(rows: List[dict]) -> str:
    """§Dry-run table: per-device memory, flops, collective schedule."""
    hdr = ("| arch | shape | mesh | compile s | peak GB/dev | HLO GFLOP/dev "
           "| collective GB/dev (by op) | model TFLOP (global) |")
    lines = [hdr, "|" + "---|" * 8]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - "
                         f"| skipped ({r.get('reason','')[:40]}) | - |")
            continue
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - "
                         f"| ERROR {r['error'][:60]} | - |")
            continue
        coll = ", ".join(f"{k.replace('collective-','c-')} {_gb(v)}"
                         for k, v in sorted(r["collectives"].items(),
                                            key=lambda kv: -kv[1]))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('compile_s','-')} | {r['peak_bytes']/2**30:.2f} "
            f"| {r['flops']/1e9:.0f} | {coll} "
            f"| {r['model_flops']/1e12:.1f} |")
    return "\n".join(lines)


def fmt_codecs(bench: dict) -> str:
    """§Codec-roofline table: achieved bytes/s of each wire-codec path
    (from benchmarks/codec_bench.py) against the HBM peak — pack/unpack
    are memory-streaming ops, so HBM_BW is the relevant roof.  Numbers
    measured on a CPU runner reflect interpret-mode kernels (a correctness
    vehicle); on TPU the Pallas column is the deployment path and the
    in-bench gate asserts pallas >= jnp."""
    hdr = ("| path | op | dense MB | wire ratio | jnp GB/s | pallas GB/s "
           "| pallas/jnp | % of peak (pallas) | parity |")
    lines = [f"(measured on backend: {bench.get('backend', '?')}, "
             f"peak HBM {HBM_BW / 1e9:.0f} GB/s)", "", hdr,
             "|" + "---|" * 9]
    rows = (bench.get("codecs", []) + bench.get("framing", [])
            + bench.get("dp_decode_sum", []))
    if not rows:
        raise SystemExit(
            "codec_bench.json has no rows under any of the sections the "
            "codec table reads ('codecs', 'framing', 'dp_decode_sum'); "
            f"sections present: {sorted(bench) or '(none)'} — regenerate "
            "with: PYTHONPATH=src python benchmarks/codec_bench.py")
    for r in rows:
        absent = ({"name", "jnp_gbps", "pallas_gbps", "pallas_over_jnp"}
                  - set(r))
        if absent:
            raise SystemExit(
                f"codec_bench row {r.get('name', '?')!r} lacks keys "
                f"{sorted(absent)} — a stale results file; regenerate "
                "with: PYTHONPATH=src python benchmarks/codec_bench.py")
        dense = r.get("dense_bytes") or r.get("buffer_bytes") or 0
        wire = (r.get("wire_bytes_pallas") or r.get("buffer_bytes")
                or (r.get("hop_buffer_bytes", 0) * r.get("dp", 0)) or dense)
        ratio = dense / wire if wire else 0.0
        peak_pct = 100.0 * r["pallas_gbps"] * 1e9 / HBM_BW
        parity = r.get("parity", r.get("byte_identical"))
        lines.append(
            f"| {r['name']} | {r.get('op', 'decode+sum')} "
            f"| {dense / 1e6:.2f} | {ratio:.1f}x "
            f"| {r['jnp_gbps']:.2f} | {r['pallas_gbps']:.2f} "
            f"| {r['pallas_over_jnp']:.2f} | {peak_pct:.3f}% "
            f"| {'ok' if parity else 'BROKEN'} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("jsons", nargs="*", help="dryrun --json outputs")
    ap.add_argument("--md", default=None, help="write markdown table here")
    ap.add_argument("--dryrun-table", action="store_true",
                    help="emit the §Dry-run table instead of §Roofline")
    ap.add_argument("--codec-table", action="store_true",
                    help="emit the §Codec-roofline table from the "
                         "committed results/codec_bench.json (or a path "
                         "given as the positional arg): achieved vs peak "
                         "bytes/s per wire-codec pack/unpack path")
    args = ap.parse_args(argv)
    if args.codec_table:
        import os
        path = args.jsons[0] if args.jsons else os.path.join(
            os.path.dirname(__file__), "results", "codec_bench.json")
        try:
            with open(path) as f:
                bench = json.load(f)
        except FileNotFoundError:
            ap.error(f"{path}: no codec-bench results file — generate it "
                     "with: PYTHONPATH=src python benchmarks/codec_bench.py"
                     " (or pass a results JSON as the positional arg)")
        except json.JSONDecodeError as e:
            ap.error(f"{path}: not valid JSON ({e}) — regenerate with: "
                     "PYTHONPATH=src python benchmarks/codec_bench.py")
        table = fmt_codecs(bench)
        print(table)
        if args.md:
            with open(args.md, "w") as f:
                f.write(table + "\n")
        return 0
    if not args.jsons:
        ap.error("provide dryrun --json outputs (or use --codec-table)")
    rows = []
    for p in args.jsons:
        with open(p) as f:
            raw = json.load(f)
        rows += raw if args.dryrun_table else [terms(r) for r in raw]
    table = fmt_dryrun(rows) if args.dryrun_table else fmt(rows)
    print(table)
    if args.md:
        with open(args.md, "w") as f:
            f.write(table + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
