"""Per-codec pack/unpack throughput: fused Pallas wire kernels vs jnp.

For every registered wire codec this measures achieved bytes/s (dense-side
bytes moved per second) of ``pack`` and ``unpack`` on both backends, plus
the payload-framing kernel (fuse/unfuse) and the fused DP decode+sum — the
whole codec hot path that PR 6 moved into Pallas.  Each row also carries a
PARITY verdict re-asserting the wire contract inline: q4 bytes bit-exact,
TopK sets equal (dense roundtrip identical), framing byte-identical, DP
decode+sum within the documented 1-ulp FMA bound.

Perf gate: on a TPU backend the Pallas path must achieve >= the jnp path's
bytes/s (asserted in-code, the ISSUE 6 acceptance).  On CPU runners the
kernels execute in INTERPRET mode — a correctness vehicle, not a perf
path (the 31-step TopK bisection in particular is slower than one XLA
sort when interpreted) — so there the ratio is recorded and banded by
``--check`` rather than asserted, and the parity booleans plus wire bytes
are gated exactly.  See README "Kernels".

Run:
  PYTHONPATH=src python -m benchmarks.codec_bench            # write json
  PYTHONPATH=src python -m benchmarks.codec_bench --check    # CI gate
"""
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.core.compressors as C
from repro.transport import codecs

SHAPE = (64, 4096)        # a boundary-sized (microbatch, features) tensor
K_FRAC = 0.10
ITERS = 30


def _timeit(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(ITERS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / ITERS


def _on_backend(backend, fn, *args):
    prev = C.KERNEL_BACKEND
    try:
        C.KERNEL_BACKEND = backend
        return fn(*args)
    finally:
        C.KERNEL_BACKEND = prev


def _gbps(nbytes, seconds):
    return round(nbytes / seconds / 1e9, 3)


def _codec_parity(name, x, pj, pp):
    """True iff the Pallas payload honors the codec's wire contract vs
    jnp: q4/none bit-exact bytes, TopK set-equal (dense roundtrip
    identical), q8 within its per-tile quantization error bound."""
    if name == "topk":
        if pj["idx"].shape != pp["idx"].shape or \
                pj["idx"].dtype != pp["idx"].dtype:
            return False
        for r in range(x.shape[0]):
            if (set(np.asarray(pj["idx"][r]).tolist())
                    != set(np.asarray(pp["idx"][r]).tolist())):
                return False
        dj = _on_backend("jnp", codecs.unpack_payload, pj, x.shape,
                         jnp.float32)
        dp = _on_backend("jnp", codecs.unpack_payload, pp, x.shape,
                         jnp.float32)
        return bool(np.array_equal(np.asarray(dj), np.asarray(dp)))
    if name == "q8" and set(pp) != set(pj):
        # per-tile Pallas format: same codes bytes count, finer scales —
        # check the reconstruction against the 8-bit error bound instead
        y = _on_backend("pallas", codecs.unpack_payload, pp, x.shape,
                        jnp.float32)
        step = float(jnp.max(x) - jnp.min(x)) / 255
        return bool(float(jnp.abs(y - x).max()) <= step + 1e-5)
    for k in pj:
        if not np.array_equal(np.asarray(pj[k]), np.asarray(pp[k])):
            if k in ("codes4", "raw", "codes"):
                return False
            a, b = np.asarray(pj[k], np.float32), np.asarray(pp[k],
                                                             np.float32)
            if not np.allclose(a, b, rtol=0,
                               atol=1.2e-7 * max(np.abs(a).max(), 1.0)):
                return False
    return True


def measure_codecs(shape=SHAPE, k_frac=K_FRAC):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    dense_bytes = x.size * 4
    tpu = jax.default_backend() == "tpu"
    rows = []
    for name in codecs.registered_codecs():
        packs, payloads = {}, {}
        for backend in ("jnp", "pallas"):
            fn = jax.jit(lambda a, nm=name, be=backend: _on_backend(
                be, codecs.get_codec(nm).pack, a, k_frac))
            packs[backend] = _timeit(fn, x)
            payloads[backend] = _on_backend(
                backend, codecs.get_codec(name).pack, x, k_frac)
        unpacks = {}
        for backend in ("jnp", "pallas"):
            p = payloads[backend]
            fn = jax.jit(lambda pl, nm=name, be=backend: _on_backend(
                be, codecs.unpack_payload, pl, shape, jnp.float32))
            unpacks[backend] = _timeit(fn, p)
        parity = _codec_parity(name, x, payloads["jnp"],
                               payloads["pallas"])
        for op, times in (("pack", packs), ("unpack", unpacks)):
            ratio = round(times["jnp"] / times["pallas"], 3)
            if tpu:
                # the acceptance gate: compiled kernels must win on-target
                assert ratio >= 1.0, (name, op, times)
            rows.append({
                "name": f"{name}:{op}", "codec": name, "op": op,
                "shape": list(shape), "k_frac": k_frac,
                "dense_bytes": dense_bytes,
                "wire_bytes_jnp": codecs.wire_bytes(payloads["jnp"]),
                "wire_bytes_pallas": codecs.wire_bytes(payloads["pallas"]),
                "jnp_gbps": _gbps(dense_bytes, times["jnp"]),
                "pallas_gbps": _gbps(dense_bytes, times["pallas"]),
                "pallas_over_jnp": ratio,
                "parity": parity,
                "perf_gate": "enforced" if tpu else "tpu-only",
            })
    return rows


def measure_framing(shape=SHAPE):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    payload = _on_backend("jnp", codecs.get_codec("q8").pack, x)
    nbytes = codecs.wire_bytes(payload)
    tpu = jax.default_backend() == "tpu"
    fuse_t, bufs = {}, {}
    for backend in ("jnp", "pallas"):
        fn = jax.jit(lambda p, be=backend: _on_backend(
            be, codecs.fuse_payload, p))
        fuse_t[backend] = _timeit(fn, payload)
        bufs[backend] = _on_backend(backend, codecs.fuse_payload, payload)
    identical = bool(np.array_equal(np.asarray(bufs["jnp"]),
                                    np.asarray(bufs["pallas"])))
    unfuse_t = {}
    for backend in ("jnp", "pallas"):
        fn = jax.jit(lambda b, be=backend: _on_backend(
            be, codecs.unfuse_payload, b, payload))
        unfuse_t[backend] = _timeit(fn, bufs[backend])
    rows = []
    for op, times in (("fuse", fuse_t), ("unfuse", unfuse_t)):
        ratio = round(times["jnp"] / times["pallas"], 3)
        if tpu:
            assert ratio >= 1.0, (op, times)
        rows.append({
            "name": f"framing:{op}", "op": op,
            "payload_leaves": len(jax.tree.leaves(payload)),
            "buffer_bytes": nbytes,
            "jnp_gbps": _gbps(nbytes, times["jnp"]),
            "pallas_gbps": _gbps(nbytes, times["pallas"]),
            "pallas_over_jnp": ratio,
            "byte_identical": identical,
            "perf_gate": "enforced" if tpu else "tpu-only",
        })
    return rows


def measure_dp_decode(dp=4, leaf_shapes=((128, 129), (2048,), (33,))):
    """Fused decode+sum kernel vs the unfused unpack->add reference loop,
    on manually stacked hop buffers (no mesh needed)."""
    from repro.kernels.dp_reduce import (build_decode_plans, decode_fits,
                                         decode_sum_fused)
    from repro.transport.collectives import pack_grad_leaf, unpack_grad_leaf
    tpu = jax.default_backend() == "tpu"
    rows = []
    for codec_name in ("q8", "q4"):
        codec = codecs.get_codec(codec_name)
        per_src = []
        for s in range(dp):
            leaves = [jax.random.normal(jax.random.PRNGKey(7 * s + i), sh)
                      for i, sh in enumerate(leaf_shapes)]
            per_src.append([pack_grad_leaf(codec, a) for a in leaves])
        slots = jnp.stack([_on_backend("jnp", codecs.fuse_payload, p)
                           for p in per_src])
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), per_src[0])
        plans = build_decode_plans(struct, list(leaf_shapes))
        assert plans is not None and decode_fits(plans, dp), codec_name

        def reference(sl):
            acc = [None] * len(leaf_shapes)
            for s in range(dp):
                pls = codecs.unfuse_payload(sl[s], struct)
                for i, sh in enumerate(leaf_shapes):
                    m = unpack_grad_leaf(codec, pls[i], sh)
                    acc[i] = m if acc[i] is None else acc[i] + m
            return acc

        def ref_fn(sl):
            return _on_backend("jnp", reference, sl)

        def fused_fn(sl):
            return decode_sum_fused(sl, plans, dp)

        t_ref = _timeit(jax.jit(ref_fn), slots)
        t_fused = _timeit(jax.jit(fused_fn), slots)
        want = ref_fn(slots)
        got = fused_fn(slots)
        ok = all(
            np.allclose(np.asarray(g).reshape(-1), np.asarray(w).reshape(-1),
                        rtol=0,
                        atol=dp * 1.2e-7 * max(float(np.abs(np.asarray(w))
                                                     .max()), 1.0))
            for g, w in zip(got, want))
        dense_bytes = sum(int(np.prod(sh)) for sh in leaf_shapes) * 4 * dp
        ratio = round(t_ref / t_fused, 3)
        if tpu:
            assert ratio >= 1.0, (codec_name, t_ref, t_fused)
        rows.append({
            "name": f"dp_decode_sum:{codec_name}", "codec": codec_name,
            "dp": dp, "leaves": len(leaf_shapes),
            "hop_buffer_bytes": int(slots.shape[1]),
            "dense_bytes": dense_bytes,
            "jnp_gbps": _gbps(dense_bytes, t_ref),
            "pallas_gbps": _gbps(dense_bytes, t_fused),
            "pallas_over_jnp": ratio,
            "parity": bool(ok),
            "perf_gate": "enforced" if jax.default_backend() == "tpu"
                         else "tpu-only",
        })
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="regression gate: recompute and compare against "
                         "the committed results/codec_bench.json (parity "
                         "booleans and wire bytes exact, throughputs "
                         "banded); exit 1 on drift")
    args = ap.parse_args(argv)
    codec_rows = measure_codecs()
    framing_rows = measure_framing()
    dp_rows = measure_dp_decode()
    for r in codec_rows + framing_rows + dp_rows:
        print(json.dumps(r))
    bad = [r["name"] for r in codec_rows + framing_rows + dp_rows
           if not r.get("parity", r.get("byte_identical", True))]
    assert not bad, f"kernel/jnp parity broken: {bad}"
    fresh = {"backend": jax.default_backend(), "codecs": codec_rows,
             "framing": framing_rows, "dp_decode_sum": dp_rows}
    if args.check:
        from benchmarks.common import run_check
        # parity booleans, wire bytes and payload structure gate exactly.
        # Interpret-mode throughputs on shared CPU runners swing several x
        # run-to-run (tiny kernels, cache effects), so the gbps/ratio
        # numbers are recorded for information only — the >= jnp perf gate
        # is the in-code assertion above, enforced when the backend is TPU.
        return run_check(
            fresh, "codec_bench",
            ignore_keys=frozenset(
                {"jnp_gbps", "pallas_gbps", "pallas_over_jnp"}))
    results = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results, exist_ok=True)
    with open(os.path.join(results, "codec_bench.json"), "w") as f:
        json.dump(fresh, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
