"""Serving throughput: static vs continuous vs paged continuous.

Two workload tiers, each swept per codec variant (top-10% wire
compression vs the --no-compress ablation):

  * ``zipf``        — mixed stream, Zipf prompt lengths AND Zipf
    max-new-tokens.  Static batching pads every prompt to the batch max
    and decodes everyone to the group's largest max-new-tokens; the
    continuous engine evicts finished slots and refills the same tick.
  * ``shared_zipf`` — the production shape paged KV exists for: every
    request opens with the SAME system prompt (here 96 tokens) followed
    by a short Zipf tail, and decodes a short Zipf completion.  Prefill
    dominates, so the prefix cache (skip the shared pages) and chunked
    prefill (never stall decode behind a whole prompt) carry the win.

Asserted acceptance criteria:

  * zipf tier: continuous tokens/s >= 1.5x static;
  * shared tier: paged (prefix cache + chunked prefill) tokens/s >= 1.3x
    the PR-4 slab continuous engine, AND strictly lower p99 TTFT —
    asserted on the compressed (paper-config) rows; the no-compress
    ablation records its smaller speedup unasserted;
  * every continuous/paged output is BIT-IDENTICAL to the same request
    served alone through an identically configured engine;
  * speculative decoding emits exactly the paged engine's greedy stream;
  * the measured runs add ZERO jit compilations after warmup (slot
    eviction/refill, page eviction and prefix hits never recompile).

Static engines have no per-request TTFT (a whole group prefills and
returns together), so the static rows report throughput only.

Writes benchmarks/results/serve_bench.json.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--requests N]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, topk_policy
from repro.launch.serve import zipf_lengths
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "serve_bench.json")


def build_workload(cfg, n, max_prompt, max_new, seed=0, a=1.2):
    """Zipf-mixed requests: prompts in [2, max_prompt], decode lengths in
    [8, max_new].  a=1.2 gives the heavy tail that makes static batching
    hurt — most requests decode ~8-16 tokens, a few run to max_new, and
    every static group decodes to ITS max."""
    rng = np.random.RandomState(seed)
    plens = zipf_lengths(rng, n, 2, max_prompt, a)
    news = zipf_lengths(rng, n, 8, max_new, a)
    prompts = [rng.randint(1, min(cfg.vocab_size, 1024),
                           l).astype(np.int32) for l in plens]
    return prompts, news


def build_shared_workload(cfg, n, prefix_len, max_tail, max_new, seed=0,
                          a=1.2):
    """Shared-system-prompt stream: every request is the same
    ``prefix_len``-token prefix plus a short Zipf tail, decoding a short
    Zipf completion.  Prompt ingestion dominates the run, which is the
    regime the prefix cache converts into page reuse."""
    rng = np.random.RandomState(seed)
    vocab = min(cfg.vocab_size, 1024)
    shared = rng.randint(1, vocab, prefix_len).astype(np.int32)
    tails = zipf_lengths(rng, n, 1, max_tail, a)
    news = zipf_lengths(rng, n, 4, max_new, a)
    prompts = [np.concatenate([shared, rng.randint(1, vocab, t)
                               .astype(np.int32)]) for t in tails]
    return prompts, news


def run_static(params, cfg, policy, compress, prompts, news, slots,
               max_seq):
    """FIFO groups of ``slots`` requests; each group pads to its own max
    prompt length and decodes to its own max new-tokens (the engine's
    semantics — finished requests still occupy their slot)."""
    eng = ServeEngine(params, cfg, policy, compress=compress,
                      max_batch=slots, max_seq=max_seq)
    groups = [list(range(i, min(i + slots, len(prompts))))
              for i in range(0, len(prompts), slots)]
    # warm every group's (batch, padded-prompt) shape so compile time
    # stays out of the measurement
    for g in groups:
        eng.generate([Request(prompts[i].copy(), 2) for i in g])
    outs = {}
    t0 = time.time()
    for g in groups:
        reqs = eng.generate([Request(prompts[i].copy(), int(news[i]))
                             for i in g])
        for i, r in zip(g, reqs):
            outs[i] = r.out
    wall = time.time() - t0
    useful = int(sum(news))
    return {"wall_s": round(wall, 3),
            "tok_per_s": round(useful / wall, 1),
            "useful_tokens": useful,
            # slots decode until the group max: the padding waste the
            # scheduler exists to eliminate
            "decoded_slot_tokens": int(sum(len(g) * max(news[i] for i in g)
                                           for g in groups))}, outs


def run_continuous(params, cfg, policy, compress, prompts, news, slots,
                   max_seq, max_prompt, **engine_kw):
    """One timed streaming run; returns (metrics, outputs, engine).
    ``engine_kw`` selects the variant: {} is the PR-4 slab engine,
    prefix_cache/prefill_chunk the paged one, draft_params speculative."""
    eng = ContinuousEngine(params, cfg, policy, compress=compress,
                           num_slots=slots, max_seq=max_seq,
                           max_prompt=max_prompt, **engine_kw)
    eng.warmup()
    compiles0 = eng.compile_stats()
    t0 = time.time()
    for i, (p, n) in enumerate(zip(prompts, news)):
        eng.submit(p, max_new_tokens=int(n), seed=i)
    done = eng.drain()
    wall = time.time() - t0
    assert eng.compile_stats() == compiles0, \
        f"recompilation during the serving run: {compiles0} -> " \
        f"{eng.compile_stats()}"
    outs = {r.req_id: r.out for r in done}
    useful = int(sum(news))
    ttfts = [r.ttft_s for r in done]
    stats = eng.stats()
    metrics = {"wall_s": round(wall, 3),
               "tok_per_s": round(useful / wall, 1),
               "useful_tokens": useful,
               # TTFT SLO percentiles over the full request stream
               # (includes queueing — the latency a client actually sees)
               "p50_ttft_s": round(float(np.percentile(ttfts, 50)), 4),
               "p99_ttft_s": round(float(np.percentile(ttfts, 99)), 4),
               "mean_ttft_s": stats["mean_ttft_s"],
               "slot_utilization": stats["slot_utilization"],
               "boundary_bytes_per_tok": stats["boundary_bytes_per_tok"],
               **compiles0}
    for k in ("prefix_hits", "prefix_hit_tokens", "cow_copies",
              "acceptance_rate"):
        if k in stats:
            metrics[k] = stats[k]
    return metrics, outs, eng


def solo_reference(params, cfg, policy, compress, prompts, news, slots,
                   max_seq, max_prompt, **engine_kw):
    """Each request alone on the SAME engine shape (num_slots unchanged —
    bit-identity is guaranteed across batch composition, i.e. per-row
    numerics; a different batch SIZE is a different XLA program)."""
    eng = ContinuousEngine(params, cfg, policy, compress=compress,
                           num_slots=slots, max_seq=max_seq,
                           max_prompt=max_prompt, **engine_kw)
    outs = {}
    for i, (p, n) in enumerate(zip(prompts, news)):
        eng.submit(p, max_new_tokens=int(n), seed=i)
        (r,) = eng.drain()
        outs[i] = r.out
    return outs


def _assert_identical(solo, outs, what):
    bad = [i for i in solo if not np.array_equal(solo[i], outs[i])]
    assert not bad, f"{what}: output != reference for requests {bad}"


def zipf_tier(params, cfg, policy, compress, name, args):
    prompts, news = build_workload(cfg, args.requests, args.max_prompt,
                                   args.max_new, args.seed)
    st, _ = run_static(params, cfg, policy, compress, prompts, news,
                       args.slots, args.max_seq)
    ct, ct_outs, _ = run_continuous(params, cfg, policy, compress,
                                    prompts, news, args.slots,
                                    args.max_seq, args.max_prompt)
    solo = solo_reference(params, cfg, policy, compress, prompts, news,
                          args.slots, args.max_seq, args.max_prompt)
    _assert_identical(solo, ct_outs, f"zipf/{name} continuous")
    speedup = ct["tok_per_s"] / st["tok_per_s"]
    row = {"name": name, "compress": compress,
           "requests": args.requests, "slots": args.slots,
           "static": st, "continuous": ct,
           "speedup": round(speedup, 2),
           "bit_identical_to_solo": True}
    assert speedup >= 1.5, \
        f"zipf/{name}: continuous {ct['tok_per_s']} tok/s is only " \
        f"{speedup:.2f}x static {st['tok_per_s']} (need >= 1.5x)"
    return row


def shared_tier(params, cfg, policy, compress, name, args):
    """Legacy slab continuous vs paged (prefix cache + chunked prefill)
    on the shared-prefix workload, plus a speculative-decoding row."""
    prompts, news = build_shared_workload(cfg, args.requests,
                                          args.shared_prefix,
                                          args.max_tail, args.shared_new,
                                          args.seed)
    max_prompt = args.shared_prefix + args.max_tail
    legacy, _, _ = run_continuous(params, cfg, policy, compress, prompts,
                                  news, args.slots, args.max_seq,
                                  max_prompt)
    paged_kw = dict(prefix_cache=True, prefill_chunk=args.prefill_chunk,
                    page_size=args.page_size)
    paged, paged_outs, _ = run_continuous(params, cfg, policy, compress,
                                          prompts, news, args.slots,
                                          args.max_seq, max_prompt,
                                          **paged_kw)
    solo = solo_reference(params, cfg, policy, compress, prompts, news,
                          args.slots, args.max_seq, max_prompt,
                          **paged_kw)
    _assert_identical(solo, paged_outs, f"shared/{name} paged")
    # self-draft speculative run (draft == target params): informational
    # throughput — the point gated here is exact greedy equivalence
    spec, spec_outs, _ = run_continuous(
        params, cfg, policy, compress, prompts, news, args.slots,
        args.max_seq, max_prompt, prefix_cache=True,
        prefill_chunk=args.prefill_chunk, page_size=args.page_size,
        draft_params=params, draft_cfg=cfg, draft_policy=policy,
        spec_k=args.spec_k)
    _assert_identical(paged_outs, spec_outs, f"shared/{name} speculative")
    speedup = paged["tok_per_s"] / legacy["tok_per_s"]
    row = {"name": name, "compress": compress,
           "requests": args.requests, "slots": args.slots,
           "legacy": legacy, "paged": paged, "speculative": spec,
           "paged_speedup": round(speedup, 2),
           "bit_identical_to_solo": True,
           "spec_matches_greedy": True}
    if compress:
        # the speedup claim is gated on the paper's serving config (wire
        # codecs on): skipping a prefix-hit page saves its codec work too.
        # The no-compress ablation prefills with plain matmuls the smoke
        # model amortizes well, so its (recorded) speedup is smaller —
        # that row exists for codec-cost accounting (F3), not this claim.
        row["paged_p99_ttft_lower"] = (paged["p99_ttft_s"]
                                       < legacy["p99_ttft_s"])
        assert speedup >= 1.3, \
            f"shared/{name}: paged {paged['tok_per_s']} tok/s is only " \
            f"{speedup:.2f}x legacy {legacy['tok_per_s']} (need >= 1.3x)"
        assert row["paged_p99_ttft_lower"], \
            f"shared/{name}: paged p99 TTFT {paged['p99_ttft_s']}s not " \
            f"below legacy {legacy['p99_ttft_s']}s"
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--max-seq", type=int, default=224)
    ap.add_argument("--shared-prefix", type=int, default=96,
                    help="shared tier: system-prompt length")
    ap.add_argument("--max-tail", type=int, default=8,
                    help="shared tier: max Zipf tail after the prefix")
    ap.add_argument("--shared-new", type=int, default=12,
                    help="shared tier: max Zipf new-tokens")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="regression gate: compare against the committed "
                         "results/serve_bench.json — token counts, wire "
                         "bytes/token, compile counters and bit-identity "
                         "flags exact, throughput within a tolerance band; "
                         "exit 1 on drift")
    args = ap.parse_args(argv)

    cfg = get(args.arch, smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    policy = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))
    zipf_rows, shared_rows = [], []
    for name, compress in (("top10", True), ("no-compress", False)):
        row = zipf_tier(params, cfg, policy, compress, name, args)
        zipf_rows.append(row)
        print(json.dumps(row), flush=True)
        row = shared_tier(params, cfg, policy, compress, name, args)
        shared_rows.append(row)
        print(json.dumps(row), flush=True)
    fresh = {"arch": cfg.arch_id,
             "workload": {"requests": args.requests,
                          "slots": args.slots,
                          "zipf_max_prompt": args.max_prompt,
                          "zipf_max_new": args.max_new,
                          "shared_prefix": args.shared_prefix,
                          "shared_max_tail": args.max_tail,
                          "shared_max_new": args.shared_new,
                          "prefill_chunk": args.prefill_chunk,
                          "page_size": args.page_size,
                          "spec_k": args.spec_k},
             "rows": zipf_rows, "shared_rows": shared_rows}
    if args.check:
        from benchmarks.common import run_check
        # structural claims (token counts, wire bytes/token, compile
        # counters, bit-identity, prefix-hit counts) gate exactly;
        # wall-clock throughputs, latency percentiles and the greedy
        # acceptance rate are machine-dependent and gate only against
        # order-of-magnitude drift
        return run_check(fresh, "serve_bench",
                         band_keys={"tok_per_s": 0.75, "wall_s": 0.75,
                                    "mean_ttft_s": 0.9, "speedup": 0.6,
                                    "p50_ttft_s": 0.9, "p99_ttft_s": 0.9,
                                    "paged_speedup": 0.6,
                                    "acceptance_rate": 0.9},
                         ignore_keys=frozenset(("seconds",)))
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(fresh, f, indent=1)
    print(f"# wrote {RESULTS}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
