"""Serving throughput: static batching vs continuous batching.

The workload is the one the paper's throughput claim actually meets in
production: a mixed stream — Zipf-distributed prompt lengths AND
Zipf-distributed max-new-tokens.  A static engine pads every prompt to the
batch max and decodes everyone until the batch's largest max-new-tokens,
burning slots on finished requests; the continuous engine evicts a
finished slot and refills it the same tick.

Asserted acceptance criteria (per policy variant):

  * continuous tokens/s >= 1.5x the static engine on the mixed workload;
  * every request's continuous-batching output is BIT-IDENTICAL to the
    same request served alone through the engine;
  * the measured serving run adds ZERO jit compilations after warmup
    (slot eviction/refill never recompiles).

Variants cover the paper's serve-time story: compressed boundaries
(top-10% through the wire codecs) vs the --no-compress ablation.

Writes benchmarks/results/serve_bench.json.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--requests N]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, topk_policy
from repro.launch.serve import zipf_lengths
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "serve_bench.json")


def build_workload(cfg, n, max_prompt, max_new, seed=0, a=1.2):
    """Zipf-mixed requests: prompts in [2, max_prompt], decode lengths in
    [8, max_new].  a=1.2 gives the heavy tail that makes static batching
    hurt — most requests decode ~8-16 tokens, a few run to max_new, and
    every static group decodes to ITS max."""
    rng = np.random.RandomState(seed)
    plens = zipf_lengths(rng, n, 2, max_prompt, a)
    news = zipf_lengths(rng, n, 8, max_new, a)
    prompts = [rng.randint(1, min(cfg.vocab_size, 1024),
                           l).astype(np.int32) for l in plens]
    return prompts, news


def run_static(params, cfg, policy, compress, prompts, news, slots,
               max_seq):
    """FIFO groups of ``slots`` requests; each group pads to its own max
    prompt length and decodes to its own max new-tokens (the engine's
    semantics — finished requests still occupy their slot)."""
    eng = ServeEngine(params, cfg, policy, compress=compress,
                      max_batch=slots, max_seq=max_seq)
    groups = [list(range(i, min(i + slots, len(prompts))))
              for i in range(0, len(prompts), slots)]
    # warm every group's (batch, padded-prompt) shape so compile time
    # stays out of the measurement
    for g in groups:
        eng.generate([Request(prompts[i].copy(), 2) for i in g])
    outs = {}
    t0 = time.time()
    for g in groups:
        reqs = eng.generate([Request(prompts[i].copy(), int(news[i]))
                             for i in g])
        for i, r in zip(g, reqs):
            outs[i] = r.out
    wall = time.time() - t0
    useful = int(sum(news))
    return {"wall_s": round(wall, 3),
            "tok_per_s": round(useful / wall, 1),
            "useful_tokens": useful,
            # slots decode until the group max: the padding waste the
            # scheduler exists to eliminate
            "decoded_slot_tokens": int(sum(len(g) * max(news[i] for i in g)
                                           for g in groups))}, outs


def run_continuous(params, cfg, policy, compress, prompts, news, slots,
                   max_seq, max_prompt):
    eng = ContinuousEngine(params, cfg, policy, compress=compress,
                           num_slots=slots, max_seq=max_seq,
                           max_prompt=max_prompt)
    eng.warmup()
    compiles0 = eng.compile_stats()
    t0 = time.time()
    for i, (p, n) in enumerate(zip(prompts, news)):
        eng.submit(p, max_new_tokens=int(n), seed=i)
    done = eng.drain()
    wall = time.time() - t0
    assert eng.compile_stats() == compiles0, \
        f"recompilation during the serving run: {compiles0} -> " \
        f"{eng.compile_stats()}"
    outs = {r.req_id: r.out for r in done}
    useful = int(sum(news))
    stats = eng.stats()
    return {"wall_s": round(wall, 3),
            "tok_per_s": round(useful / wall, 1),
            "useful_tokens": useful,
            "slot_utilization": stats["slot_utilization"],
            "mean_ttft_s": stats["mean_ttft_s"],
            "boundary_bytes_per_tok": stats["boundary_bytes_per_tok"],
            **compiles0}, outs, eng


def solo_reference(params, cfg, policy, compress, prompts, news, slots,
                   max_seq, max_prompt):
    """Each request alone on the SAME engine shape (num_slots unchanged —
    bit-identity is guaranteed across batch composition, i.e. per-row
    numerics; a different batch SIZE is a different XLA program)."""
    eng = ContinuousEngine(params, cfg, policy, compress=compress,
                           num_slots=slots, max_seq=max_seq,
                           max_prompt=max_prompt)
    outs = {}
    for i, (p, n) in enumerate(zip(prompts, news)):
        eng.submit(p, max_new_tokens=int(n), seed=i)
        (r,) = eng.drain()
        outs[i] = r.out
    return outs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--max-seq", type=int, default=224)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="regression gate: compare against the committed "
                         "results/serve_bench.json — token counts, wire "
                         "bytes/token, compile counters and bit-identity "
                         "flags exact, throughput within a tolerance band; "
                         "exit 1 on drift")
    args = ap.parse_args(argv)

    cfg = get(args.arch, smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    prompts, news = build_workload(cfg, args.requests, args.max_prompt,
                                   args.max_new, args.seed)
    policy = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))
    rows = []
    for name, compress in (("top10", True), ("no-compress", False)):
        st, st_outs = run_static(params, cfg, policy, compress, prompts,
                                 news, args.slots, args.max_seq)
        ct, ct_outs, _ = run_continuous(params, cfg, policy, compress,
                                        prompts, news, args.slots,
                                        args.max_seq, args.max_prompt)
        solo = solo_reference(params, cfg, policy, compress, prompts, news,
                              args.slots, args.max_seq, args.max_prompt)
        mismatches = [i for i in solo
                      if not np.array_equal(solo[i], ct_outs[i])]
        assert not mismatches, \
            f"continuous output != solo for requests {mismatches}"
        speedup = ct["tok_per_s"] / st["tok_per_s"]
        row = {"name": name, "compress": compress,
               "requests": args.requests, "slots": args.slots,
               "static": st, "continuous": ct,
               "speedup": round(speedup, 2),
               "bit_identical_to_solo": True}
        rows.append(row)
        print(json.dumps(row), flush=True)
        assert speedup >= 1.5, \
            f"{name}: continuous {ct['tok_per_s']} tok/s is only " \
            f"{speedup:.2f}x static {st['tok_per_s']} (need >= 1.5x)"
    fresh = {"arch": cfg.arch_id,
             "workload": {"requests": args.requests,
                          "slots": args.slots,
                          "zipf_max_prompt": args.max_prompt,
                          "zipf_max_new": args.max_new},
             "rows": rows}
    if args.check:
        from benchmarks.common import run_check
        # structural claims (token counts, wire bytes/token, compile
        # counters, bit-identity) gate exactly; wall-clock throughputs are
        # machine-dependent and gate only against order-of-magnitude drift
        return run_check(fresh, "serve_bench",
                         band_keys={"tok_per_s": 0.75, "wall_s": 0.75,
                                    "mean_ttft_s": 0.9, "speedup": 0.6},
                         ignore_keys=frozenset(("seconds",)))
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(fresh, f, indent=1)
    print(f"# wrote {RESULTS}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
