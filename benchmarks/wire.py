"""Boundary wire-bytes model — the paper's motivation quantified.

For each compression mode, computes the bytes crossing ONE pipeline-stage
boundary per training step (forward activations + backward gradients) for a
representative LM stage tensor (B, S, d_model), and the implied transfer
time over slow-network (1 Gbit/s, the paper's Petals-style setting) and TPU
ICI (50 GB/s) links.  Pure arithmetic — no device work.
"""
from __future__ import annotations

from typing import List

from repro.core.policy import (BoundaryPolicy, NO_COMPRESSION, quant_policy,
                               topk_policy)

GBIT = 1e9 / 8
ICI = 50e9


def boundary_bytes(bp: BoundaryPolicy, numel: int, elem_bytes: int = 2):
    fw = bp.fw.wire_bytes_per_elem(elem_bytes) * numel
    bw = bp.bw.wire_bytes_per_elem(elem_bytes) * numel
    return fw, bw


def rows(batch: int = 8, seq: int = 1024, d_model: int = 768) -> List[dict]:
    """GPT-2-small fine-tuning shape (paper Sec. 3.2)."""
    numel = batch * seq * d_model
    modes = [("no-compression", NO_COMPRESSION)]
    modes += [(f"fw{a}-bw{b}", quant_policy(a, b))
              for a, b in [(4, 8), (4, 4), (2, 8)]]
    modes += [(f"top{int(k*100)}%", topk_policy(k))
              for k in [0.5, 0.3, 0.2, 0.1, 0.05]]
    modes += [("top10%+reuse", topk_policy(0.10, reuse_indices=True))]
    out = []
    base = 2 * numel * 2.0
    for name, bp in modes:
        fw, bw = boundary_bytes(bp, numel)
        if bp.reuse_indices:
            # reused indices need not be retransmitted backward: values only
            bw = bp.bw.k_frac * 2 * numel
        tot = fw + bw
        out.append({
            "name": name, "fw_MB": fw / 1e6, "bw_MB": bw / 1e6,
            "ratio": base / tot,
            "ms_1gbit": 1e3 * tot / GBIT, "ms_ici": 1e3 * tot / ICI,
        })
    return out
