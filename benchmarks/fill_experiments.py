"""Render the cached §Repro tables into EXPERIMENTS.md (replaces the
REPRO_TABLES_PLACEHOLDER marker).  Pure cache replay — no training."""
import io
import re
import sys
from contextlib import redirect_stdout

import benchmarks.common as common

common.CACHED_ONLY = True

from benchmarks.run import main as run_main  # noqa: E402


def main():
    buf = io.StringIO()
    with redirect_stdout(buf):
        run_main(["--cached-only"])
    text = buf.getvalue()
    # keep only the table sections + validation block (drop roofline dup)
    cut = text.find("\n### Roofline")
    if cut != -1:
        tail_start = text.find("### Paper-findings validation")
        tail = text[tail_start:] if tail_start != -1 else ""
        text = text[:cut] + "\n" + tail
    path = "EXPERIMENTS.md"
    doc = open(path).read()
    if "REPRO_TABLES_PLACEHOLDER" in doc:
        doc = doc.replace("REPRO_TABLES_PLACEHOLDER", text.strip())
    else:
        # refresh between the §Repro header and the Notes subsection
        doc = re.sub(
            r"(## §Repro — paper Tables 1-5\n.*?output \(F1-F6\)\.\n)(.*?)(\n### Notes vs the paper)",
            lambda m: m.group(1) + "\n" + text.strip() + "\n" + m.group(3),
            doc, flags=re.S)
    open(path, "w").write(doc)
    print("EXPERIMENTS.md §Repro updated")


if __name__ == "__main__":
    sys.exit(main())
