"""Continuous-batching serving with boundary compression (paper finding F3
at serve time).

Streams a mixed-length batch of requests through the ContinuousEngine's
submit()/step()/drain() API on a reduced Mixtral-style MoE config with the
Top-10% boundary policy — each stage cut packs/unpacks the real TopK wire
payload — first with compression ON, then the same requests with
compression OFF, and shows the generations diverge: compression is part of
the trained model's function.

Run:  PYTHONPATH=src python examples/serve_compressed.py
"""
import numpy as np
import jax

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, topk_policy
from repro.models import transformer
from repro.serve.engine import ContinuousEngine

cfg = get("mixtral-8x7b", smoke=True)
policy = CompressionPolicy(num_stages=4, boundary=topk_policy(0.10))
params = transformer.init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.RandomState(0)
# mixed prompt lengths + mixed decode budgets: the scheduler packs them
prompts = [rng.randint(0, min(cfg.vocab_size, 512), n).astype(np.int32)
           for n in (24, 9, 17, 5)]
news = (16, 6, 10, 12)

outs = {}
for compress in (True, False):
    engine = ContinuousEngine(params, cfg, policy, compress=compress,
                              num_slots=2, max_seq=128)
    engine.warmup()
    for p, n in zip(prompts, news):
        engine.submit(p.copy(), max_new_tokens=n)
    done = {r.req_id: r for r in engine.drain()}
    outs[compress] = [done[i].out for i in range(len(prompts))]
    stats = engine.stats()
    print(f"compress={compress}: util={stats['slot_utilization']} "
          f"mean_ttft={stats['mean_ttft_s']}s "
          f"wire bytes/token={stats['boundary_bytes_per_tok']}")
    for i in range(2):
        print(f"  req{i} ({done[i].metrics()['new_tokens']} toks) "
              f"-> {done[i].out.tolist()}")

same = all(np.array_equal(a, b) for a, b in zip(outs[True], outs[False]))
print(f"generations identical with/without compression: {same}")
print("-> expect False: serving must keep the training-time compression "
      "(finding F3)")
