"""Continuous-batching serving with boundary compression (paper finding F3
at serve time).

Part 1 streams a mixed-length batch of requests through the
ContinuousEngine's submit()/step()/drain() API on a reduced Mixtral-style
MoE config with the Top-10% boundary policy — each stage cut packs/unpacks
the real TopK wire payload — first with compression ON, then the same
requests with compression OFF, and shows the generations diverge:
compression is part of the trained model's function.

Part 2 turns on the paged serving path (gpt2-small — paged mode needs a
full-context arch, not Mixtral's sliding window): every request shares a
system-prompt prefix, so the prefix cache reuses its KV pages instead of
re-prefilling, chunked prefill ingests the rest without stalling decode,
and a draft model speculates ahead — while the emitted tokens stay
BIT-IDENTICAL to plain greedy decoding.  F3 applies to the draft too: a
draft trained with boundary compression must serve compressed.

Run:  PYTHONPATH=src python examples/serve_compressed.py
"""
import numpy as np
import jax

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, topk_policy
from repro.models import transformer
from repro.serve.engine import ContinuousEngine

cfg = get("mixtral-8x7b", smoke=True)
policy = CompressionPolicy(num_stages=4, boundary=topk_policy(0.10))
params = transformer.init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.RandomState(0)
# mixed prompt lengths + mixed decode budgets: the scheduler packs them
prompts = [rng.randint(0, min(cfg.vocab_size, 512), n).astype(np.int32)
           for n in (24, 9, 17, 5)]
news = (16, 6, 10, 12)

outs = {}
for compress in (True, False):
    engine = ContinuousEngine(params, cfg, policy, compress=compress,
                              num_slots=2, max_seq=128)
    engine.warmup()
    for p, n in zip(prompts, news):
        engine.submit(p.copy(), max_new_tokens=n)
    done = {r.req_id: r for r in engine.drain()}
    outs[compress] = [done[i].out for i in range(len(prompts))]
    stats = engine.stats()
    print(f"compress={compress}: util={stats['slot_utilization']} "
          f"mean_ttft={stats['mean_ttft_s']}s "
          f"wire bytes/token={stats['boundary_bytes_per_tok']}")
    for i in range(2):
        print(f"  req{i} ({done[i].metrics()['new_tokens']} toks) "
              f"-> {done[i].out.tolist()}")

same = all(np.array_equal(a, b) for a, b in zip(outs[True], outs[False]))
print(f"generations identical with/without compression: {same}")
print("-> expect False: serving must keep the training-time compression "
      "(finding F3)")

# --- part 2: paged serving — shared prefix + chunked prefill + drafts ---
cfg2 = get("gpt2-small", smoke=True)
policy2 = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))
params2 = transformer.init_params(jax.random.PRNGKey(0), cfg2)

shared = rng.randint(0, min(cfg2.vocab_size, 512), 48).astype(np.int32)
reqs = [np.concatenate([shared,
                        rng.randint(0, min(cfg2.vocab_size, 512), t)
                        .astype(np.int32)]) for t in (7, 3, 9, 5)]

variants = {
    "plain": {},
    "paged": dict(prefix_cache=True, prefill_chunk=16),
    # self-draft: the target proposes for itself — acceptance is high and
    # the output is still exactly the target's greedy stream
    "paged+spec": dict(prefix_cache=True, prefill_chunk=16,
                       draft_params=params2, draft_cfg=cfg2,
                       draft_policy=policy2, spec_k=4),
}
outs2 = {}
for name, kw in variants.items():
    eng = ContinuousEngine(params2, cfg2, policy2, compress=True,
                           num_slots=2, max_seq=128, max_prompt=64, **kw)
    eng.warmup()
    for p in reqs:
        eng.submit(p.copy(), max_new_tokens=8)
    done = {r.req_id: r for r in eng.drain()}
    outs2[name] = [done[i].out for i in range(len(reqs))]
    stats = eng.stats()
    extra = ""
    if "prefix_hits" in stats:
        extra += (f" prefix_hits={stats['prefix_hits']}"
                  f" ({stats['prefix_hit_tokens']} toks reused)")
    if "acceptance_rate" in stats:
        extra += f" draft_acceptance={stats['acceptance_rate']}"
    print(f"{name}: mean_ttft={stats['mean_ttft_s']}s{extra}")

# the prefix cache and the draft are pure accelerations: token streams
# match plain paged greedy decoding bit for bit
for name in ("paged+spec",):
    assert all(np.array_equal(a, b)
               for a, b in zip(outs2["paged"], outs2[name]))
print("paged and speculative outputs are bit-identical: True")
