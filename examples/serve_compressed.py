"""Batched serving with boundary compression (paper finding F3 at serve
time).

Spins up the ServeEngine on a reduced Mixtral-style MoE config with the
Top-10% boundary policy, serves a batch of greedy-decode requests with
compression ON, then the same requests with compression OFF, and shows the
generations diverge — compression is part of the trained model's function.

Run:  PYTHONPATH=src python examples/serve_compressed.py
"""
import numpy as np
import jax

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, topk_policy
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

cfg = get("mixtral-8x7b", smoke=True)
policy = CompressionPolicy(num_stages=4, boundary=topk_policy(0.10))
params = transformer.init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.RandomState(0)
prompts = [rng.randint(0, min(cfg.vocab_size, 512), 24).astype(np.int32)
           for _ in range(4)]

outs = {}
for compress in (True, False):
    engine = ServeEngine(params, cfg, policy, compress=compress,
                         max_batch=4, max_seq=128)
    reqs = engine.generate([Request(p.copy(), 16) for p in prompts])
    probe = engine.throughput_probe(4, 24, 16)
    outs[compress] = [r.out for r in reqs]
    print(f"compress={compress}: {probe['tok_per_s']:.1f} tok/s")
    for i, r in enumerate(reqs[:2]):
        print(f"  req{i} -> {r.out.tolist()}")

same = all(np.array_equal(a, b) for a, b in zip(outs[True], outs[False]))
print(f"generations identical with/without compression: {same}")
print("-> expect False: serving must keep the training-time compression "
      "(finding F3)")
