"""Real pipeline parallelism with compressed stage handoffs (beyond-paper).

Forces 4 host devices, builds a 4-stage pipeline over mesh axis "stage" via
shard_map, and streams microbatches through it with the boundary payload
PACKED on the wire (bf16 raw / int8 quant / 4-bit packed / TopK
values+indices).  Verifies the pipelined result matches the sequential
forward and prints the measured bytes-per-boundary of each scheme — the
collective-bytes reduction that motivates the whole paper — then demos the
pluggable schedules (repro.transport.schedules): 1F1B (fused single-buffer
hops, rematerialized ticks) and interleaved virtual stages (each device
runs 2 round-robin stage slices: 1/v the fill bubble, v*S-1 compressed
cuts).

Run:  PYTHONPATH=src python examples/pipeline_stages.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

from repro.transport import (get_schedule, pack_payload, pipeline_forward,
                             wire_bytes)

mesh = jax.make_mesh((4,), ("stage",))
B, D = 8, 256
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (B, D), jnp.float32)

# 4 stages, each an MLP block; stage s holds slice s of the stacked params.
k1, k2 = jax.random.split(key)
w1 = jax.random.normal(k1, (4, D, 4 * D)) * (1.0 / D) ** 0.5
w2 = jax.random.normal(k2, (4, 4 * D, D)) * (1.0 / (4 * D)) ** 0.5
params = {"w1": w1, "w2": w2}


def stage_fn(p, h):
    return h + jax.nn.gelu(h @ p["w1"]) @ p["w2"]


# sequential reference
ref = x
for s in range(4):
    ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)

print(f"pipeline over mesh {dict(mesh.shape)} — payload schemes:")
for scheme, k in [("none", 0.1), ("q8", 0.1), ("q4", 0.1), ("topk", 0.1)]:
    out = pipeline_forward(stage_fn, params, x, mesh, "stage",
                           scheme=scheme, k_frac=k)
    err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    payload = pack_payload(ref[: B // 4], scheme, k)
    mb = wire_bytes(payload)
    raw = ref[: B // 4].size * 2
    print(f"  {scheme:5s}: bytes/boundary {mb:7d} "
          f"({raw / mb:4.1f}x vs bf16)  rel-err vs sequential {err:.3f}")
print("-> 'none' must be ~exact; q8 tight; q4/topk lossy by design")

# --- pluggable schedules -----------------------------------------------------
print("\nschedules (mb=8 microbatches on 4 stages):")
out_1f1b = pipeline_forward(stage_fn, params, x, mesh, "stage", scheme="q8",
                            microbatches=8, schedule="1f1b")
print(f"  1f1b       : {get_schedule('1f1b').describe(8, 4)}  "
      f"rel-err {float(jnp.max(jnp.abs(out_1f1b - ref)) / jnp.max(jnp.abs(ref))):.3f}")

# interleaved: 8 LOGICAL stage slices (2 per device, round-robin).  To
# keep the same total model as the 4-stage reference, interleave the 4
# real slices with 4 IDENTITY slices (zero-weight residual MLPs):
# logical order [real0, id, real1, id, real2, id, real3, id].
params8 = {"w1": jnp.concatenate([w1, jnp.zeros_like(w1)]),
           "w2": jnp.concatenate([w2, jnp.zeros_like(w2)])}
order = np.array([0, 4, 1, 5, 2, 6, 3, 7])
params8 = jax.tree.map(lambda a: a[order], params8)
out_il = pipeline_forward(stage_fn, params8, x, mesh, "stage", scheme="q8",
                          microbatches=8, schedule="interleaved",
                          virtual_stages=2)
err = float(jnp.max(jnp.abs(out_il - ref)) / jnp.max(jnp.abs(ref)))
print(f"  interleaved: {get_schedule('interleaved', 2).describe(8, 4)}  "
      f"rel-err {err:.3f}")
print("-> interleaved shrinks the fill bubble by 1/v and multiplies the "
      "compressed cuts — the regime where the codecs pay off")
