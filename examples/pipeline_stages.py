"""Real pipeline parallelism with compressed stage handoffs (beyond-paper).

Forces 4 host devices, builds a 4-stage GPipe pipeline over mesh axis
"stage" via shard_map, and streams microbatches through it with the boundary
payload PACKED on the wire (bf16 raw / int8 quant / 4-bit packed / TopK
values+indices).  Verifies the pipelined result matches the sequential
forward and prints the measured bytes-per-boundary of each scheme — the
collective-bytes reduction that motivates the whole paper.

Run:  PYTHONPATH=src python examples/pipeline_stages.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pipeline import (pack_payload, pipeline_forward, wire_bytes)

mesh = jax.make_mesh((4,), ("stage",))
B, D = 8, 256
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (B, D), jnp.float32)

# 4 stages, each an MLP block; stage s holds slice s of the stacked params.
k1, k2 = jax.random.split(key)
w1 = jax.random.normal(k1, (4, D, 4 * D)) * (1.0 / D) ** 0.5
w2 = jax.random.normal(k2, (4, 4 * D, D)) * (1.0 / (4 * D)) ** 0.5
params = {"w1": w1, "w2": w2}


def stage_fn(p, h):
    return h + jax.nn.gelu(h @ p["w1"]) @ p["w2"]


# sequential reference
ref = x
for s in range(4):
    ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)

print(f"pipeline over mesh {dict(mesh.shape)} — payload schemes:")
for scheme, k in [("none", 0.1), ("q8", 0.1), ("q4", 0.1), ("topk", 0.1)]:
    out = pipeline_forward(stage_fn, params, x, mesh, "stage",
                           scheme=scheme, k_frac=k)
    err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    payload = pack_payload(ref[: B // 4], scheme, k)
    mb = wire_bytes(payload)
    raw = ref[: B // 4].size * 2
    print(f"  {scheme:5s}: bytes/boundary {mb:7d} "
          f"({raw / mb:4.1f}x vs bf16)  rel-err vs sequential {err:.3f}")
print("-> 'none' must be ~exact; q8 tight; q4/topk lossy by design")
