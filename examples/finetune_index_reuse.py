"""Paper Table 5 in miniature: TopK index reuse vs separate masks.

Fine-tunes a pretrained tiny LM with Top-10% boundary compression two ways:
(a) backward gradients compressed with the REUSED forward TopK indices, and
(b) activations and gradients compressed with INDEPENDENT TopK masks.
The paper reports (b) diverges on a pretrained model (ppl 2990 vs 74);
this demo shows the same ordering at toy scale.

Run:  PYTHONPATH=src python examples/finetune_index_reuse.py
"""
import math

from repro.core.policy import CompressionPolicy, topk_policy
from repro.data.synthetic import LMData
from repro.models.config import ModelConfig
from repro.train.loop import pretrain_lm, run_lm_experiment

cfg = ModelConfig(
    arch_id="ft-demo", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=256,
    pos_embed="rope", norm="layernorm", mlp="gelu", max_seq=64)

data = LMData(num_train=256, num_test=64)
print("pretraining (uncompressed)...")
pre, loss = pretrain_lm(cfg, steps=200, data=data)
print(f"  pretrain loss {loss:.3f}")

K = 0.30          # paper Table 5 ladder; at toy scale top30 shows the
                  # reuse-vs-separate mechanism without total collapse
for reuse in (True, False):
    pol = CompressionPolicy(
        num_stages=4, boundary=topk_policy(K, reuse_indices=reuse))
    r = run_lm_experiment(cfg, pol, pretrained_params=pre, epochs=2,
                          data=data, name=f"reuse={reuse}")
    print(f"top{int(K*100)} reuse_indices={reuse}:  "
          f"eval loss {r.loss_on:.3f}  "
          f"ppl {math.exp(min(r.loss_on, 20)):.1f}")
print("-> separate masks (reuse=False) should be worse (finding F6); the "
      "full-scale version is benchmarks table5")
