"""Quickstart: train a tiny LM with the paper's boundary compression.

Builds a 4-layer transformer, cuts it into 4 pipeline stages (3 compression
boundaries, the paper's MP degree), trains ~60 steps with Top-10% activation
+ gradient compression (forward TopK indices reused backward, paper Table 5),
then evaluates with compression ON and OFF — reproducing finding F3 in
miniature: the trained model expects its boundary compression at inference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax
import jax.numpy as jnp

from repro.core.boundary import init_boundary_state
from repro.core.policy import CompressionPolicy, topk_policy
from repro.data.synthetic import LMData
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.train.steps import (make_lm_eval_step, make_lm_train_step)

cfg = ModelConfig(
    arch_id="quickstart-lm", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=256,
    pos_embed="rope", norm="rmsnorm", mlp="swiglu", max_seq=64)

policy = CompressionPolicy(num_stages=4,
                           boundary=topk_policy(0.10, reuse_indices=True))

data = LMData(num_train=256, num_test=64)
opt = OptimizerConfig(kind="adamw", lr=1e-3, schedule="constant",
                      grad_clip=1.0)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
opt_state = init_opt_state(opt, params)
bstates = [init_boundary_state(policy.at(i), (data.seq_len, cfg.d_model),
                               batch=16) for i in range(3)]
step = make_lm_train_step(cfg, policy, opt, remat=False, donate=False)

print(f"training {cfg.arch_id} with policy "
      f"{policy.boundary.name} at 3 stage boundaries")
n = 0
for ep in range(4):
    for toks, ids in data.epoch(16, ep):
        params, opt_state, bstates, m = step(
            params, opt_state, bstates, {"tokens": jnp.asarray(toks)},
            jnp.asarray(ids))
        n += 1
        if n % 16 == 0:
            print(f"  step {n:3d}  loss {float(m['loss']):.3f}")

for compress in (True, False):
    ev = make_lm_eval_step(cfg, policy, compress)
    losses = [float(ev(params, {"tokens": jnp.asarray(t)}))
              for t, _ in data.test_batches(16)]
    loss = sum(losses) / len(losses)
    tag = "ON " if compress else "OFF"
    print(f"eval compression {tag}: loss {loss:.3f} "
          f"ppl {math.exp(loss):.1f}")
print("-> the compressed-inference loss should be the lower one (finding F3)")
