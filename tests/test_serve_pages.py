"""Page-table invariants (serve/pages.py): property tests over random
alloc / share / CoW / release sequences, plus the device-pool scatter
semantics the paged engine builds on.

The load-bearing invariants:
  * no page leaks — every page is always in exactly ONE of
    {free, LRU-cached, active (rc > 0)};
  * a refcount hits zero exactly at its release (free or park, never
    early, never negative);
  * a shared or prefix-indexed page is never handed out for in-place
    writing (``writable`` copy-on-writes it);
  * a prefix-hash collision falls back to full token-id comparison —
    correctness never rests on the hash.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.serve.pages import (PagePoolFull, PageTable, TRASH_PAGE,
                               copy_pages, init_page_pool, pages_for)


def _prompt(rng, n):
    return rng.randint(0, 1000, n).astype(np.int32)


class TestPageTableBasics:
    def test_alloc_release_roundtrip(self):
        pt = PageTable(8, 4)
        pids = [pt.alloc() for _ in range(7)]
        assert TRASH_PAGE not in pids
        assert len(set(pids)) == 7
        with pytest.raises(PagePoolFull):
            pt.alloc()
        pt.release(pids)
        pt.check_invariants()
        assert pt.available() == 7

    def test_release_below_zero_raises(self):
        pt = PageTable(4, 4)
        pid = pt.alloc()
        pt.release([pid])
        with pytest.raises(ValueError):
            pt.release([pid])

    def test_trash_release_is_noop(self):
        pt = PageTable(4, 4)
        pt.release([TRASH_PAGE])
        pt.check_invariants()

    def test_match_increfs_and_caps_at_full_pages(self):
        """A prompt's LAST token is never shareable: only full pages of
        tokens[:-1] are matched, so the chunk that produces the first
        generated token always recomputes."""
        rng = np.random.RandomState(0)
        pt = PageTable(16, 4)
        prompt = _prompt(rng, 13)                 # 3 full pages of [:-1]
        pids = [pt.alloc() for _ in range(4)]
        pt.register_prefix(prompt, pids)
        m = pt.match_prefix(prompt)
        assert m == pids[:3]
        assert all(pt.ref[p] == 2 for p in m)
        # exact multiple: len-1 divisible by page -> still capped
        p2 = _prompt(rng, 9)                      # (9-1)//4 == 2 pages
        pidsb = [pt.alloc() for _ in range(3)]
        pt.register_prefix(p2, pidsb)
        assert len(pt.match_prefix(p2)) == 2
        pt.check_invariants()

    def test_released_indexed_pages_park_in_lru_then_evict(self):
        rng = np.random.RandomState(1)
        pt = PageTable(6, 4)                      # 5 usable pages
        prompt = _prompt(rng, 9)
        pids = [pt.alloc() for _ in range(3)]
        pt.register_prefix(prompt, pids)
        pt.release(pids)
        assert pt.cached_pages() == 2             # the 2 full pages park
        assert pt.available() == 5
        # exhaust the free list; the next allocs evict from the LRU
        got = [pt.alloc() for _ in range(5)]
        assert len(set(got)) == 5
        pt.check_invariants()
        assert pt.match_prefix(prompt) == []      # index gone with eviction

    def test_writable_cow_on_shared_and_indexed(self):
        rng = np.random.RandomState(2)
        pt = PageTable(8, 4)
        prompt = _prompt(rng, 9)
        pids = [pt.alloc() for _ in range(3)]
        pt.register_prefix(prompt, pids)
        # private unindexed page: in place
        assert pt.writable(pids[2]) == (pids[2], False)
        # indexed page (rc 1): CoW even with a single user
        new, copy = pt.writable(pids[0])
        assert copy and new != pids[0]
        # shared page (rc 2): CoW
        m = pt.match_prefix(prompt)               # re-incref pids[1]
        assert pids[1] in m
        new2, copy2 = pt.writable(pids[1])
        assert copy2 and new2 != pids[1]
        assert pt.cow_copies == 2
        pt.check_invariants()

    def test_collision_falls_back_to_token_compare(self):
        """With a deliberately constant hash every chain digest collides;
        matching must still stop at the first token-id mismatch."""
        rng = np.random.RandomState(3)
        pt = PageTable(16, 4, hash_fn=lambda parent, chunk: b"same")
        a, b = _prompt(rng, 9), _prompt(rng, 9)
        assert not np.array_equal(a[:4], b[:4])
        pids = [pt.alloc() for _ in range(3)]
        pt.register_prefix(a, pids)
        assert pt.match_prefix(b) == []           # digest hit, tokens differ
        # only page 0 could be indexed (page 1's digest collides with it),
        # and matching it requires the token-id compare to pass
        assert pt.match_prefix(a) == pids[:1]
        pt.check_invariants()


class TestPageTableProperties:
    """Random operation sequences — the ISSUE's property checklist."""

    @given(st.integers(0, 500), st.integers(2, 6), st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def test_no_leaks_under_random_ops(self, seed, log_pages, page_size):
        rng = np.random.RandomState(seed)
        num_pages = 2 ** log_pages
        pt = PageTable(num_pages, page_size)
        held = []                                 # lists of owned page ids
        prompts = [_prompt(rng, rng.randint(1, 4 * page_size))
                   for _ in range(4)]
        for _ in range(60):
            op = rng.randint(4)
            if op == 0:                           # alloc a span
                try:
                    held.append([pt.alloc()
                                 for _ in range(rng.randint(1, 4))])
                except PagePoolFull:
                    pass
            elif op == 1 and held:                # release a span
                pt.release(held.pop(rng.randint(len(held))))
            elif op == 2:                         # match + register
                p = prompts[rng.randint(len(prompts))]
                m = pt.match_prefix(p)
                need = pages_for(len(p), page_size) - len(m)
                try:
                    fresh = [pt.alloc() for _ in range(need)]
                except PagePoolFull:
                    pt.release(m)
                    continue
                pt.register_prefix(p, m + fresh)
                held.append(m + fresh)
            elif op == 3 and held:                # CoW a random held page
                span = held[rng.randint(len(held))]
                i = rng.randint(len(span))
                new, _copy = pt.writable(span[i])
                span[i] = new
            pt.check_invariants()
        for span in held:
            pt.release(span)
        pt.check_invariants()
        # every non-indexed page must be back on the free list
        assert pt.active_pages() == 0
        assert pt.available() == num_pages - 1

    @given(st.integers(0, 500))
    @settings(max_examples=25, deadline=None)
    def test_refcount_zero_exactly_at_last_release(self, seed):
        rng = np.random.RandomState(seed)
        pt = PageTable(32, 4)
        prompt = _prompt(rng, 4 * rng.randint(2, 5) + 1)
        n = pages_for(len(prompt), 4)
        base = [pt.alloc() for _ in range(n)]
        pt.register_prefix(prompt, base)
        users = [base]
        for _ in range(rng.randint(1, 4)):
            m = pt.match_prefix(prompt)
            users.append(m + [pt.alloc() for _ in range(n - len(m))])
        full = (len(prompt) - 1) // 4
        shared = base[:full]
        expect = len(users)
        for i, span in enumerate(users):
            for pid in shared:
                assert pt.ref[pid] == expect - i
            pt.release(span)
            pt.check_invariants()
        for pid in shared:                        # parked, not freed
            assert pt.ref[pid] == 0
            assert pid in pt._lru

    @given(st.integers(0, 500))
    @settings(max_examples=25, deadline=None)
    def test_shared_page_never_handed_out_writable(self, seed):
        rng = np.random.RandomState(seed)
        pt = PageTable(64, 8)
        prompt = _prompt(rng, rng.randint(9, 40))
        n = pages_for(len(prompt), 8)
        base = [pt.alloc() for _ in range(n)]
        pt.register_prefix(prompt, base)
        m = pt.match_prefix(prompt)
        spans = [base, m + [pt.alloc() for _ in range(n - len(m))]]
        for span in spans:
            for i, pid in enumerate(span):
                was_shared = pt.shared(pid)
                new, copy = pt.writable(pid)
                # a shared/indexed page is never returned in place, and
                # the returned page has no other users and no index entry
                assert copy == (new != pid) == was_shared
                assert pt.ref[new] == 1 and new not in pt._meta
                span[i] = new
                pt.check_invariants()
        for span in spans:
            pt.release(span)
        pt.check_invariants()


class TestDevicePool:
    def test_pool_scatter_respects_page_map(self):
        """Writes land in the mapped physical page; a trash-mapped row
        touches page 0 only."""
        pool = {"k": jnp.zeros((1, 5, 4, 2, 3)),
                "v": jnp.zeros((1, 5, 4, 2, 3))}
        k = pool["k"][0]
        page_map = jnp.asarray([[2, 3]])
        wpos = jnp.asarray([[4]])                 # logical page 1, offset 0
        phys = jnp.take_along_axis(page_map, wpos // 4, axis=1)
        knew = k.at[phys, wpos % 4].set(1.0)
        assert float(knew[3, 0].sum()) > 0
        assert float(knew[2].sum()) == 0 and float(knew[0].sum()) == 0

    def test_copy_pages_copies_every_leaf(self):
        pool = {"b0": {"k": jnp.arange(2 * 4 * 3 * 2, dtype=jnp.float32)
                       .reshape(2, 4, 3, 2)}}
        out = copy_pages(pool, jnp.int32(1), jnp.int32(3))
        np.testing.assert_array_equal(np.asarray(out["b0"]["k"][:, 3]),
                                      np.asarray(pool["b0"]["k"][:, 1]))
        np.testing.assert_array_equal(np.asarray(out["b0"]["k"][:, :3]),
                                      np.asarray(pool["b0"]["k"][:, :3]))

    def test_init_page_pool_rejects_window_archs(self):
        from repro.configs.registry import get
        from repro.models import transformer
        cfg = get("mixtral-8x7b", smoke=True)
        assert cfg.window is not None
        with pytest.raises(ValueError, match="sliding-window"):
            init_page_pool(transformer, cfg, 8, 4)

    def test_pages_for(self):
        assert pages_for(1, 8) == 1
        assert pages_for(8, 8) == 1
        assert pages_for(9, 8) == 2
