"""Model-substrate invariants across architecture families."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.core.policy import CompressionPolicy, NO_POLICY, topk_policy
from repro.models import transformer


def _batch(cfg, b, s, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (b, s)).astype(np.int32))}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((b, cfg.num_patches, cfg.d_model),
                                          jnp.bfloat16)
    return batch


# NOTE mixtral excluded: capacity-limited expert routing is computed over
# the whole (B,S) token set, so a later token can evict an earlier token
# from an expert's capacity — MoE with finite capacity is not strictly
# causal.  Standard behaviour (Switch/GShard), not a bug.
@pytest.mark.parametrize("arch", ["glm4-9b", "starcoder2-7b", "rwkv6-3b",
                                  "hymba-1.5b"])
def test_causality(arch):
    """Perturbing token t+k never changes logits at positions < t."""
    cfg = get(arch, smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, 2, 16)
    logits1 = transformer.forward_eval(params, b, cfg, NO_POLICY)
    toks2 = b["tokens"].at[:, 12].set((b["tokens"][:, 12] + 7)
                                      % cfg.vocab_size)
    b2 = dict(b, tokens=toks2)
    logits2 = transformer.forward_eval(params, b2, cfg, NO_POLICY)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :12], np.float32),
        np.asarray(logits2[:, :12], np.float32), atol=2e-2)
    # and the perturbation DOES reach later positions
    assert np.abs(np.asarray(logits1[:, 12:], np.float32)
                  - np.asarray(logits2[:, 12:], np.float32)).max() > 1e-4


def test_boundary_count_matches_policy():
    cfg = get("granite-8b", smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    for stages in (1, 2, 4):
        pol = CompressionPolicy(num_stages=stages,
                                boundary=topk_policy(0.5))
        x, aux, new_fw = transformer.forward_hidden(
            params, _batch(cfg, 2, 8), cfg, pol, None,
            jnp.zeros((2,), jnp.int32), remat=False)
        # a 2-group smoke model can host at most num_groups-1 boundaries
        expect = min(stages, cfg.num_groups) - 1
        assert len(new_fw) == expect, (stages, len(new_fw))


def test_moe_aux_loss_positive_and_finite():
    cfg = get("mixtral-8x7b", smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    _, aux, _ = transformer.forward_hidden(
        params, _batch(cfg, 2, 8), cfg, NO_POLICY, None,
        jnp.zeros((2,), jnp.int32), remat=False)
    a = float(aux)
    assert np.isfinite(a) and a > 0.0


def test_rwkv_decode_state_constant_memory():
    """SSM decode carries O(1) state: cache pytree size is independent of
    the nominal context length."""
    cfg = get("rwkv6-3b", smoke=True)
    c64 = jax.eval_shape(lambda: transformer.init_caches(cfg, 1, 64))
    c4k = jax.eval_shape(lambda: transformer.init_caches(cfg, 1, 4096))
    sz = lambda t: sum(x.size for x in jax.tree.leaves(t))
    assert sz(c64) == sz(c4k)


def test_swa_cache_is_windowed():
    """Mixtral SWA: KV cache length is min(cache_len, window)."""
    cfg = get("mixtral-8x7b", smoke=True)
    assert cfg.window is not None
    big = 8 * cfg.window
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, 1, big))
    # attn caches are (groups, batch, cache_len, kv_heads, head_dim)
    lens = [x.shape[2] for x in jax.tree.leaves(caches) if x.ndim == 5]
    assert lens and max(lens) <= cfg.window, (lens, cfg.window)


def test_gemma2_softcap_bounds_logits():
    cfg = get("gemma2-27b", smoke=True)
    assert cfg.final_softcap
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    logits = transformer.forward_eval(params, _batch(cfg, 1, 8), cfg,
                                      NO_POLICY)
    assert float(jnp.abs(logits.astype(jnp.float32)).max()) \
        <= cfg.final_softcap + 1e-3


def test_vlm_patch_embeds_change_text_logits():
    cfg = get("pixtral-12b", smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, 1, 8, seed=1)
    l1 = transformer.forward_eval(params, b, cfg, NO_POLICY)
    b2 = dict(b, patch_embeds=jnp.ones_like(b["patch_embeds"]))
    l2 = transformer.forward_eval(params, b2, cfg, NO_POLICY)
    assert np.abs(np.asarray(l1, np.float32)
                  - np.asarray(l2, np.float32)).max() > 1e-4


def test_compression_boundary_is_transparent_at_k100():
    """Top-100% and 16-bit-ish quant should be ~identity on the forward."""
    cfg = get("glm4-9b", smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, 2, 8)
    base = transformer.forward_eval(params, b, cfg, NO_POLICY)
    pol = CompressionPolicy(num_stages=4, boundary=topk_policy(1.0))
    comp = transformer.forward_eval(params, b, cfg, pol, compress=True)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(comp, np.float32), atol=2e-2)
