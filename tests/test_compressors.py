"""Unit + property tests for compression operators (paper Sec. 2.2-2.3)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.core import compressors as C


class TestQuantization:
    def test_roundtrip_8bit_close(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        y = C.quantize_dequantize(x, 8)
        span = float(x.max() - x.min())
        assert np.max(np.abs(np.asarray(y - x))) <= span / 255 + 1e-6

    @pytest.mark.parametrize("bits", [2, 4, 6, 8])
    def test_levels(self, bits):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        codes, _, _ = C.quantize_kbit(x, bits)
        assert int(codes.max()) <= (1 << bits) - 1
        assert len(np.unique(np.asarray(codes))) <= (1 << bits)

    def test_constant_tensor_safe(self):
        x = jnp.full((3, 5), 2.5)
        y = C.quantize_dequantize(x, 4)
        np.testing.assert_allclose(np.asarray(y), 2.5, rtol=1e-6)

    def test_endpoints_exact(self):
        # min and max map to themselves
        x = jnp.array([[-3.0, 0.0, 5.0]])
        y = C.quantize_dequantize(x, 8)
        assert np.isclose(float(y[0, 0]), -3.0, atol=1e-5)
        assert np.isclose(float(y[0, 2]), 5.0, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(bits=st.sampled_from([2, 4, 6, 8]),
           seed=st.integers(0, 2**31 - 1))
    def test_error_bound_property(self, bits, seed):
        """|C(x) - x| <= span / (2^bits - 1) elementwise (half-step rounding
        gives span/levels/2; we assert the loose bound)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 16)) * 3.0
        y = C.quantize_dequantize(x, bits)
        span = float(x.max() - x.min())
        assert float(jnp.max(jnp.abs(y - x))) <= span / ((1 << bits) - 1) + 1e-5

    def test_per_axis_scales(self):
        x = jnp.stack([jnp.linspace(0, 1, 16), jnp.linspace(0, 100, 16)])
        y_global = C.quantize_dequantize(x, 4)
        y_rowwise = C.quantize_dequantize(x, 4, axis=(1,))
        err_g = float(jnp.abs(y_global[0] - x[0]).max())
        err_r = float(jnp.abs(y_rowwise[0] - x[0]).max())
        assert err_r < err_g  # per-row scale is strictly better on row 0


class TestTopK:
    def test_keeps_largest(self):
        x = jnp.array([[1.0, -5.0, 0.1, 3.0, -0.2, 0.05, 2.0, -4.0]])
        y = C.topk_compress(x, 0.25)  # keep 2 of 8
        nz = np.nonzero(np.asarray(y))[1]
        assert set(nz.tolist()) == {1, 7}  # -5, -4 are largest by |.|

    @pytest.mark.parametrize("k", [0.5, 0.3, 0.2, 0.1, 0.05])
    def test_sparsity(self, k):
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 1000))
        y = C.topk_compress(x, k)
        frac = float((y != 0).mean())
        assert abs(frac - k) < 0.01

    def test_per_example_independent(self):
        x = jnp.stack([jnp.arange(8.0), jnp.arange(8.0)[::-1]])
        m = C.topk_mask(x, 0.25)
        assert np.asarray(m[0]).tolist() == [False] * 6 + [True] * 2
        assert np.asarray(m[1]).tolist() == [True] * 2 + [False] * 6

    def test_values_indices_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 32))
        vals, idx = C.topk_values_indices(x, 0.25)
        y = C.topk_scatter(vals, idx, x.shape)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(C.topk_compress(x, 0.25)),
                                   rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           k=st.sampled_from([0.5, 0.2, 0.1]),
           n=st.sampled_from([64, 100, 256]))
    def test_topk_is_best_k_sparse_approx(self, seed, k, n):
        """C(x) minimizes ||x - y|| over k-sparse y  (biasedness property:
        ||C(x)-x||^2 <= (1-k)||x||^2 on average; we check the exact argmin)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
        y = C.topk_compress(x, k)
        kept = max(1, int(round(k * n)))
        # kept entries are the `kept` largest magnitudes
        mags = np.sort(np.abs(np.asarray(x)), axis=-1)
        err = np.asarray(jnp.sum((x - y) ** 2, axis=-1))
        best = (mags[:, :-kept] ** 2).sum(-1)
        np.testing.assert_allclose(err, best, rtol=1e-5)

    def test_wire_bytes_model(self):
        assert C.quant(4).wire_bytes_per_elem() == 0.5
        assert C.quant(8).wire_bytes_per_elem() == 1.0
        assert C.topk(0.1).wire_bytes_per_elem(2) == pytest.approx(0.6)
        assert C.IDENTITY.wire_bytes_per_elem(2) == 2.0


class TestGradFlow:
    def test_quant_nondiff_outside_vjp(self):
        # quantize_dequantize is piecewise constant -> grad ~ 0 through round
        g = jax.grad(lambda x: C.quantize_dequantize(x, 4).sum())(jnp.ones((2, 2)))
        assert np.all(np.isfinite(np.asarray(g)))
