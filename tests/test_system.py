"""System-level tests: optimizers, checkpoint round-trip, data determinism,
policy plumbing, serving engine, train driver integration."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.checkpoint import io as ckpt
from repro.core.policy import (BoundaryPolicy, CompressionPolicy, NO_POLICY,
                               quant_policy, topk_policy)
from repro.data.synthetic import ImageClassData, LMData
from repro.optim.optimizers import (OptimizerConfig, apply_updates,
                                    init_opt_state, schedule_lr)


class TestOptimizers:
    def _quadratic_steps(self, opt, steps=200):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
        state = init_opt_state(opt, params)
        for _ in range(steps):
            grads = jax.grad(
                lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
            params, state = apply_updates(opt, params, grads, state)
        return params

    def test_sgd_momentum_converges(self):
        p = self._quadratic_steps(OptimizerConfig(
            kind="sgd", lr=0.1, momentum=0.9, schedule="constant"))
        assert float(jnp.abs(p["w"]).max()) < 1e-2

    def test_adamw_converges(self):
        p = self._quadratic_steps(OptimizerConfig(
            kind="adamw", lr=0.05, schedule="constant"))
        assert float(jnp.abs(p["w"]).max()) < 1e-2

    def test_weight_decay_shrinks(self):
        opt = OptimizerConfig(kind="sgd", lr=0.1, weight_decay=0.5,
                              schedule="constant")
        params = {"w": jnp.ones((4,))}
        state = init_opt_state(opt, params)
        zero = {"w": jnp.zeros((4,))}
        params, _ = apply_updates(opt, params, zero, state)
        assert float(params["w"][0]) < 1.0

    def test_cosine_schedule_endpoints(self):
        opt = OptimizerConfig(kind="sgd", lr=1.0, schedule="cosine",
                              t_max=100)
        assert float(schedule_lr(opt, jnp.int32(0))) == pytest.approx(1.0)
        assert float(schedule_lr(opt, jnp.int32(100))) < 0.01

    def test_grad_clip_bounds_update(self):
        opt = OptimizerConfig(kind="sgd", lr=1.0, grad_clip=1.0,
                              schedule="constant")
        params = {"w": jnp.zeros((3,))}
        state = init_opt_state(opt, params)
        huge = {"w": jnp.full((3,), 1e6)}
        new, _ = apply_updates(opt, params, huge, state)
        assert float(jnp.abs(new["w"]).max()) <= 1.0 + 1e-5


class TestCheckpoint:
    def test_roundtrip_mixed_dtypes(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
                "b": [jnp.ones((4,), jnp.bfloat16) * 1.5,
                      jnp.zeros((2, 2), jnp.float32)],
                "c": {"d": jnp.array(7.0)}}
        p = str(tmp_path / "ck.npz")
        ckpt.save(p, tree, step=42, extra={"arch": "x"})
        back, step = ckpt.restore(p, jax.eval_shape(lambda: tree))
        assert step == 42
        for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert l1.dtype == l2.dtype
            np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                          np.asarray(l2, np.float32))


class TestData:
    def test_image_data_deterministic(self):
        a, b = ImageClassData(num_train=64, num_test=16), \
               ImageClassData(num_train=64, num_test=16)
        np.testing.assert_array_equal(a.x_train, b.x_train)
        xa = list(a.epoch(16, 3))
        xb = list(b.epoch(16, 3))
        np.testing.assert_array_equal(xa[0][2], xb[0][2])

    def test_lm_data_ids_stable_across_epochs(self):
        d = LMData(num_train=64, num_test=16)
        seen = {}
        for ep in range(2):
            for toks, ids in d.epoch(16, ep):
                for t, i in zip(toks, ids):
                    key = int(i)
                    if key in seen:
                        np.testing.assert_array_equal(seen[key], t)
                    seen[key] = t.copy()
        assert len(seen) == 64

    def test_synthetic_stream_deals_ids_per_replica_under_dp(self):
        """AQ-SGD dp routing contract: contiguous batch shard r must carry
        ids from its own block [r*N/dp, (r+1)*N/dp) every step."""
        from repro.configs.registry import get
        from repro.launch.train import synthetic_stream
        cfg = get("gpt2-small", smoke=True)
        dp, batch, ns = 2, 8, 16
        stream = synthetic_stream(cfg, batch, 32, num_samples=ns, dp=dp)
        seen = [set() for _ in range(dp)]
        for _ in range(6):
            _, ids = next(stream)
            for r in range(dp):
                shard = ids[r * (batch // dp):(r + 1) * (batch // dp)]
                lo, hi = r * ns // dp, (r + 1) * ns // dp
                assert ((shard >= lo) & (shard < hi)).all(), (r, shard)
                seen[r].update(int(i) for i in shard)
        # the cycling still revisits every row of each replica's block
        assert all(len(s) == ns // dp for s in seen)

    def test_lm_task_learnable_structure(self):
        """Order-2 Markov: the same (t-2,t-1) context has <=4 successors."""
        d = LMData(num_train=32)
        succ_count = {}
        for row in d.train:
            for t in range(2, d.seq_len):
                succ_count.setdefault(
                    (row[t - 2], row[t - 1]), set()).add(row[t])
        assert max(len(v) for v in succ_count.values()) <= 4


class TestPolicy:
    def test_cut_layers_even_partition(self):
        pol = CompressionPolicy(num_stages=4)
        assert pol.cut_layers(40) == (9, 19, 29)
        cuts = pol.cut_layers(46)
        assert len(cuts) == 3
        # stage sizes differ by at most 1 layer
        sizes = [cuts[0] + 1, cuts[1] - cuts[0], cuts[2] - cuts[1],
                 46 - 1 - cuts[2]]
        assert max(sizes) - min(sizes) <= 1, sizes
        assert len(pol.cut_layers(12)) == 3

    def test_overrides(self):
        bp = quant_policy(2, 8)
        pol = CompressionPolicy(num_stages=4, boundary=topk_policy(0.1),
                                overrides=((1, bp),))
        assert pol.at(0).fw.kind == "topk"
        assert pol.at(1).fw.bits == 2

    def test_reuse_requires_topk(self):
        with pytest.raises(ValueError):
            BoundaryPolicy(fw=quant_policy(4, 4).fw, reuse_indices=True)

    @given(st.integers(1, 8), st.integers(8, 64))
    @settings(max_examples=20, deadline=None)
    def test_cuts_sorted_in_range(self, stages, layers):
        pol = CompressionPolicy(num_stages=stages)
        cuts = pol.cut_layers(layers)
        assert len(cuts) == stages - 1
        assert all(0 <= c < layers for c in cuts)
        assert list(cuts) == sorted(set(cuts))


class TestServeEngine:
    def test_generate_shapes_and_determinism(self):
        from repro.configs.registry import get
        from repro.models import transformer
        from repro.serve.engine import Request, ServeEngine
        cfg = get("granite-8b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, NO_POLICY, max_batch=2, max_seq=64)
        rng = np.random.RandomState(0)
        mk = lambda: [Request(rng_.randint(0, 100, 8).astype(np.int32), 6)
                      for rng_ in [np.random.RandomState(1),
                                   np.random.RandomState(2)]]
        r1, r2 = eng.generate(mk()), eng.generate(mk())
        for a, b in zip(r1, r2):
            assert a.out.shape == (6,)
            np.testing.assert_array_equal(a.out, b.out)

    def test_compression_changes_generation(self):
        from repro.configs.registry import get
        from repro.models import transformer
        from repro.serve.engine import Request, ServeEngine
        cfg = get("granite-8b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        pol = CompressionPolicy(num_stages=4, boundary=topk_policy(0.05))
        prompt = np.random.RandomState(3).randint(0, 100, 16).astype(np.int32)
        outs = []
        for compress in (True, False):
            eng = ServeEngine(params, cfg, pol, compress=compress,
                              max_batch=1, max_seq=64)
            outs.append(eng.generate([Request(prompt.copy(), 8)])[0].out)
        # not a hard guarantee, but with top5% at 3 boundaries the
        # trajectories essentially always diverge
        assert not np.array_equal(outs[0], outs[1])


class TestTrainDriver:
    def test_train_main_runs_and_learns(self, tmp_path):
        from repro.launch.train import main
        js = str(tmp_path / "m.json")
        ck = str(tmp_path / "ck.npz")
        rc = main(["--arch", "gpt2-small", "--smoke", "--steps", "12",
                   "--batch", "4", "--seq", "32", "--policy", "top10reuse",
                   "--log-every", "4", "--json", js, "--ckpt", ck,
                   "--ckpt-every", "12", "--no-remat"])
        assert rc == 0
        import json as j
        hist = j.load(open(js))
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.5
        assert os.path.exists(ck)

    def test_gradient_accumulation_matches_single_batch(self):
        """grad_accum=2 must give (numerically close) the same update
        as one full batch — the accumulation preserves the paper's
        per-example semantics.  The deprecated ``microbatches=`` alias
        still selects accumulation (with a DeprecationWarning)."""
        import warnings
        from repro.configs.registry import get
        from repro.models import transformer
        from repro.optim.optimizers import OptimizerConfig, init_opt_state
        from repro.train.steps import make_lm_train_step
        cfg = get("granite-8b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        opt = OptimizerConfig(kind="sgd", lr=0.1, momentum=0.0,
                              weight_decay=0.0, schedule="constant",
                              moment_dtype=jnp.float32)
        batch = {"tokens": jnp.asarray(
            np.random.RandomState(0).randint(0, cfg.vocab_size, (4, 16))
            .astype(np.int32))}
        ids = jnp.arange(4, dtype=jnp.int32)
        outs = []
        for mb in (1, 2):
            step = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                      donate=False, grad_accum=mb)
            p, _, _, m = step(params, init_opt_state(opt, params), [],
                              batch, ids)
            outs.append((jax.tree.leaves(p)[0].astype(jnp.float32),
                         float(m["loss"])))
        assert abs(outs[0][1] - outs[1][1]) < 0.05
        np.testing.assert_allclose(np.asarray(outs[0][0]),
                                   np.asarray(outs[1][0]), atol=0.02)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            step = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                      donate=False, microbatches=2)
            assert any(issubclass(x.category, DeprecationWarning)
                       for x in w), w
        p, _, _, m = step(params, init_opt_state(opt, params), [],
                          batch, ids)
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(p)[0].astype(jnp.float32)),
            np.asarray(outs[1][0]), atol=1e-6)

    def test_serve_main_runs(self):
        from repro.launch.serve import main
        rc = main(["--arch", "gpt2-small", "--smoke", "--policy", "top10",
                   "--batch", "2", "--prompt-len", "8", "--new-tokens", "4",
                   "--max-seq", "32"])
        assert rc == 0
