"""Tests for the compression boundary (custom_vjp) and feedback state."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import compressors as C
from repro.core.boundary import (boundary_apply, boundary_eval,
                                 init_boundary_state)
from repro.core.feedback import (aqsgd_message, ef21_message, ef_message,
                                 efmixed_message)
from repro.core.policy import (aqsgd_policy, ef_policy, quant_policy,
                               topk_policy, NO_COMPRESSION)


def _run_boundary(policy, x, state=None, ids=None):
    if state is None:
        state = init_boundary_state(policy, x.shape[1:], batch=x.shape[0])
    if ids is None:
        ids = jnp.zeros((x.shape[0],), jnp.int32)

    def f(x, bw_buf):
        y, new_fw = boundary_apply(policy, x, state["fw"], bw_buf, ids)
        return (y ** 2).sum() / 2, (y, new_fw)

    (loss, (y, new_fw)), (g_x, new_bw) = jax.value_and_grad(
        f, argnums=(0, 1), has_aux=True)(x, state["bw"])
    return y, g_x, new_fw, new_bw


class TestPlainBoundary:
    def test_identity_passthrough(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        y, g_x, _, _ = _run_boundary(NO_COMPRESSION, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x))
        np.testing.assert_allclose(np.asarray(g_x), np.asarray(x))  # d/dx x^2/2 = x

    def test_quant_boundary_compresses_both_directions(self):
        pol = quant_policy(fw_bits=4, bw_bits=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
        y, g_x, _, _ = _run_boundary(pol, x)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(C.quantize_dequantize(x, 4)))
        # backward cotangent is y; it gets 8-bit quantized
        np.testing.assert_allclose(np.asarray(g_x),
                                   np.asarray(C.quantize_dequantize(y, 8)),
                                   rtol=1e-5)

    def test_topk_separate_masks_differ(self):
        pol = topk_policy(0.1)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 512))
        y, g_x, _, _ = _run_boundary(pol, x)
        assert abs(float((y != 0).mean()) - 0.1) < 0.02
        assert abs(float((g_x != 0).mean()) - 0.1) < 0.02

    def test_topk_index_reuse(self):
        """Paper Table 5: gradient must be masked by the FORWARD indices."""
        pol = topk_policy(0.1, reuse_indices=True)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 512))
        ids = jnp.zeros((2,), jnp.int32)
        state = init_boundary_state(pol, x.shape[1:], batch=2)

        def f(x, bw):
            y, _ = boundary_apply(pol, x, state["fw"], bw, ids)
            # weight the cotangent so it is NOT aligned with the fw mask
            w = jnp.arange(y.size, dtype=y.dtype).reshape(y.shape)[:, ::-1]
            return (y * w).sum()

        g_x = jax.grad(f)(x, state["bw"])
        fw_mask = np.asarray(C.topk_mask(x, 0.1))
        g = np.asarray(g_x)
        assert np.all(g[~fw_mask] == 0)          # nothing outside fw mask
        assert np.count_nonzero(g) > 0

    def test_eval_modes(self):
        pol = topk_policy(0.2)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 64))
        on = boundary_eval(pol, x, compress=True)
        off = boundary_eval(pol, x, compress=False)
        np.testing.assert_allclose(np.asarray(off), np.asarray(x))
        np.testing.assert_allclose(np.asarray(on),
                                   np.asarray(C.topk_compress(x, 0.2)))


class TestFeedbackMessages:
    def test_ef_accumulates_exactly(self):
        """EF invariant: message + new_error == x + old_error."""
        comp = C.topk(0.3)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 100))
        e = jax.random.normal(jax.random.PRNGKey(6), (2, 100)) * 0.1
        m, e2 = ef_message(comp, x, e)
        np.testing.assert_allclose(np.asarray(m + e2), np.asarray(x + e), rtol=1e-5)

    def test_ef21_converges_on_constant_input(self):
        """EF21 contraction: on a fixed x, g_t -> x (message error -> 0)."""
        comp = C.topk(0.3)
        x = jax.random.normal(jax.random.PRNGKey(7), (1, 64))
        g = jnp.zeros_like(x)
        errs = []
        for _ in range(30):
            m, g = ef21_message(comp, x, g)
            errs.append(float(jnp.abs(m - x).max()))
        assert errs[-1] < errs[0] * 0.05

    def test_efmixed_sparsity_and_invariant(self):
        comp = C.topk(0.2)
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 1000))
        e = jax.random.normal(jax.random.PRNGKey(9), (2, 1000))
        m, e2 = efmixed_message(comp, x, e)
        # K/2 from input + K/2 from buffer => about K% nonzero (overlap possible)
        frac = float((m != 0).mean())
        assert 0.1 < frac <= 0.21
        np.testing.assert_allclose(np.asarray(m + e2), np.asarray(x + e), rtol=1e-5)

    def test_aqsgd_per_example_buffers(self):
        comp = C.topk(0.5)
        buf = jnp.zeros((10, 8))
        x = jax.random.normal(jax.random.PRNGKey(10), (2, 8))
        ids = jnp.array([3, 7], jnp.int32)
        m, buf2 = aqsgd_message(comp, x, buf, ids)
        # only rows 3 and 7 touched
        untouched = np.asarray(buf2[jnp.array([0, 1, 2, 4, 5, 6, 8, 9])])
        assert np.all(untouched == 0)
        np.testing.assert_allclose(np.asarray(buf2[ids]), np.asarray(m))

    def test_aqsgd_second_pass_smaller_error(self):
        """Visiting the same example twice: 2nd message error < 1st (EF21
        per-example contraction — the point of AQ-SGD)."""
        comp = C.topk(0.3)
        buf = jnp.zeros((4, 256))
        x = jax.random.normal(jax.random.PRNGKey(11), (1, 256))
        ids = jnp.array([2], jnp.int32)
        m1, buf = aqsgd_message(comp, x, buf, ids)
        m2, buf = aqsgd_message(comp, x, buf, ids)
        assert float(jnp.abs(m2 - x).sum()) < float(jnp.abs(m1 - x).sum())


class TestBoundaryWithFeedback:
    def test_fw_buffer_threads_through(self):
        pol = ef_policy(0.2, mode="ef")
        x = jax.random.normal(jax.random.PRNGKey(12), (2, 128))
        state = init_boundary_state(pol, x.shape[1:], batch=2)
        ids = jnp.zeros((2,), jnp.int32)
        w = jax.random.normal(jax.random.PRNGKey(99), x.shape)  # dense cotangent

        def f(x, bw):
            y, new_fw = boundary_apply(pol, x, state["fw"], bw, ids)
            return (y * w).sum(), (y, new_fw)

        (_, (y, new_fw)), (g_x, new_bw) = jax.value_and_grad(
            f, argnums=(0, 1), has_aux=True)(x, state["bw"])
        # EF invariant at the boundary level
        np.testing.assert_allclose(np.asarray(y + new_fw.resid),
                                   np.asarray(x), rtol=1e-5)
        assert new_bw.resid.shape == x.shape  # bw EF buffer via cotangent
        # dense cotangent w compressed by top-20% leaves a nonzero error
        assert float(jnp.abs(new_bw.resid).sum()) > 0
        np.testing.assert_allclose(np.asarray(g_x + new_bw.resid),
                                   np.asarray(w), rtol=1e-5)

    def test_bw_buffer_update_via_cotangent(self):
        pol = ef_policy(0.2, mode="ef21")
        x = jax.random.normal(jax.random.PRNGKey(13), (2, 128))
        state = init_boundary_state(pol, x.shape[1:], batch=2)
        ids = jnp.zeros((2,), jnp.int32)

        def f(x, bw):
            y, _ = boundary_apply(pol, x, state["fw"], bw, ids)
            return (y ** 2).sum() / 2

        g_x, new_bw = jax.grad(f, argnums=(0, 1))(x, state["bw"])
        # EF21: new buffer == the message that was passed upstream == g_x
        np.testing.assert_allclose(np.asarray(new_bw.resid),
                                   np.asarray(g_x), rtol=1e-5)

    def test_aqsgd_boundary(self):
        pol = aqsgd_policy(0.5)
        x = jax.random.normal(jax.random.PRNGKey(14), (2, 64))
        state = init_boundary_state(pol, x.shape[1:], batch=2, num_samples=8)
        ids = jnp.array([1, 5], jnp.int32)
        y, g_x, new_fw, _ = _run_boundary(pol, x, state=state, ids=ids)
        assert new_fw.resid.shape == (8, 64)
        np.testing.assert_allclose(np.asarray(new_fw.resid[ids]),
                                   np.asarray(y))

    def test_jit_and_grad_compose(self):
        pol = ef_policy(0.3, mode="efmixed")
        x = jax.random.normal(jax.random.PRNGKey(15), (2, 64))
        state = init_boundary_state(pol, x.shape[1:], batch=2)
        ids = jnp.zeros((2,), jnp.int32)

        @jax.jit
        def step(x, fw, bw):
            def f(x, bw):
                y, new_fw = boundary_apply(pol, x, fw, bw, ids)
                return (y ** 2).sum(), new_fw
            (loss, new_fw), (gx, new_bw) = jax.value_and_grad(
                f, argnums=(0, 1), has_aux=True)(x, bw)
            return loss, gx, new_fw, new_bw

        loss, gx, new_fw, new_bw = step(x, state["fw"], state["bw"])
        assert np.isfinite(float(loss))
        assert gx.shape == x.shape
