"""Real shard_map pipeline: packed payloads + pipelined forward vs
sequential reference (core/pipeline.py, the beyond-paper path).

Needs >1 host device: spawned in a subprocess with
--xla_force_host_platform_device_count=4 so the main pytest process keeps
seeing exactly one device (DESIGN rule).  Payload packing itself is
single-device and tested in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.core.pipeline import pack_payload, unpack_payload, wire_bytes


class TestPayloadPacking:
    def _roundtrip(self, x, scheme, k=0.25):
        p = pack_payload(x, scheme, k)
        y = unpack_payload(p, x.shape, jnp.float32)
        return p, np.asarray(y)

    def test_none_exact_bf16(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 8))
        p, y = self._roundtrip(x, "none")
        np.testing.assert_allclose(y, np.asarray(x.astype(jnp.bfloat16),
                                                 dtype=np.float32))
        assert wire_bytes(p) == x.size * 2

    def test_q8_tight(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
        p, y = self._roundtrip(x, "q8")
        span = float(x.max() - x.min())
        assert np.abs(y - np.asarray(x)).max() <= span / 255 + 1e-6

    def test_q4_pack_halves_bytes(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 128))
        p8 = pack_payload(x, "q8")
        p4 = pack_payload(x, "q4")
        assert p4["codes4"].size == p8["codes"].size // 2
        y = unpack_payload(p4, x.shape, jnp.float32)
        span = float(x.max() - x.min())
        assert np.abs(np.asarray(y) - np.asarray(x)).max() <= span / 15 + 1e-6

    def test_topk_scatter_matches_dense_topk(self):
        from repro.core.compressors import topk_compress
        x = jax.random.normal(jax.random.PRNGKey(3), (3, 64))
        p = pack_payload(x, "topk", 0.25)
        y = unpack_payload(p, x.shape, jnp.float32)
        dense = topk_compress(x, 0.25)
        np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                                   rtol=1e-2, atol=1e-2)

    @given(st.sampled_from(["none", "q8", "q4"]),
           st.integers(1, 5), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_shapes_roundtrip_property(self, scheme, b, blocks):
        n = 128 * blocks
        x = jax.random.normal(jax.random.PRNGKey(b * 7 + blocks), (b, n))
        p, y = self._roundtrip(x, scheme)
        assert y.shape == x.shape
        assert np.isfinite(y).all()

    def test_wire_bytes_ordering(self):
        """q4 < q8 < none; topk(10%) < none (bf16 values + int32 idx)."""
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 1024))
        b = {s: wire_bytes(pack_payload(x, s, 0.10))
             for s in ("none", "q8", "q4", "topk")}
        assert b["q4"] < b["q8"] < b["none"]
        assert b["topk"] < b["none"]


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.pipeline import pipeline_forward
    mesh = jax.make_mesh((4,), ("stage",))
    B, D = 8, 64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, D), jnp.float32)
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (4, D, 2 * D)) * 0.1,
              "w2": jax.random.normal(k2, (4, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    ref = x
    for s in range(4):
        ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)
    out = pipeline_forward(stage_fn, params, x, mesh, "stage", scheme="none")
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 0.05, f"pipeline vs sequential err {err}"
    out8 = pipeline_forward(stage_fn, params, x, mesh, "stage", scheme="q8")
    err8 = float(jnp.max(jnp.abs(out8 - ref)) / jnp.max(jnp.abs(ref)))
    assert err8 < 0.2, f"q8 pipeline rel err {err8}"
    print("PIPE_OK", err, err8)
""")


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PIPE_OK" in r.stdout
