"""Transport-layer tests: codec registry round-trips, wire-cost models,
simulated/real equivalence, and the differentiable pipeline (subprocess,
2 host devices — the main pytest process keeps seeing exactly one device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.core.compressors import (quant, quantize_dequantize, topk,
                                    topk_compress)
from repro.transport.codecs import (codec_for, get_codec, pack_payload,
                                    registered_codecs, unpack_payload,
                                    wire_bytes)

K_FRACS = (0.05, 0.1, 0.3)
DTYPES = (jnp.bfloat16, jnp.float32)
DIMS = (33, 64)          # odd and even feature dims


def _x(shape, dtype, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


class TestCodecRoundtrip:
    @pytest.mark.parametrize("scheme", registered_codecs())
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", DIMS)
    @pytest.mark.parametrize("k", K_FRACS)
    def test_roundtrip_shape_finite(self, scheme, dtype, n, k):
        x = _x((3, n), dtype)
        p = pack_payload(x, scheme, k)
        y = unpack_payload(p, x.shape, dtype)
        assert y.shape == x.shape and y.dtype == dtype
        assert np.isfinite(np.asarray(y, np.float32)).all()

    @pytest.mark.parametrize("dtype", DTYPES)
    def test_rank3_roundtrip(self, dtype):
        x = _x((2, 5, 7), dtype)       # odd flattened dim (35)
        for scheme in registered_codecs():
            y = unpack_payload(pack_payload(x, scheme, 0.3), x.shape, dtype)
            assert y.shape == x.shape

    def test_q8_matches_dense_compressor_exactly(self):
        x = _x((4, 64), jnp.float32)
        got = get_codec("q8").roundtrip(x)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(quantize_dequantize(x, 8)))

    @pytest.mark.parametrize("n", (33, 34, 64))
    def test_q4_odd_even_matches_dense_compressor(self, n):
        """The odd-feature-dim mis-pack fix: pad to even, truncate back."""
        x = _x((3, n), jnp.float32)
        got = get_codec("q4").roundtrip(x)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(quantize_dequantize(x, 4)))

    def test_topk_matches_dense_compressor(self):
        x = _x((3, 64), jnp.float32)
        got = get_codec("topk").roundtrip(x, 0.25)
        dense = topk_compress(x, 0.25)
        # wire values ride as bf16
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-2, atol=1e-2)
        assert (np.asarray(got != 0) == np.asarray(dense != 0)).all()

    @given(st.sampled_from(sorted(registered_codecs())),
           st.integers(1, 4), st.integers(3, 99))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, scheme, b, n):
        x = _x((b, n), jnp.float32, seed=b * 101 + n)
        y = unpack_payload(pack_payload(x, scheme, 0.1), x.shape,
                           jnp.float32)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()


class TestTopKIndices:
    def test_uint16_when_fits(self):
        x = _x((2, 1000), jnp.float32)
        assert pack_payload(x, "topk", 0.1)["idx"].dtype == jnp.uint16

    def test_int32_when_large(self):
        x = _x((1, (1 << 16) + 8), jnp.float32)
        p = pack_payload(x, "topk", 0.01)
        assert p["idx"].dtype == jnp.int32
        y = unpack_payload(p, x.shape, jnp.float32)
        assert y.shape == x.shape

    def test_cost_model_tracks_idx_dtype(self):
        c = topk(0.1)
        assert c.wire_bytes_per_elem(2, n=1024) == pytest.approx(0.4)
        assert c.wire_bytes_per_elem(2, n=(1 << 16) + 1) == pytest.approx(0.6)
        assert c.wire_bytes_per_elem(2) == pytest.approx(0.6)  # unknown n

    def test_payload_bytes_match_cost_model(self):
        b, n, k = 4, 1024, 0.1
        x = _x((b, n), jnp.float32)
        got = wire_bytes(pack_payload(x, "topk", k))
        model = b * n * topk(k).wire_bytes_per_elem(2, n=n)
        # continuous model vs discrete k=round(k_frac*n): one elem/row slack
        assert abs(got - model) <= b * (2 + 2)


class TestCodecRegistry:
    def test_codec_for_mapping(self):
        assert codec_for(quant(8)).name == "q8"
        assert codec_for(quant(4)).name == "q4"
        assert codec_for(topk(0.1)).name == "topk"
        with pytest.raises(ValueError):
            codec_for(quant(6))

    def test_unknown_scheme_raises(self):
        with pytest.raises(ValueError):
            pack_payload(jnp.zeros((1, 4)), "zstd")

    def test_quant_payload_bytes_match_cost_model(self):
        b, n = 4, 256
        x = _x((b, n), jnp.float32)
        for bits in (4, 8):
            got = wire_bytes(pack_payload(x, f"q{bits}"))
            model = b * n * quant(bits).wire_bytes_per_elem(2)
            assert abs(got - model) <= 16   # per-tensor min/scale scalars


# ---------------------------------------------------------------------------
# Differentiable pipeline (subprocess: 2 host devices)
# ---------------------------------------------------------------------------

GRAD_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    from repro.transport.pipeline import pipeline_apply
    S, B, D = 2, 4, 16
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, D), jnp.float32)
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
              "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]

    def seq_loss(params, x):
        h = x
        for s in range(S):
            h = stage_fn(jax.tree.map(lambda a: a[s], params), h)
            if s < S - 1:   # wire casts to bf16; cotangent rounds through too
                h = h.astype(jnp.bfloat16).astype(jnp.float32)
        return jnp.sum(h ** 2)

    def pipe_loss(params, x):
        out = pipeline_apply(stage_fn, params, x, mesh, "stage",
                             scheme="none")
        return jnp.sum(out ** 2)

    ls, gs = jax.value_and_grad(seq_loss)(params, x)
    lp, gp = jax.value_and_grad(pipe_loss)(params, x)
    assert abs(float(ls - lp)) < 1e-4, (float(ls), float(lp))
    for k in gs:
        d = float(jnp.max(jnp.abs(gs[k] - gp[k])))
        m = float(jnp.max(jnp.abs(gs[k]))) + 1e-9
        assert d / m < 1e-5, (k, d, m)
    gxs = jax.grad(seq_loss, argnums=1)(params, x)
    gxp = jax.grad(pipe_loss, argnums=1)(params, x)
    assert float(jnp.max(jnp.abs(gxs - gxp))) < 1e-5
    print("GRAD_EQUIV_OK")
""")


TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    from repro.core.boundary import boundary_apply
    from repro.core.policy import CompressionPolicy, quant_policy, topk_policy
    from repro.data.synthetic import ImageClassData
    from repro.models import cnn
    from repro.optim.optimizers import (OptimizerConfig, apply_updates,
                                        init_opt_state)
    from repro.train.steps import make_cnn_train_step, xent_loss

    data = ImageClassData()
    opt = OptimizerConfig(kind="sgd", lr=0.05, momentum=0.9,
                          schedule="constant")
    params0 = cnn.init_pipeline_params(jax.random.PRNGKey(0), 2, width=8)

    def run(pol, steps=10):
        step = make_cnn_train_step(pol, opt, transport="pipeline")
        p, o = params0, init_opt_state(opt, params0)
        losses = []
        for i, (x, y, ids) in enumerate(data.epoch(50, 0)):
            if i >= steps:
                break
            p, o, _, m = step(p, o, [], jnp.asarray(x), jnp.asarray(y),
                              jnp.asarray(ids))
            losses.append(float(m["loss"]))
        return losses

    # q8: the real pipeline must track the simulated boundary step-for-step
    pol = CompressionPolicy(num_stages=2, boundary=quant_policy(8, 8))
    pipe = run(pol)

    def seq_loss(params, images, labels):
        x = cnn.pipeline_stem(params, images)
        n = params["stages"]["b0"]["conv1"].shape[0]
        for s in range(n):
            x = cnn.pipeline_stage_apply(
                jax.tree.map(lambda a: a[s], params["stages"]), x)
            if s < n - 1:
                x, _ = boundary_apply(
                    pol.at(s), x, jnp.zeros((0,)), jnp.zeros((0,)),
                    jnp.zeros((x.shape[0],), jnp.int32))
        return xent_loss(cnn.pipeline_head(params, x), labels)

    @jax.jit
    def sstep(p, o, x, y):
        loss, g = jax.value_and_grad(seq_loss)(p, x, y)
        p, o = apply_updates(opt, p, g, o)
        return p, o, loss

    p, o = params0, init_opt_state(opt, params0)
    seq = []
    for i, (x, y, ids) in enumerate(data.epoch(50, 0)):
        if i >= len(pipe):
            break
        p, o, l = sstep(p, o, jnp.asarray(x), jnp.asarray(y))
        seq.append(float(l))
    for a, b in zip(pipe, seq):
        assert abs(a - b) < 0.02 * max(abs(b), 1.0), (pipe, seq)
    assert pipe[-1] < pipe[0], pipe

    # topk: training loss decreases through the sparse wire
    pipe_t = run(CompressionPolicy(num_stages=2,
                                   boundary=topk_policy(0.10)))
    assert pipe_t[-1] < pipe_t[0], pipe_t
    print("TRAIN_OK", pipe[-1], pipe_t[-1])
""")


def _run_sub(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def test_pipeline_gradients_match_sequential_subprocess():
    """Satellite: 2-stage CPU gradient equivalence, scheme='none'."""
    r = _run_sub(GRAD_EQUIV_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "GRAD_EQUIV_OK" in r.stdout


@pytest.mark.slow
def test_pipeline_training_decreases_loss_subprocess():
    """Acceptance: 2-stage CNN training through the real ppermute path
    with q8 (tracks the simulated boundary step-for-step) and topk."""
    r = _run_sub(TRAIN_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "TRAIN_OK" in r.stdout
