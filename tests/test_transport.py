"""Transport-layer tests: codec registry round-trips, wire-cost models,
simulated/real equivalence, and the differentiable pipeline (subprocess,
2 host devices — the main pytest process keeps seeing exactly one device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.core.compressors import (quant, quantize_dequantize, topk,
                                    topk_compress)
from repro.transport.codecs import (codec_for, get_codec, pack_payload,
                                    registered_codecs, unpack_payload,
                                    wire_bytes)

K_FRACS = (0.05, 0.1, 0.3)
DTYPES = (jnp.bfloat16, jnp.float32)
DIMS = (33, 64)          # odd and even feature dims


def _x(shape, dtype, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


class TestCodecRoundtrip:
    @pytest.mark.parametrize("scheme", registered_codecs())
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", DIMS)
    @pytest.mark.parametrize("k", K_FRACS)
    def test_roundtrip_shape_finite(self, scheme, dtype, n, k):
        x = _x((3, n), dtype)
        p = pack_payload(x, scheme, k)
        y = unpack_payload(p, x.shape, dtype)
        assert y.shape == x.shape and y.dtype == dtype
        assert np.isfinite(np.asarray(y, np.float32)).all()

    @pytest.mark.parametrize("dtype", DTYPES)
    def test_rank3_roundtrip(self, dtype):
        x = _x((2, 5, 7), dtype)       # odd flattened dim (35)
        for scheme in registered_codecs():
            y = unpack_payload(pack_payload(x, scheme, 0.3), x.shape, dtype)
            assert y.shape == x.shape

    def test_q8_matches_dense_compressor_exactly(self):
        x = _x((4, 64), jnp.float32)
        got = get_codec("q8").roundtrip(x)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(quantize_dequantize(x, 8)))

    @pytest.mark.parametrize("n", (33, 34, 64))
    def test_q4_odd_even_matches_dense_compressor(self, n):
        """The odd-feature-dim mis-pack fix: pad to even, truncate back."""
        x = _x((3, n), jnp.float32)
        got = get_codec("q4").roundtrip(x)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(quantize_dequantize(x, 4)))

    def test_topk_matches_dense_compressor(self):
        x = _x((3, 64), jnp.float32)
        got = get_codec("topk").roundtrip(x, 0.25)
        dense = topk_compress(x, 0.25)
        # wire values ride as bf16
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-2, atol=1e-2)
        assert (np.asarray(got != 0) == np.asarray(dense != 0)).all()

    @given(st.sampled_from(sorted(registered_codecs())),
           st.integers(1, 4), st.integers(3, 99))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, scheme, b, n):
        x = _x((b, n), jnp.float32, seed=b * 101 + n)
        y = unpack_payload(pack_payload(x, scheme, 0.1), x.shape,
                           jnp.float32)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()


class TestTopKIndices:
    def test_uint16_when_fits(self):
        x = _x((2, 1000), jnp.float32)
        assert pack_payload(x, "topk", 0.1)["idx"].dtype == jnp.uint16

    def test_int32_when_large(self):
        x = _x((1, (1 << 16) + 8), jnp.float32)
        p = pack_payload(x, "topk", 0.01)
        assert p["idx"].dtype == jnp.int32
        y = unpack_payload(p, x.shape, jnp.float32)
        assert y.shape == x.shape

    def test_cost_model_tracks_idx_dtype(self):
        c = topk(0.1)
        assert c.wire_bytes_per_elem(2, n=1024) == pytest.approx(0.4)
        assert c.wire_bytes_per_elem(2, n=(1 << 16) + 1) == pytest.approx(0.6)
        assert c.wire_bytes_per_elem(2) == pytest.approx(0.6)  # unknown n

    def test_payload_bytes_match_cost_model(self):
        b, n, k = 4, 1024, 0.1
        x = _x((b, n), jnp.float32)
        got = wire_bytes(pack_payload(x, "topk", k))
        model = b * n * topk(k).wire_bytes_per_elem(2, n=n)
        # continuous model vs discrete k=round(k_frac*n): one elem/row slack
        assert abs(got - model) <= b * (2 + 2)


class TestCodecRegistry:
    def test_codec_for_mapping(self):
        assert codec_for(quant(8)).name == "q8"
        assert codec_for(quant(4)).name == "q4"
        assert codec_for(topk(0.1)).name == "topk"
        with pytest.raises(ValueError):
            codec_for(quant(6))

    def test_unknown_scheme_raises(self):
        with pytest.raises(ValueError):
            pack_payload(jnp.zeros((1, 4)), "zstd")

    def test_quant_payload_bytes_match_cost_model(self):
        b, n = 4, 256
        x = _x((b, n), jnp.float32)
        for bits in (4, 8):
            got = wire_bytes(pack_payload(x, f"q{bits}"))
            model = b * n * quant(bits).wire_bytes_per_elem(2)
            assert abs(got - model) <= 16   # per-tensor min/scale scalars


# ---------------------------------------------------------------------------
# Differentiable pipeline (subprocess: 2 host devices)
# ---------------------------------------------------------------------------

GRAD_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    from repro.transport.pipeline import pipeline_apply
    S, B, D = 2, 4, 16
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, D), jnp.float32)
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
              "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]

    def seq_loss(params, x):
        h = x
        for s in range(S):
            h = stage_fn(jax.tree.map(lambda a: a[s], params), h)
            if s < S - 1:   # wire casts to bf16; cotangent rounds through too
                h = h.astype(jnp.bfloat16).astype(jnp.float32)
        return jnp.sum(h ** 2)

    def pipe_loss(params, x):
        out = pipeline_apply(stage_fn, params, x, mesh, "stage",
                             scheme="none")
        return jnp.sum(out ** 2)

    ls, gs = jax.value_and_grad(seq_loss)(params, x)
    lp, gp = jax.value_and_grad(pipe_loss)(params, x)
    assert abs(float(ls - lp)) < 1e-4, (float(ls), float(lp))
    for k in gs:
        d = float(jnp.max(jnp.abs(gs[k] - gp[k])))
        m = float(jnp.max(jnp.abs(gs[k]))) + 1e-9
        assert d / m < 1e-5, (k, d, m)
    gxs = jax.grad(seq_loss, argnums=1)(params, x)
    gxp = jax.grad(pipe_loss, argnums=1)(params, x)
    assert float(jnp.max(jnp.abs(gxs - gxp))) < 1e-5
    print("GRAD_EQUIV_OK")
""")


TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    from repro.core.boundary import boundary_apply
    from repro.core.feedback import FeedbackState
    from repro.core.policy import CompressionPolicy, quant_policy, topk_policy
    from repro.data.synthetic import ImageClassData
    from repro.models import cnn
    from repro.optim.optimizers import (OptimizerConfig, apply_updates,
                                        init_opt_state)
    from repro.train.steps import make_cnn_train_step, xent_loss

    data = ImageClassData()
    opt = OptimizerConfig(kind="sgd", lr=0.05, momentum=0.9,
                          schedule="constant")
    params0 = cnn.init_pipeline_params(jax.random.PRNGKey(0), 2, width=8)

    def run(pol, steps=10):
        step = make_cnn_train_step(pol, opt, transport="pipeline")
        p, o = params0, init_opt_state(opt, params0)
        losses = []
        for i, (x, y, ids) in enumerate(data.epoch(50, 0)):
            if i >= steps:
                break
            p, o, _, m = step(p, o, [], jnp.asarray(x), jnp.asarray(y),
                              jnp.asarray(ids))
            losses.append(float(m["loss"]))
        return losses

    # q8: the real pipeline must track the simulated boundary step-for-step
    pol = CompressionPolicy(num_stages=2, boundary=quant_policy(8, 8))
    pipe = run(pol)

    def seq_loss(params, images, labels):
        x = cnn.pipeline_stem(params, images)
        n = params["stages"]["b0"]["conv1"].shape[0]
        for s in range(n):
            x = cnn.pipeline_stage_apply(
                jax.tree.map(lambda a: a[s], params["stages"]), x)
            if s < n - 1:
                z = jnp.zeros((0,))
                x, _ = boundary_apply(
                    pol.at(s), x,
                    FeedbackState(resid=z, mirror=z, agg=z, direction="fw"),
                    FeedbackState(resid=z, mirror=z, agg=z, direction="bw"),
                    jnp.zeros((x.shape[0],), jnp.int32))
        return xent_loss(cnn.pipeline_head(params, x), labels)

    @jax.jit
    def sstep(p, o, x, y):
        loss, g = jax.value_and_grad(seq_loss)(p, x, y)
        p, o = apply_updates(opt, p, g, o)
        return p, o, loss

    p, o = params0, init_opt_state(opt, params0)
    seq = []
    for i, (x, y, ids) in enumerate(data.epoch(50, 0)):
        if i >= len(pipe):
            break
        p, o, l = sstep(p, o, jnp.asarray(x), jnp.asarray(y))
        seq.append(float(l))
    for a, b in zip(pipe, seq):
        assert abs(a - b) < 0.02 * max(abs(b), 1.0), (pipe, seq)
    assert pipe[-1] < pipe[0], pipe

    # topk: training loss decreases through the sparse wire
    pipe_t = run(CompressionPolicy(num_stages=2,
                                   boundary=topk_policy(0.10)))
    assert pipe_t[-1] < pipe_t[0], pipe_t
    print("TRAIN_OK", pipe[-1], pipe_t[-1])
""")


# ---------------------------------------------------------------------------
# Error feedback over the real wire (subprocess: 2 host devices)
# ---------------------------------------------------------------------------

FEEDBACK_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.boundary import boundary_apply
    from repro.core.feedback import FeedbackState
    from repro.core.policy import BoundaryPolicy, aqsgd_policy, ef_policy
    from repro.core.compressors import quant
    from repro.transport.pipeline import pipeline_apply, init_feedback_state

    def fbs(arr, mode, direction):
        z = jnp.zeros((0,))
        return FeedbackState(resid=arr, mirror=z, agg=z, mode=mode,
                             direction=direction)

    S, B, D, MB = 2, 4, 16, 2
    MBSZ = B // MB
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params0 = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
               "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    LR = 0.05

    def pipe_train(bp, num_samples, steps, seed=0, schedule="gpipe"):
        '''SGD-train through the real wire; returns (losses, final state).'''
        st = init_feedback_state(bp, (D,), num_stages=S, batch=B,
                                 num_samples=num_samples)
        params = params0

        @jax.jit
        def train_step(params, fw_state, bw_state, x, ids):
            def loss_fn(params, bw_state):
                y, new_fw = pipeline_apply(
                    stage_fn, params, x, mesh, "stage", policy=bp,
                    schedule=schedule,
                    fw_state=fw_state, bw_state=bw_state, ids=ids)
                return jnp.sum(y.astype(jnp.float32) ** 2) / B, new_fw
            (l, new_fw), (g, new_bw) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(params, bw_state)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            return params, new_fw, new_bw, l

        rng = np.random.RandomState(seed)
        losses = []
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            n = max(num_samples, B)
            ids = jnp.asarray(rng.permutation(n)[:B], jnp.int32)
            params, fw, bw, l = train_step(params, st["fw"], st["bw"],
                                           x, ids)
            st = {"fw": fw, "bw": bw}
            losses.append(float(l))
        return losses, st, params

    def sim_train(bp, num_samples, steps, seed=0):
        '''Reference: simulated boundary applied per microbatch (the GPipe
        schedule the pipeline runs), same SGD.'''
        if bp.feedback == "aqsgd":
            fw = jnp.zeros((num_samples, D))
        elif bp.feedback != "none":
            fw = jnp.zeros((B, D))
        else:
            fw = jnp.zeros((0,))
        bw = jnp.zeros((B, D)) if bp.bw_feedback != "none" else jnp.zeros((0,))
        params = params0

        @jax.jit
        def train_step(params, fw_buf, bw_buf, x, ids):
            def loss_fn(params, bw_buf):
                ys, nfs = [], []
                fwb = fw_buf
                for j in range(MB):
                    sl = slice(j * MBSZ, (j + 1) * MBSZ)
                    fb = (fwb if bp.feedback == "aqsgd" else
                          (fwb[sl] if bp.feedback != "none"
                           else jnp.zeros((0,))))
                    bb = (bw_buf[sl] if bp.bw_feedback != "none"
                          else jnp.zeros((0,)))
                    h = stage_fn(jax.tree.map(lambda a: a[0], params), x[sl])
                    h, nf = boundary_apply(bp, h, fbs(fb, bp.feedback, "fw"),
                                           fbs(bb, bp.bw_feedback, "bw"),
                                           ids[sl])
                    nf = nf.resid
                    if bp.feedback == "aqsgd":
                        fwb = nf
                    h = stage_fn(jax.tree.map(lambda a: a[1], params), h)
                    ys.append(h)
                    nfs.append(nf)
                y = jnp.concatenate(ys, 0)
                nf = (fwb if bp.feedback == "aqsgd" else
                      (jnp.concatenate(nfs, 0) if bp.feedback != "none"
                       else fw_buf))
                return jnp.sum(y.astype(jnp.float32) ** 2) / B, nf
            (l, new_fw), (g, new_bw) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(params, bw_buf)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            return params, new_fw, new_bw, l

        rng = np.random.RandomState(seed)
        losses = []
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            n = max(num_samples, B)
            ids = jnp.asarray(rng.permutation(n)[:B], jnp.int32)
            params, fw, bw, l = train_step(params, fw, bw, x, ids)
            losses.append(float(l))
        return losses, (fw, bw), params
""")


FEEDBACK_EQUIV_SCRIPT = FEEDBACK_COMMON + textwrap.dedent("""
    # (a) EF / AQ-SGD training through the real wire tracks the simulated
    # boundary STEP-FOR-STEP (q8: the wire roundtrip is bit-identical to
    # the dense compressor, so the bar is float accumulation error)
    q8 = quant(8)
    for bp, ns, tag in [
        (BoundaryPolicy(fw=q8, bw=q8, feedback="ef", bw_feedback="ef"),
         0, "ef"),
        (BoundaryPolicy(fw=q8, bw=q8, feedback="ef21", bw_feedback="ef21"),
         0, "ef21"),
        (BoundaryPolicy(fw=q8, bw=q8, feedback="aqsgd"), 12, "aqsgd"),
    ]:
        pl, pst, pp = pipe_train(bp, ns, steps=6)
        slr, (sfw, sbw), sp = sim_train(bp, ns, steps=6)
        for t, (a, b) in enumerate(zip(pl, slr)):
            assert abs(a - b) < 1e-4 * max(abs(b), 1.0), (tag, t, pl, slr)
        dp = max(float(jnp.max(jnp.abs(pp[k] - sp[k]))) for k in pp)
        assert dp < 1e-4, (tag, dp)
        # pipeline cut-0 buffer == simulated buffer (stage 0 owns cut 0)
        if bp.feedback == "aqsgd":
            d = float(jnp.max(jnp.abs(pst["fw"].resid[0] - sfw)))
            dm = float(jnp.max(jnp.abs(pst["fw"].mirror[1] - sfw)))
            assert d < 1e-4 and dm < 1e-4, (tag, d, dm)
        else:
            d = float(jnp.max(jnp.abs(
                pst["fw"].resid[0].reshape(B, D) - sfw)))
            assert d < 1e-4, (tag, d)
        print(tag, "tracks simulated:", pl[-1], slr[-1])

    # (b) AQ-SGD buffers update ONLY the example ids actually seen
    bp = BoundaryPolicy(fw=q8, bw=q8, feedback="aqsgd")
    st = init_feedback_state(bp, (D,), num_stages=S, batch=B, num_samples=16)
    seen = jnp.asarray([3, 7, 11, 1], jnp.int32)
    def loss_fn(params, bw_state, fw_state, x):
        y, new_fw = pipeline_apply(stage_fn, params, x, mesh, "stage",
                                   policy=bp, fw_state=fw_state,
                                   bw_state=bw_state, ids=seen)
        return jnp.sum(y ** 2), new_fw
    x = jax.random.normal(jax.random.PRNGKey(5), (B, D))
    (_, nf), _ = jax.value_and_grad(loss_fn, has_aux=True)(
        params0, st["bw"], st["fw"], x)
    touched = np.nonzero(np.asarray(
        jnp.any(nf.resid[0].reshape(16, -1) != 0, axis=-1)))[0]
    assert set(touched) <= set(np.asarray(seen).tolist()), touched
    assert len(touched) == B, touched

    # (c) feedback='none': size-0 buffers ride the scan carry untouched
    none_bp = BoundaryPolicy(fw=q8, bw=q8)
    st0 = init_feedback_state(none_bp, (D,), num_stages=S, batch=B)
    assert all(st0[d].resid.shape == (S, 0)
               and st0[d].mirror.shape == (S, 0) for d in ("fw", "bw")), st0
    y, nf0 = pipeline_apply(stage_fn, params0, x, mesh, "stage",
                            policy=none_bp, fw_state=st0["fw"],
                            bw_state=st0["bw"])
    assert nf0.resid.shape == (S, 0) and nf0.mirror.shape == (S, 0), nf0
    print("FEEDBACK_EQUIV_OK")
""")


FEEDBACK_TOPK_SCRIPT = FEEDBACK_COMMON + textwrap.dedent("""
    # AQ-SGD + TopK (paper Table 4 config) over the real wire: training
    # tracks the simulated boundary step-for-step.  TopK wire values ride
    # as bf16 while the dense compressor keeps fp32, so the bar is a loss
    # tolerance over a short horizon (selection is discontinuous: a tie
    # flip separates otherwise-equivalent trajectories).
    for bp, ns, tag in [(aqsgd_policy(0.3), 12, "aqsgd+top30"),
                        (ef_policy(0.3, "ef"), 0, "ef+top30")]:
        pl, _, _ = pipe_train(bp, ns, steps=5)
        sl, _, _ = sim_train(bp, ns, steps=5)
        for t, (a, b) in enumerate(zip(pl, sl)):
            assert abs(a - b) < 0.03 * max(abs(b), 1.0), (tag, t, pl, sl)
        print(tag, "tracks simulated:", pl[-1], sl[-1])

    # and compensated TopK training makes progress through the real wire
    pl, _, _ = pipe_train(aqsgd_policy(0.3), 12, steps=10)
    assert pl[-1] < pl[0], pl
    print("FEEDBACK_TOPK_OK")
""")


FEEDBACK_DP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.policy import BoundaryPolicy
    from repro.core.compressors import quant
    from repro.launch.mesh import make_dp_pipeline_mesh
    from repro.transport.pipeline import pipeline_apply, init_feedback_state
    from repro.transport.collectives import (init_dp_state,
                                             make_grad_all_reduce)

    DP, S, B, D, MB = 2, 2, 8, 16, 2
    SH = B // DP                          # per-replica shard
    mesh = make_dp_pipeline_mesh(DP, S)
    mesh1 = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params0 = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
               "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    LR = 0.05
    q8 = quant(8)

    def dp_train(bp, steps, num_samples=0, ids_fn=None):
        '''2x2 mesh: boundary feedback states carry a leading (dp,) dim;
        gradients reduce EXACTLY (codec none), so any trajectory drift vs
        the per-shard solo reference is the boundary feedback itself.'''
        st = init_feedback_state(bp, (D,), num_stages=S, batch=B,
                                 microbatches=MB, num_samples=num_samples,
                                 dp=DP)
        reduce_fn = make_grad_all_reduce(mesh, "data", "none")
        dpst = init_dp_state(params0, DP, "none")

        @jax.jit
        def train_step(params, fw_state, bw_state, dpst, x, ids):
            pdp = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (DP, *a.shape)), params)
            def loss_fn(pdp, bw_state):
                y, new_fw = pipeline_apply(
                    stage_fn, pdp, x, mesh, "stage", policy=bp,
                    microbatches=MB, dp_axis="data",
                    fw_state=fw_state, bw_state=bw_state, ids=ids)
                return jnp.sum(y.astype(jnp.float32) ** 2) / B, new_fw
            (l, new_fw), (g_dp, new_bw) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(pdp, bw_state)
            g, dpst = reduce_fn(g_dp, dpst)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            return params, new_fw, new_bw, dpst, l

        rng = np.random.RandomState(0)
        params, losses = params0, []
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            ids = (ids_fn(rng) if ids_fn is not None
                   else jnp.zeros((B,), jnp.int32))
            params, fw, bw, dpst, l = train_step(
                params, st["fw"], st["bw"], dpst, x, ids)
            st = {"fw": fw, "bw": bw}
            losses.append(float(l))
        return losses, st, params

    def solo_train(bp, steps, num_samples=0, ids_fn=None):
        '''Reference: each replica's shard through the SAME single-replica
        pipeline program with its own feedback state; shard grads summed
        serially (what an exact DP reduce computes).'''
        ns_sh = num_samples // DP if num_samples else 0
        sts = [init_feedback_state(bp, (D,), num_stages=S, batch=SH,
                                   microbatches=MB, num_samples=ns_sh)
               for _ in range(DP)]

        @jax.jit
        def shard_grad(params, fw_state, bw_state, xs, ids):
            def loss_fn(params, bw_state):
                y, new_fw = pipeline_apply(
                    stage_fn, params, xs, mesh1, "stage", policy=bp,
                    microbatches=MB, fw_state=fw_state,
                    bw_state=bw_state, ids=ids)
                return jnp.sum(y.astype(jnp.float32) ** 2) / B, new_fw
            (l, new_fw), (g, new_bw) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(params, bw_state)
            return l, g, new_fw, new_bw

        rng = np.random.RandomState(0)
        params, losses = params0, []
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            ids = (ids_fn(rng) if ids_fn is not None
                   else jnp.zeros((B,), jnp.int32))
            ltot, g = 0.0, None
            for r in range(DP):
                sl = slice(r * SH, (r + 1) * SH)
                lids = ids[sl] - r * ns_sh     # replica-local buffer rows
                l, gr, nf, nb = shard_grad(params, sts[r]["fw"],
                                           sts[r]["bw"], x[sl], lids)
                sts[r] = {"fw": nf, "bw": nb}
                ltot = ltot + l
                g = gr if g is None else jax.tree.map(jnp.add, g, gr)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            losses.append(float(ltot))
        return losses, sts, params

    # (a) EF / EF21 boundary feedback + dp: the 2x2 run tracks the
    # per-shard solo reference step-for-step, and replica r's slice of
    # the sharded feedback state equals solo run r's state
    for mode in ("ef", "ef21"):
        bp = BoundaryPolicy(fw=q8, bw=q8, feedback=mode, bw_feedback=mode)
        dl, dst, dparams = dp_train(bp, 6)
        slr, ssts, sparams = solo_train(bp, 6)
        for t, (a, b) in enumerate(zip(dl, slr)):
            assert abs(a - b) < 1e-4 * max(abs(b), 1.0), (mode, t, dl, slr)
        dmax = max(float(np.max(np.abs(
            np.asarray(dparams[k]) - np.asarray(sparams[k]))))
            for k in dparams)
        assert dmax < 1e-4, (mode, dmax)
        for r in range(DP):
            for dname in ("fw", "bw"):
                d = float(np.max(np.abs(
                    np.asarray(dst[dname].resid)[r]
                    - np.asarray(ssts[r][dname].resid))))
                assert d < 1e-4, (mode, dname, r, d)
        print(mode, "+dp tracks per-shard solo:", dl[-1], slr[-1])

    # (b) AQ-SGD + dp: id-sharded buffers — with the routing contract
    # (example i lives on replica i // (NS/DP)) training matches the
    # per-shard solo reference and each replica touches ONLY its rows
    NS = 16
    PER = NS // DP
    bp = BoundaryPolicy(fw=q8, bw=q8, feedback="aqsgd")

    def routed_ids(rng):
        return jnp.asarray(np.concatenate(
            [rng.permutation(PER)[:SH] + r * PER for r in range(DP)]),
            jnp.int32)

    dl, dst, dparams = dp_train(bp, 5, num_samples=NS, ids_fn=routed_ids)
    slr, ssts, sparams = solo_train(bp, 5, num_samples=NS,
                                    ids_fn=routed_ids)
    for t, (a, b) in enumerate(zip(dl, slr)):
        assert abs(a - b) < 1e-4 * max(abs(b), 1.0), (t, dl, slr)
    for r in range(DP):
        d = float(np.max(np.abs(np.asarray(dst["fw"].resid)[r]
                                - np.asarray(ssts[r]["fw"].resid))))
        assert d < 1e-4, (r, d)
    print("aqsgd+dp tracks per-shard solo:", dl[-1], slr[-1])

    # single known step: the touched buffer rows are EXACTLY the local
    # ids each replica saw (gather/scatter stayed replica-local)
    st = init_feedback_state(bp, (D,), num_stages=S, batch=B,
                             microbatches=MB, num_samples=NS, dp=DP)
    ids = jnp.asarray([3, 7, 1, 5, 10, 14, 8, 12], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, D))
    pdp = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (DP, *a.shape)), params0)
    def one(pdp, bw_state):
        y, new_fw = pipeline_apply(stage_fn, pdp, x, mesh, "stage",
                                   policy=bp, microbatches=MB,
                                   dp_axis="data", fw_state=st["fw"],
                                   bw_state=bw_state, ids=ids)
        return jnp.sum(y.astype(jnp.float32) ** 2), new_fw
    (_, nf), _ = jax.value_and_grad(one, has_aux=True)(pdp, st["bw"])
    for r, local in ((0, {3, 7, 1, 5}), (1, {2, 6, 0, 4})):
        rows = np.asarray(jnp.any(
            nf.resid[r][0].reshape(PER, D) != 0, axis=-1))
        touched = set(np.nonzero(rows)[0].tolist())
        assert touched == local, (r, touched, local)
    print("FEEDBACK_DP_OK")
""")


# ---------------------------------------------------------------------------
# Pipeline schedules (transport/schedules.py)
# ---------------------------------------------------------------------------

SCHEDULE_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.transport.pipeline import pipeline_apply
    S, B, D, MB = 2, 8, 16, 8
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
              "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    x = jax.random.normal(key, (B, D), jnp.float32)

    def loss(sched, scheme):
        def f(p, xx):
            out = pipeline_apply(stage_fn, p, xx, mesh, "stage",
                                 scheme=scheme, microbatches=MB,
                                 schedule=sched)
            return jnp.sum(out.astype(jnp.float32) ** 2)
        return jax.value_and_grad(f)(params, x)

    # 1F1B (rematerialized ticks + fused single-buffer hops) is the SAME
    # math as GPipe — bit-for-bit, loss AND grads, with microbatches >>
    # stages, compressed or not
    for scheme in ("none", "q8"):
        lg, gg = loss("gpipe", scheme)
        lf, gf = loss("1f1b", scheme)
        assert float(lg) == float(lf), (scheme, float(lg), float(lf))
        for k in gg:
            assert np.array_equal(np.asarray(gg[k]), np.asarray(gf[k])), \\
                (scheme, k)
        print("1f1b == gpipe bitwise:", scheme, float(lg))

    # interleaved validation: microbatch count must tile the stage count
    try:
        pipeline_apply(stage_fn, params, x, mesh, "stage", scheme="none",
                       microbatches=3, schedule="interleaved",
                       virtual_stages=2)
        raise SystemExit("interleaved mb % S accepted")
    except ValueError as e:
        assert "divisible" in str(e), e
    print("SCHEDULE_EQUIV_OK")
""")


SCHEDULE_INTERLEAVED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.boundary import boundary_apply
    from repro.core.compressors import quant
    from repro.core.feedback import FeedbackState
    from repro.core.policy import BoundaryPolicy, quant_policy
    from repro.transport.pipeline import (init_feedback_state,
                                          pipeline_apply)

    def fbs(arr, mode, direction):
        z = jnp.zeros((0,))
        return FeedbackState(resid=arr, mirror=z, agg=z, mode=mode,
                             direction=direction)

    S, V, B, D, MB = 2, 2, 8, 16, 4
    MBSZ = B // MB
    L = S * V
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    pL = {"w1": jax.random.normal(k1, (L, D, 2 * D)) * 0.1,
          "w2": jax.random.normal(k2, (L, 2 * D, D)) * 0.1}

    # (a) scheme='none' on bf16 activations: the wire cast is the identity,
    # so interleaved(v=2) must equal GPipe whose stage_fn composes the same
    # two chunks back to back — BIT FOR BIT in the loss — and must equal
    # the per-microbatch sequential reference with the wire cast at EVERY
    # logical cut bit-for-bit in loss AND grads.  (Composing chunks inside
    # one gpipe stage removes two backward-direction bf16 casts, so grads
    # vs composed-gpipe agree only to bf16 precision — the per-cut
    # reference is the exact semantic twin.)
    def chunk_fn(p, h):
        return (h + jnp.tanh(h @ p["w1"]) @ p["w2"]).astype(h.dtype)

    def composed_fn(p, h):      # gpipe stage = v chunks, no cut between
        for q in range(V):
            h = chunk_fn(jax.tree.map(lambda a: a[q], p), h)
        return h

    x16 = jax.random.normal(key, (B, D), jnp.float32).astype(jnp.bfloat16)
    p_dev = jax.tree.map(lambda a: a.reshape(S, V, *a.shape[1:]), pL)

    def g_loss(p, xx):
        out = pipeline_apply(composed_fn, p, xx, mesh, "stage",
                             scheme="none", microbatches=MB)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def cut_seq_loss(p, xx):
        hs = []
        for j in range(MB):
            h = xx[j * MBSZ:(j + 1) * MBSZ]
            for l in range(L):
                h = chunk_fn(jax.tree.map(lambda a: a[l], p), h)
                h = h.astype(jnp.bfloat16)       # the wire, at every cut
            hs.append(h)
        return jnp.sum(jnp.concatenate(hs).astype(jnp.float32) ** 2)

    def i_loss(p, xx):
        out = pipeline_apply(chunk_fn, p, xx, mesh, "stage", scheme="none",
                             microbatches=MB, schedule="interleaved",
                             virtual_stages=V)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    lg = g_loss(p_dev, x16)
    lc, gc = jax.value_and_grad(cut_seq_loss)(pL, x16)
    li, gi = jax.value_and_grad(i_loss)(pL, x16)
    assert float(lg) == float(li) == float(lc), \\
        (float(lg), float(li), float(lc))
    for k in gc:
        assert np.array_equal(np.asarray(gc[k]), np.asarray(gi[k])), k
    print("interleaved == gpipe loss bitwise; == per-cut sequential "
          "loss+grads bitwise (none/bf16):", float(li))

    # (b) q8: interleaved crosses 3 quantized cuts; the reference is the
    # SIMULATED boundary applied per microbatch at every logical cut —
    # matches to 1e-4 (straight-through bw compression included).
    bp = quant_policy(8, 8)
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    x = jax.random.normal(key, (B, D), jnp.float32)

    def seq_loss(p, xx):
        hs = []
        for j in range(MB):
            h = xx[j * MBSZ:(j + 1) * MBSZ]
            for l in range(L):
                h = stage_fn(jax.tree.map(lambda a: a[l], p), h)
                if l < L - 1:
                    h, _ = boundary_apply(bp, h,
                                          fbs(jnp.zeros((0,)), "none", "fw"),
                                          fbs(jnp.zeros((0,)), "none", "bw"),
                                          jnp.zeros((MBSZ,), jnp.int32))
            hs.append(h)
        return jnp.sum(jnp.concatenate(hs).astype(jnp.float32) ** 2)

    def int_loss(p, xx):
        out = pipeline_apply(stage_fn, p, xx, mesh, "stage", scheme="q8",
                             microbatches=MB, schedule="interleaved",
                             virtual_stages=V)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    ls, gs = jax.value_and_grad(seq_loss)(pL, x)
    li, gi = jax.value_and_grad(int_loss)(pL, x)
    assert abs(float(ls - li)) < 1e-4 * max(abs(float(ls)), 1.0), \\
        (float(ls), float(li))
    for k in gs:
        d = float(jnp.max(jnp.abs(gs[k] - gi[k])))
        m = float(jnp.max(jnp.abs(gs[k]))) + 1e-9
        assert d / m < 1e-4, (k, d, m)
    print("interleaved q8 matches per-cut simulated boundary:",
          float(ls), float(li))

    # (c) feedback under interleaved: EF21+q8 both directions exercises
    # the chunk-indexed buffers — send slices, delta-coded recv MIRRORS,
    # and bw cotangent buffers all carry a (S, v, ...) chunk dim.  Cut
    # l = k*S + d maps to the fw sender's slot [l % S, l // S] and the
    # receiver-side slots [(l+1) % S, (l+1) // S].
    bp21 = BoundaryPolicy(fw=quant(8), bw=quant(8),
                          feedback="ef21", bw_feedback="ef21")
    st = init_feedback_state(bp21, (D,), num_stages=S, batch=B,
                             microbatches=MB, virtual_stages=V)
    ids0 = jnp.zeros((B,), jnp.int32)

    def pipe_fb_loss(p, bw_state):
        y, new_fw = pipeline_apply(stage_fn, p, x, mesh, "stage",
                                   policy=bp21, microbatches=MB,
                                   schedule="interleaved", virtual_stages=V,
                                   fw_state=st["fw"], bw_state=bw_state,
                                   ids=ids0)
        return jnp.sum(y.astype(jnp.float32) ** 2), new_fw
    (lp, nfp), (gp, nbp) = jax.value_and_grad(
        pipe_fb_loss, argnums=(0, 1), has_aux=True)(pL, st["bw"])

    fw0 = jnp.zeros((L - 1, B, D))

    def seq_fb_loss(p, bw_bufs):
        ys, nfs = [], []
        for j in range(MB):
            sl = slice(j * MBSZ, (j + 1) * MBSZ)
            h = x[sl]
            cut_nf = []
            for l in range(L):
                h = stage_fn(jax.tree.map(lambda a: a[l], p), h)
                if l < L - 1:
                    h, nf = boundary_apply(bp21, h,
                                           fbs(fw0[l, sl], "ef21", "fw"),
                                           fbs(bw_bufs[l, sl], "ef21", "bw"),
                                           ids0[sl])
                    cut_nf.append(nf.resid)
            ys.append(h)
            nfs.append(cut_nf)
        y = jnp.concatenate(ys, 0)
        nf_full = jnp.stack([
            jnp.concatenate([nfs[j][l] for j in range(MB)], 0)
            for l in range(L - 1)])
        return jnp.sum(y.astype(jnp.float32) ** 2), nf_full
    (lr, nfr), (gr, nbr) = jax.value_and_grad(
        seq_fb_loss, argnums=(0, 1), has_aux=True)(
            pL, jnp.zeros((L - 1, B, D)))

    assert abs(float(lp - lr)) < 1e-4 * max(abs(float(lr)), 1.0), \\
        (float(lp), float(lr))
    for k in gr:
        d = float(jnp.max(jnp.abs(gr[k] - gp[k])))
        m = float(jnp.max(jnp.abs(gr[k]))) + 1e-9
        assert d / m < 1e-4, (k, d, m)
    for l in range(L - 1):
        snd, rcv = (l % S, l // S), ((l + 1) % S, (l + 1) // S)
        for tag, got, want in [
                ("fw send", nfp.resid[snd].reshape(B, D), nfr[l]),
                ("fw mirror", nfp.mirror[rcv].reshape(B, D), nfr[l]),
                ("bw send", nbp.resid[rcv].reshape(B, D), nbr[l]),
                ("bw mirror", nbp.mirror[snd].reshape(B, D), nbr[l])]:
            d = float(jnp.max(jnp.abs(got - want)))
            assert d < 1e-4, (tag, l, d)
    print("interleaved EF21 buffers match per-cut simulated boundary")
    print("SCHEDULE_INTERLEAVED_OK")
""")


SCHEDULE_FEEDBACK_SCRIPT = FEEDBACK_COMMON + textwrap.dedent("""
    # EF / AQ-SGD buffers under 1F1B match the simulated boundary
    # step-for-step (q8 wire: exact roundtrip), exactly like the gpipe
    # acceptance test — the feedback machinery is schedule-agnostic.
    q8c = quant(8)
    for bp, ns, tag in [
        (BoundaryPolicy(fw=q8c, bw=q8c, feedback="ef", bw_feedback="ef"),
         0, "ef"),
        (BoundaryPolicy(fw=q8c, bw=q8c, feedback="aqsgd"), 12, "aqsgd"),
    ]:
        pl, pst, pp = pipe_train(bp, ns, steps=5, schedule="1f1b")
        slr, (sfw, sbw), sp = sim_train(bp, ns, steps=5)
        for t, (a, b) in enumerate(zip(pl, slr)):
            assert abs(a - b) < 1e-4 * max(abs(b), 1.0), (tag, t, pl, slr)
        dp = max(float(jnp.max(jnp.abs(pp[k] - sp[k]))) for k in pp)
        assert dp < 1e-4, (tag, dp)
        if bp.feedback == "aqsgd":
            d = float(jnp.max(jnp.abs(pst["fw"].resid[0] - sfw)))
            dm = float(jnp.max(jnp.abs(pst["fw"].mirror[1] - sfw)))
            assert d < 1e-4 and dm < 1e-4, (tag, d, dm)
        else:
            d = float(jnp.max(jnp.abs(
                pst["fw"].resid[0].reshape(B, D) - sfw)))
            assert d < 1e-4, (tag, d)
        print(tag, "under 1f1b tracks simulated:", pl[-1], slr[-1])
    print("SCHEDULE_FEEDBACK_OK")
""")


def _run_sub(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def test_pipeline_gradients_match_sequential_subprocess():
    """Satellite: 2-stage CPU gradient equivalence, scheme='none'."""
    r = _run_sub(GRAD_EQUIV_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "GRAD_EQUIV_OK" in r.stdout


@pytest.mark.slow
def test_pipeline_training_decreases_loss_subprocess():
    """Acceptance: 2-stage CNN training through the real ppermute path
    with q8 (tracks the simulated boundary step-for-step) and topk."""
    r = _run_sub(TRAIN_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "TRAIN_OK" in r.stdout


@pytest.mark.slow
def test_pipeline_feedback_matches_simulated_subprocess():
    """Acceptance (run explicitly in CI): EF/EF21/AQ-SGD training through
    the real compressed ppermute wire tracks the simulated boundary
    step-for-step (q8 — exact wire roundtrip); AQ-SGD buffers touch only
    the ids in flight; feedback='none' buffers stay size-0 in the carry."""
    r = _run_sub(FEEDBACK_EQUIV_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "FEEDBACK_EQUIV_OK" in r.stdout


def test_pipeline_feedback_topk_tracks_simulated_subprocess():
    """Paper Table 4 config (AQ-SGD + TopK) over the real wire: loss
    curves track the simulated boundary and training makes progress."""
    r = _run_sub(FEEDBACK_TOPK_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "FEEDBACK_TOPK_OK" in r.stdout


@pytest.mark.slow
def test_dp_pipeline_boundary_feedback_subprocess():
    """Acceptance (run explicitly in CI): boundary feedback on the 2x2
    DPxPP mesh.  EF / EF21 with dp-sharded buffers track a per-shard
    single-replica pipeline reference step-for-step (exact grad reduce
    isolates the feedback path), and AQ-SGD's id-sharded buffer touches
    only the example ids each replica saw."""
    r = _run_sub(FEEDBACK_DP_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "FEEDBACK_DP_OK" in r.stdout


# ---------------------------------------------------------------------------
# Schedule subsystem
# ---------------------------------------------------------------------------

class TestSchedulePlans:
    """Pure schedule-math checks: the per-tick plan simulated with numpy —
    no devices, no shard_map."""

    @pytest.mark.parametrize("s,v,mb", [(2, 1, 2), (2, 1, 8), (4, 1, 4),
                                        (2, 2, 4), (4, 2, 8), (2, 3, 6)])
    def test_every_pair_computed_once_in_dependency_order(self, s, v, mb):
        from repro.transport.schedules import get_schedule
        sched = (get_schedule("interleaved", v) if v > 1
                 else get_schedule("gpipe"))
        sched.validate(mb, s)
        ticks = sched.num_ticks(mb, s)
        when = {}                      # (logical stage, microbatch) -> tick
        for t in range(ticks):
            for d in range(s):
                pl = sched.plan(jnp.int32(t), jnp.int32(d), mb, s)
                if not bool(pl.valid):
                    continue
                lg = int(pl.k) * s + d
                key = (lg, int(pl.j))
                assert key not in when, key
                when[key] = t
                assert bool(pl.inject) == (lg == 0)
                assert bool(pl.last) == (lg == s * v - 1)
        assert len(when) == s * v * mb
        for (lg, j), t in when.items():
            if lg > 0:     # input produced one tick earlier, one hop away
                assert when[(lg - 1, j)] == t - 1, (lg, j)
        assert max(when.values()) == ticks - 1

    def test_bubble_and_cuts_model(self):
        from repro.transport.schedules import get_schedule
        g = get_schedule("gpipe")
        i2 = get_schedule("interleaved", 2)
        assert g.bubble_fraction(8, 4) == pytest.approx(3 / 11)
        assert i2.bubble_fraction(8, 4) == pytest.approx(3 / 19)
        assert i2.bubble_fraction(8, 4) < g.bubble_fraction(8, 4)
        assert g.wire_cuts(4) == 3 and i2.wire_cuts(4) == 7
        f = get_schedule("1f1b")
        assert f.bubble_fraction(8, 4) == g.bubble_fraction(8, 4)
        assert f.stash_microbatches(16, 4) == 4
        assert g.stash_microbatches(16, 4) == 16

    def test_registry_and_validation(self):
        from repro.transport.schedules import (as_schedule, get_schedule)
        with pytest.raises(ValueError):
            get_schedule("zero-bubble")
        with pytest.raises(ValueError):
            get_schedule("gpipe", 2).validate(4, 2)
        with pytest.raises(ValueError):
            get_schedule("1f1b", 2).validate(4, 2)
        with pytest.raises(ValueError):
            get_schedule("interleaved", 2).validate(3, 2)
        s = get_schedule("interleaved", 2)
        assert as_schedule(s) is s
        with pytest.raises(ValueError):
            as_schedule(s, virtual_stages=3)

    def test_nonpositive_microbatches_rejected(self):
        """Satellite: microbatches=0 used to silently mean 'stage count'."""
        from repro.transport.pipeline import pipeline_apply
        mesh = jax.make_mesh((1,), ("stage",))
        params = {"w": jnp.zeros((1, 4, 4))}
        x = jnp.zeros((4, 4))
        fn = lambda p, h: h @ p["w"]
        for bad in (0, -1, 2.5):
            with pytest.raises(ValueError, match="positive"):
                pipeline_apply(fn, params, x, mesh, "stage",
                               microbatches=bad)

    def test_params_leading_dim_checked(self):
        from repro.transport.pipeline import pipeline_apply
        mesh = jax.make_mesh((1,), ("stage",))
        params = {"w": jnp.zeros((3, 4, 4))}    # not S*v = 2
        x = jnp.zeros((4, 4))
        with pytest.raises(ValueError, match="leading dim"):
            pipeline_apply(lambda p, h: h @ p["w"], params, x, mesh,
                           "stage", schedule="interleaved",
                           virtual_stages=2)


class TestFusedPayload:
    @pytest.mark.parametrize("scheme", ("none", "q8", "q4", "topk"))
    def test_fuse_roundtrip_bitwise(self, scheme):
        from repro.transport.codecs import fuse_payload, unfuse_payload
        x = _x((4, 33), jnp.float32)
        p = pack_payload(x, scheme, 0.1)
        buf = fuse_payload(p)
        assert buf.dtype == jnp.uint8
        assert buf.size == wire_bytes(p)          # byte-identical wire cost
        q = unfuse_payload(buf, jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), p))
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_schedule_1f1b_matches_gpipe_subprocess():
    """Satellite: 1F1B == GPipe bit-for-bit (loss + grads, none and q8,
    microbatches >> stages) and interleaved rejects mb % S != 0."""
    r = _run_sub(SCHEDULE_EQUIV_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SCHEDULE_EQUIV_OK" in r.stdout


@pytest.mark.slow
def test_schedule_interleaved_matches_references_subprocess():
    """Acceptance (run explicitly in CI): interleaved(v=2) == composed
    GPipe bit-for-bit at scheme='none' on bf16, matches the per-cut
    simulated boundary to 1e-4 with q8 (loss + grads), and the
    chunk-indexed EF21 feedback buffers (send + delta-coded mirrors, both
    directions) match the per-cut simulated boundary."""
    r = _run_sub(SCHEDULE_INTERLEAVED_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SCHEDULE_INTERLEAVED_OK" in r.stdout


@pytest.mark.slow
def test_schedule_1f1b_feedback_matches_simulated_subprocess():
    """Acceptance (run explicitly in CI): EF/AQ-SGD buffers under the
    1F1B schedule match the simulated boundary step-for-step."""
    r = _run_sub(SCHEDULE_FEEDBACK_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SCHEDULE_FEEDBACK_OK" in r.stdout
