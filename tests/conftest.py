"""Shared test helpers.

``hypothesis_or_stubs`` lets the suite run (with property tests skipped)
when ``hypothesis`` is not installed — the tier-1 command must never die at
collection time on an optional dev dependency.  Install the full dev set
with ``pip install -r requirements-dev.txt`` to run the property tests too.
"""
import pytest


def hypothesis_or_stubs():
    """Returns ``(given, settings, st)`` — real hypothesis if available,
    otherwise stubs that mark each property test as skipped.

    Usage (top of a test module)::

        from conftest import hypothesis_or_stubs
        given, settings, st = hypothesis_or_stubs()
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        pass

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStubs:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the stubbed ``given`` never runs them)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    return given, settings, _StrategyStubs()
