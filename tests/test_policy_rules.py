"""Tests for the adaptive compression-policy rule engine (core/policy.py)
and the boundary-policy mode validation it builds on."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.policy import (BoundaryPolicy, BW_FEEDBACK_MODES,
                               CompressionPolicy, FEEDBACK_MODES, PolicyRule,
                               PolicyRules, parse_policy_rules, parse_rule,
                               resolve_policy, topk_policy)
from repro.core.compressors import topk


class TestBoundaryPolicyValidation:
    """Satellite: the flattened ``__post_init__`` mode checks — every
    rejected string raises, with the aqsgd-is-activations-only note."""

    @pytest.mark.parametrize("mode", ["aqsgd", "momentum", "EF", "ef-21", ""])
    def test_every_bad_bw_feedback_rejected(self, mode):
        assert mode not in BW_FEEDBACK_MODES
        with pytest.raises(ValueError, match="bad bw_feedback mode"):
            BoundaryPolicy(fw=topk(0.1), bw=topk(0.1), bw_feedback=mode)

    def test_aqsgd_bw_rejection_explains_why(self):
        with pytest.raises(ValueError, match="activations-only"):
            BoundaryPolicy(fw=topk(0.1), bw=topk(0.1), bw_feedback="aqsgd")

    @pytest.mark.parametrize("mode", ["q8", "EF21", "ef_mixed", ""])
    def test_every_bad_fw_feedback_rejected(self, mode):
        assert mode not in FEEDBACK_MODES
        with pytest.raises(ValueError, match="bad feedback mode"):
            BoundaryPolicy(fw=topk(0.1), bw=topk(0.1), feedback=mode)

    @pytest.mark.parametrize("mode", BW_FEEDBACK_MODES)
    def test_every_valid_bw_mode_accepted(self, mode):
        BoundaryPolicy(fw=topk(0.1), bw=topk(0.1), bw_feedback=mode)


class TestReuseIndicesFeedbackRejection:
    """Satellite: the pipeline's reuse_indices x feedback error names the
    conflicting fields and both valid configurations."""

    def test_message_names_fields_and_valid_configs(self):
        from repro.transport.pipeline import PipelineTransport
        bp = BoundaryPolicy(fw=topk(0.1), bw=topk(0.1), feedback="ef",
                            reuse_indices=True)
        with pytest.raises(NotImplementedError) as ei:
            PipelineTransport(bp, "stage", 4)
        msg = str(ei.value)
        assert "feedback='ef'" in msg and "bw_feedback='none'" in msg
        assert "(a) reuse_indices=True with feedback='none'" in msg
        assert "(b) feedback/bw_feedback modes with reuse_indices=False" \
            in msg


class TestRuleParsing:
    def test_plain_codec(self):
        r = parse_rule("q8")
        assert r == PolicyRule(codec="q8")
        assert r.matches(1, 0, "fw") and r.matches(10**9, 9, "bw")

    def test_full_spec(self):
        r = parse_rule("topk:0.25@size>=4096,depth<2,dir=fw")
        assert r.codec == "topk" and r.k_frac == 0.25
        assert r.matches(4096, 1, "fw")
        assert not r.matches(4095, 1, "fw")      # size below threshold
        assert not r.matches(4096, 2, "fw")      # too deep
        assert not r.matches(4096, 1, "bw")      # wrong direction
        assert r.name == "topk:0.25@dir=fw,size>=4096,depth<2"

    @pytest.mark.parametrize("spec,err", [
        ("zstd", "unknown rule codec"),
        ("topk:0", "k_frac"),
        ("topk:1.5", "k_frac"),
        ("q8@size=4096", "bad rule condition"),
        ("q8@banana", "bad rule condition"),
        ("", "empty"),
    ])
    def test_bad_specs_rejected(self, spec, err):
        with pytest.raises(ValueError, match=err):
            parse_policy_rules(spec)


class TestResolve:
    def test_degenerate_one_rule_equals_static(self):
        """The acceptance hinge: a one-rule set resolves to a policy that
        is ``==`` the hand-written static one, so it shares jit caches and
        reproduces static runs bit-for-bit."""
        rules = parse_policy_rules("topk:0.1")
        assert rules.resolve(4096) == CompressionPolicy(
            num_stages=4, boundary=topk_policy(0.1))
        # resolve_policy passes static policies through untouched
        static = CompressionPolicy(num_stages=4, boundary=topk_policy(0.1))
        assert resolve_policy(static, 4096) is static

    def test_degenerate_rule_trains_bitwise_like_static(self):
        from repro.data.synthetic import ImageClassData
        from repro.optim.optimizers import OptimizerConfig, init_opt_state
        from repro.train.steps import make_cnn_train_step
        from repro.models import cnn
        data = ImageClassData()
        opt = OptimizerConfig(kind="sgd", lr=0.05, momentum=0.9,
                              schedule="constant")
        static = CompressionPolicy(num_stages=4, boundary=topk_policy(0.1))
        sizes = [int(np.prod(s)) for s in cnn.boundary_shapes(8, data.image)]
        rules = PolicyRules((PolicyRule(codec="topk", k_frac=0.1),),
                            num_stages=4)

        def run(policy, boundary_feat=None):
            params = cnn.init_params(jax.random.PRNGKey(0), width=8)
            step = make_cnn_train_step(policy, opt,
                                       boundary_feat=boundary_feat)
            o = init_opt_state(opt, params)
            losses = []
            for i, (x, y, ids) in enumerate(data.epoch(20, 0)):
                if i >= 3:
                    break
                params, o, _, m = step(params, o, [], jnp.asarray(x),
                                       jnp.asarray(y), jnp.asarray(ids))
                losses.append(float(m["loss"]))
            return losses, params

        l_static, p_static = run(static)
        l_rules, p_rules = run(rules, boundary_feat=sizes)
        assert l_static == l_rules                       # float-exact
        for a, b in zip(jax.tree.leaves(p_static), jax.tree.leaves(p_rules)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_size_adaptive_resolves_distinct_codecs(self):
        rules = parse_policy_rules("q4@size>=65536;q8@size>=16384;none")
        pol = rules.resolve([128 * 1024, 32 * 1024, 4 * 1024])
        kinds = [pol.at(i).fw.name for i in range(3)]
        assert kinds == ["q4", "q8", "none"]
        assert len(set(kinds)) == 3

    def test_direction_rules_split_fw_bw(self):
        rules = parse_policy_rules("q4@dir=fw;q8@dir=bw")
        bp = rules.resolve(4096).at(0)
        assert bp.fw.name == "q4" and bp.bw.name == "q8"

    def test_unmatched_boundary_suggests_catch_all(self):
        rules = parse_policy_rules("q8@size>=65536")
        with pytest.raises(ValueError, match="catch-all"):
            rules.resolve(4096)

    def test_wrong_size_count_rejected(self):
        rules = parse_policy_rules("q8")
        with pytest.raises(ValueError, match="boundary sizes"):
            rules.resolve([4096, 4096])    # 3 boundaries, 2 sizes

    def test_train_step_requires_boundary_feat_for_rules(self):
        from repro.optim.optimizers import OptimizerConfig
        from repro.train.steps import make_cnn_train_step
        opt = OptimizerConfig(kind="sgd", lr=0.05, schedule="constant")
        with pytest.raises(ValueError, match="boundary_feat"):
            make_cnn_train_step(parse_policy_rules("q8"), opt)


class TestShardIds:
    """AQ-SGD id-sharding: the routing contract for dp example buffers."""

    def test_localizes_per_replica(self):
        from repro.core.feedback import shard_ids
        ids = jnp.array([8, 11, 9], jnp.int32)
        local = shard_ids(ids, replica=1, num_samples=16, dp=2)
        np.testing.assert_array_equal(np.asarray(local), [0, 3, 1])

    def test_indivisible_num_samples_rejected(self):
        from repro.core.feedback import shard_ids
        with pytest.raises(ValueError, match="num_samples"):
            shard_ids(jnp.zeros((2,), jnp.int32), 0, num_samples=10, dp=4)
