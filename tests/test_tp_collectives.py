"""Compressed tensor-parallel collectives (transport/tp_collectives.py).

In-process: mesh constructors, TPCollectives/init_tp_state validation,
the tp=1 degenerate passthrough, and the exact-vs-model wire cost of
``tp_wire_report`` per codec.  Subprocesses (forced host devices): the
tp=2 toy acceptance — codec="none" training BIT-IDENTICAL to a blocked
rank-ordered solo reference, q8+EF tracking it step for step — the LM
DPxTP step behind ``parallel=ParallelSpec``, and the 8-device 2x2x2
(data, stage, tensor) pipeline run.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.mesh import (make_3d_mesh, make_local_mesh,
                               make_tensor_mesh)
from repro.transport.codecs import wire_bytes
from repro.transport.tp_collectives import (TP_FEEDBACK_MODES,
                                            TPCollectives, init_tp_state,
                                            tp_apply, tp_payload_struct,
                                            tp_wire_report)


class TestMeshes:
    def test_3d_axis_names_and_shape(self):
        mesh = make_3d_mesh(1, 1, 1)
        assert mesh.axis_names == ("data", "stage", "tensor")
        assert dict(mesh.shape) == {"data": 1, "stage": 1, "tensor": 1}

    def test_local_mesh_uses_canonical_names(self):
        assert make_local_mesh().axis_names == ("data", "tensor")

    def test_bad_sizes_rejected(self):
        with pytest.raises(ValueError, match="tp"):
            make_tensor_mesh(0)
        with pytest.raises(ValueError, match="dp"):
            make_3d_mesh(0, 1, 1)

    def test_insufficient_devices(self):
        n = jax.device_count()
        with pytest.raises(RuntimeError, match="devices"):
            make_tensor_mesh(n + 1)
        with pytest.raises(RuntimeError, match="devices"):
            make_3d_mesh(n + 1, 1, 1)


class TestValidation:
    def test_tp_feedback_modes_are_the_tp_scoped_registry(self):
        assert set(TP_FEEDBACK_MODES) == {"none", "ef", "ef21"}

    def test_unknown_feedback_rejected(self):
        with pytest.raises(ValueError, match="unknown tp feedback"):
            TPCollectives(make_tensor_mesh(1), "tensor", codec="q8",
                          feedback="momentum")
        with pytest.raises(ValueError, match="unknown tp feedback"):
            init_tp_state((4, 8, 16), 2, "aqsgd")  # boundary-only mode

    def test_feedback_needs_a_lossy_codec(self):
        with pytest.raises(ValueError, match="nothing to compensate"):
            TPCollectives(make_tensor_mesh(1), "tensor", codec="none",
                          feedback="ef")

    def test_state_buffers_per_mode(self):
        feat, sites = (4, 8, 16), 3
        none = init_tp_state(feat, sites, "none")
        assert none.resid.size == 0 and none.mirror.size == 0
        ef = init_tp_state(feat, sites, "ef")
        assert ef.resid.shape == (sites, *feat) and ef.mirror.size == 0
        ef21 = init_tp_state(feat, sites, "ef21")
        assert ef21.mirror.shape == (sites, *feat) and ef21.resid.size == 0
        assert ef.scope == "tp"

    def test_wire_report_rejects_indivisible_seq(self):
        with pytest.raises(ValueError, match="not divisible"):
            tp_wire_report((4, 63, 32), 2, "q8")


class TestWireReport:
    FEAT = (4, 64, 32)

    @pytest.mark.parametrize("codec", ("none", "q8", "q4", "topk"))
    def test_exact_matches_cost_model(self, codec):
        rep = tp_wire_report(self.FEAT, 2, codec, k_frac=0.25)
        exact, model = rep["payload_bytes_per_hop"], rep["model_bytes"]
        assert abs(exact - model) <= 64 + 0.005 * model, rep
        assert rep["hops_per_collective"] == 1
        assert rep["wire_bytes_per_collective"] == exact
        assert rep["wire_bytes_per_forward"] == 2 * exact

    def test_compression_orders_bytes(self):
        by = {c: tp_wire_report(self.FEAT, 2, c)["payload_bytes_per_hop"]
              for c in ("none", "q8", "q4")}
        assert by["q4"] < by["q8"] < by["none"]

    def test_hops_scale_with_ring(self):
        r4 = tp_wire_report(self.FEAT, 4, "q8", sites=3)
        assert r4["hops_per_collective"] == 3
        assert (r4["wire_bytes_per_forward"]
                == 3 * 2 * 3 * r4["payload_bytes_per_hop"])

    def test_payload_struct_none_is_raw_bf16(self):
        shard = (4, 32, 32)
        struct = tp_payload_struct(shard, "none")
        assert wire_bytes(struct) == int(np.prod(shard)) * 2

    def test_collectives_wire_report_delegates(self):
        tpc = TPCollectives(make_tensor_mesh(1), "tensor", codec="q8")
        rep = tpc.wire_report(self.FEAT, sites=2)
        assert rep["tp"] == 1 and rep["hops_per_collective"] == 0
        assert rep["sites_per_forward"] == 2


def _mlp_stage_fn(tpc):
    """gather -> gelu MLP on the full activation -> reduce-scatter."""

    def fn(p, xl, rs, ms):
        if tpc.feedback == "ef":
            full, buf = tpc.gather_site(xl, rs[0])
            rs = rs.at[0].set(buf)
        else:
            full, _ = tpc.gather_site(xl, None)
        y = tpc.scatter(jax.nn.gelu(full @ p["w1"]) @ p["w2"])
        return y, rs, ms

    return fn


class TestTp1Passthrough:
    @pytest.mark.parametrize("codec", ("none", "q8"))
    def test_tp1_apply_is_identity(self, codec):
        """A 1-wide ring never packs: gather/scatter are exact even with a
        lossy codec configured, so solo programs are untouched."""
        tpc = TPCollectives(make_tensor_mesh(1), "tensor", codec=codec)
        rng = np.random.RandomState(0)
        d, f = 16, 32
        params = {"w1": jnp.asarray(rng.randn(d, f), jnp.float32),
                  "w2": jnp.asarray(rng.randn(f, d), jnp.float32)}
        x = jnp.asarray(rng.randn(2, 8, d), jnp.float32)
        y, _ = tp_apply(_mlp_stage_fn(tpc), params, x, tpc,
                        param_dims={"w1": 1, "w2": 0}, sites=1)
        ref = jax.nn.gelu(x @ params["w1"]) @ params["w2"]
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


# ---------------------------------------------------------------------------
# Subprocess acceptance (forced host devices)
# ---------------------------------------------------------------------------

TOY_TP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_tensor_mesh
    from repro.transport.tp_collectives import (TPCollectives,
                                                init_tp_state, tp_apply)

    TP, B, S, D, F, LR, STEPS = 2, 4, 16, 32, 64, 0.05, 4
    mesh = make_tensor_mesh(TP)
    rng = np.random.RandomState(0)
    w1 = jnp.asarray(rng.randn(D, F) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.randn(F, D) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, D), jnp.float32)
    tgt = jnp.asarray(rng.randn(B, S, D), jnp.float32)

    def stage_fn(tpc):
        def fn(p, xl, rs, ms):
            if tpc.feedback == "ef":
                full, buf = tpc.gather_site(xl, rs[0])
                rs = rs.at[0].set(buf)
            else:
                full, _ = tpc.gather_site(xl, None)
            y = tpc.scatter(jax.nn.gelu(full @ p["w1"]) @ p["w2"])
            return y, rs, ms
        return fn

    def run_tp(codec, feedback="none"):
        tpc = TPCollectives(mesh, "tensor", codec=codec, feedback=feedback)
        state = init_tp_state((B, S, D), 1, feedback)
        fn = stage_fn(tpc)

        @jax.jit
        def step(params, state):
            def loss_fn(p):
                y, ns = tp_apply(fn, p, x, tpc,
                                 param_dims={"w1": 1, "w2": 0},
                                 state=state, sites=1)
                return jnp.mean((y - tgt) ** 2), ns
            (loss, ns), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params)
            new = jax.tree.map(lambda p, d: p - LR * d, params, g)
            return new, ns, loss

        params, losses = {"w1": w1, "w2": w2}, []
        for _ in range(STEPS):
            params, state, loss = step(params, state)
            losses.append(float(loss))
        return losses, params

    def run_solo():
        # the blocked rank-ordered reference: same LOCAL matmul shapes,
        # partial outputs summed in source-rank order s=0..tp-1 (every
        # sum is 2-term at tp=2, so association matches the wire's)
        f = F // TP

        @jax.jit
        def step(params):
            def loss_fn(p):
                y = None
                for s in range(TP):
                    h = jax.nn.gelu(x @ p["w1"][:, s * f:(s + 1) * f])
                    part = h @ p["w2"][s * f:(s + 1) * f, :]
                    y = part if y is None else y + part
                return jnp.mean((y - tgt) ** 2)
            loss, g = jax.value_and_grad(loss_fn)(params)
            new = jax.tree.map(lambda p, d: p - LR * d, params, g)
            return new, loss

        params, losses = {"w1": w1, "w2": w2}, []
        for _ in range(STEPS):
            params, loss = step(params)
            losses.append(float(loss))
        return losses, params

    # Forward pass: BITWISE.  Every wire op is a raw passthrough, the
    # gather concatenates in source-rank order and every reduce-scatter
    # sum is 2-term at tp=2, so the association matches the reference's.
    tpc0 = TPCollectives(mesh, "tensor", codec="none")
    fn0 = stage_fn(tpc0)

    @jax.jit
    def tp_fwd(params):
        y, _ = tp_apply(fn0, params, x, tpc0,
                        param_dims={"w1": 1, "w2": 0}, sites=1)
        return y

    f = F // TP

    @jax.jit
    def ref_fwd(params):
        y = None
        for s in range(TP):
            h = jax.nn.gelu(x @ params["w1"][:, s * f:(s + 1) * f])
            part = h @ params["w2"][s * f:(s + 1) * f, :]
            y = part if y is None else y + part
        return y

    assert np.array_equal(np.asarray(tp_fwd({"w1": w1, "w2": w2})),
                          np.asarray(ref_fwd({"w1": w1, "w2": w2})))
    print("TOY_TP_FWD_BITWISE_OK")

    # Training: ulp-level.  The wire adds NO error (w2's gradient comes
    # back bit-identical), but XLA may tile the dw1 dot_general's B*S
    # reduction differently across the two programs, and GSPMD reduces
    # the sharded scalar mean with a different association — both last-
    # ulp float effects, not codec loss.
    ref_losses, ref_params = run_solo()
    tp_losses, tp_params = run_tp("none")
    np.testing.assert_allclose(tp_losses, ref_losses, rtol=1e-5, atol=0)
    for k in ("w1", "w2"):
        np.testing.assert_allclose(
            np.asarray(tp_params[k]), np.asarray(ref_params[k]),
            rtol=1e-5, atol=1e-7, err_msg=k)
    print("TOY_TP_TRAIN_OK")

    q8_losses, _ = run_tp("q8", feedback="ef")
    assert all(np.isfinite(q8_losses)), q8_losses
    assert q8_losses[-1] < q8_losses[0], q8_losses
    for a, b in zip(q8_losses, ref_losses):
        assert abs(a - b) <= 0.2 * max(abs(b), 1.0), (a, b)
    print("TOY_TP_EF_OK")
""")


LM_DP_TP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.configs.registry import get
    from repro.core.parallel import AxisSpec, ParallelSpec
    from repro.core.policy import NO_POLICY
    from repro.data.synthetic import LMData
    from repro.train.loop import run_lm_experiment

    cfg = get("gpt2-small", smoke=True)

    def curve(spec):
        data = LMData(num_train=24, seq_len=32)
        return run_lm_experiment(cfg, NO_POLICY, epochs=1, batch=8,
                                 data=data, parallel=spec).train_curve

    solo = curve(ParallelSpec())
    tp2 = curve(ParallelSpec({"tensor": 2}))
    assert all(np.isfinite(tp2)), tp2
    for a, b in zip(tp2, solo):
        assert abs(a - b) <= 0.05 * max(abs(b), 1.0), (tp2, solo)
    print("LM_TP2_NONE_OK")

    q8 = curve(ParallelSpec({"tensor": AxisSpec(size=2, codec="q8",
                                                feedback="ef")}))
    for a, b in zip(q8, solo):
        assert abs(a - b) <= 0.2 * max(abs(b), 1.0), (q8, solo)
    print("LM_TP2_Q8EF_OK")

    dptp = curve(ParallelSpec({"data": 2, "tensor": 2}))
    assert all(np.isfinite(dptp)) and dptp[-1] < dptp[0], dptp
    for a, b in zip(dptp, solo):
        assert abs(a - b) <= 0.2 * max(abs(b), 1.0), (dptp, solo)
    print("LM_DP2_TP2_OK")
""")


LM_3D_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs.registry import get
    from repro.core.parallel import AxisSpec, ParallelSpec
    from repro.core.policy import NO_POLICY
    from repro.data.synthetic import LMData
    from repro.train.loop import run_lm_experiment

    cfg = get("gpt2-small", smoke=True)

    def curve(spec):
        data = LMData(num_train=24, seq_len=32)
        return run_lm_experiment(cfg, NO_POLICY, epochs=1, batch=8,
                                 data=data, parallel=spec).train_curve

    ref = curve(ParallelSpec({"data": 2,
                              "stage": AxisSpec(size=2, codec="q8")}))
    full = curve(ParallelSpec({"data": 2,
                               "stage": AxisSpec(size=2, codec="q8"),
                               "tensor": AxisSpec(size=2, codec="q4")}))
    assert all(np.isfinite(full)) and full[-1] < full[0], full
    for a, b in zip(full, ref):
        assert abs(a - b) <= 0.2 * max(abs(b), 1.0), (full, ref)
    print("LM_3D_OK")
""")


def _run_sub(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_toy_tp_acceptance_subprocess():
    """tp=2 gelu-MLP: the uncompressed wire is BITWISE on the forward
    pass vs the blocked rank-ordered solo reference, training matches to
    the ulp, and q8+EF tracks the reference step for step."""
    r = _run_sub(TOY_TP_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "TOY_TP_FWD_BITWISE_OK" in r.stdout
    assert "TOY_TP_TRAIN_OK" in r.stdout
    assert "TOY_TP_EF_OK" in r.stdout


@pytest.mark.slow
def test_lm_dp_tp_acceptance_subprocess():
    """2x1x2 DPxTP LM behind parallel=ParallelSpec: tp=2/none tracks solo
    tightly, q8+EF and the composed DPxTP mesh track it loosely."""
    r = _run_sub(LM_DP_TP_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr
    for tag in ("LM_TP2_NONE_OK", "LM_TP2_Q8EF_OK", "LM_DP2_TP2_OK"):
        assert tag in r.stdout, r.stdout


@pytest.mark.slow
def test_lm_3d_mesh_acceptance_subprocess():
    """All three axes at once (2x2x2, 8 devices): the q8-stage/q4-tensor
    pipeline trains and tracks the tp=1 pipeline reference."""
    r = _run_sub(LM_3D_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "LM_3D_OK" in r.stdout
