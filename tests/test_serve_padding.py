"""Regression: left-padded prompts in a mixed-length batch must generate
the same tokens as the same prompt served alone.

ServeEngine left-aligns prompts to the longest in the batch (left-pad with
token 0).  Without a padding mask the pad positions enter causal attention
as real context, so a short prompt's generation depends on who it is
batched with.  RoPE attention depends only on position DIFFERENCES, so
with pad slots masked the two servings are exactly equal.
"""
import numpy as np
import jax

from repro.configs.registry import get
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

NEW_TOKENS = 8


def _engine(arch="gpt2-small"):
    cfg = get(arch, smoke=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, max_batch=4, max_seq=64), cfg


def _gen(engine, prompts):
    reqs = [Request(np.asarray(p, np.int32), NEW_TOKENS) for p in prompts]
    return [r.out.copy() for r in engine.generate(reqs)]


def test_short_prompt_same_alone_and_batched():
    engine, cfg = _engine()
    rng = np.random.RandomState(3)
    short = rng.randint(1, cfg.vocab_size, 5)
    long_ = rng.randint(1, cfg.vocab_size, 19)

    alone = _gen(engine, [short])[0]
    batched = _gen(engine, [long_, short])[1]
    np.testing.assert_array_equal(alone, batched)


def test_equal_length_batch_unaffected():
    """No padding => the mask is a no-op: batching can't change outputs."""
    engine, cfg = _engine()
    rng = np.random.RandomState(5)
    a = rng.randint(1, cfg.vocab_size, 9)
    b = rng.randint(1, cfg.vocab_size, 9)
    alone = _gen(engine, [a])[0]
    batched = _gen(engine, [a, b])[0]
    np.testing.assert_array_equal(alone, batched)
