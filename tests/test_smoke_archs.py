"""Per-architecture smoke tests: reduced config (2 layer-groups, d<=256,
<=4 experts), one forward/train step + one decode step on CPU; assert
output shapes and no NaNs.  Exercises the same code paths the full dry-run
lowers, including compression boundaries (fw q4 / bw q8 policy)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, get
from repro.core.policy import CompressionPolicy, quant_policy
from repro.models import encdec, transformer
from repro.models.config import param_count

POLICY = CompressionPolicy(num_stages=2, boundary=quant_policy(4, 8))

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_forward_and_grad(arch):
    cfg = get(arch, smoke=True)
    mod = encdec if cfg.enc_dec else transformer
    key = jax.random.PRNGKey(0)
    params = mod.init_params(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux, _ = mod.forward_train(p, batch, cfg, POLICY)
        return transformer.lm_loss(logits, labels) + 0.01 * aux, logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.abs(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_then_decode(arch):
    cfg = get(arch, smoke=True)
    mod = encdec if cfg.enc_dec else transformer
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    cache_len = S + 4

    logits, state = mod.prefill(params, batch, cfg, POLICY,
                                cache_len=cache_len)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    token = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    for step in range(2):
        logits1, state = mod.decode_step(params, token, state,
                                         jnp.int32(S + step), cfg, POLICY)
        assert logits1.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits1, np.float32)))
        token = jnp.argmax(logits1, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_prefill_continuation(arch):
    """Teacher-forced decode over positions S..S+1 must equal a fresh
    prefill over S+2 tokens (cache correctness, incl. ring buffers)."""
    cfg = get(arch, smoke=True)
    mod = encdec if cfg.enc_dec else transformer
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    full = _batch(cfg, jax.random.PRNGKey(1))
    tokens = full["tokens"]
    cache_len = S

    short = dict(full, tokens=tokens[:, :S - 2])
    _, state = mod.prefill(params, short, cfg, cache_len=cache_len)
    # decode the next two ground-truth tokens
    logits_d = []
    for i in range(2):
        lg, state = mod.decode_step(params, tokens[:, S - 2 + i],
                                    state, jnp.int32(S - 2 + i), cfg)
        logits_d.append(lg)
    ref, _ = mod.prefill(params, full, cfg, cache_len=cache_len + 2)
    np.testing.assert_allclose(
        np.asarray(logits_d[-1], np.float32),
        np.asarray(ref[:, 0], np.float32), atol=0.35, rtol=0.1)


def test_param_count_sane():
    # full llama4 should be in the 300-500B range; glm4 in 8-12B
    n = param_count(get("llama4-maverick-400b-a17b"))
    assert 3.0e11 < n < 5.5e11, n
    n = param_count(get("glm4-9b"))
    assert 7e9 < n < 1.3e10, n
