"""Checkpoint satellites: full-train-state save/resume and restore errors.

``--resume`` must reproduce the interrupted run's trajectory EXACTLY —
that requires the optimizer moments and the error-feedback buffers in the
file, not just params (EF state is part of the training dynamics).
"""
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs.registry import get
from repro.core.boundary import init_boundary_state
from repro.core.policy import CompressionPolicy, ef_policy
from repro.launch.train import make_batch, synthetic_stream
from repro.models import transformer
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.train.steps import make_lm_train_step


class TestTrainStateRoundtrip:
    def test_resume_reproduces_trajectory_exactly(self, tmp_path):
        """6 straight steps == 3 steps -> save -> restore -> 3 more steps,
        bit-for-bit on params, moments, AND feedback buffers."""
        cfg = get("gpt2-small", smoke=True)
        pol = CompressionPolicy(num_stages=2, boundary=ef_policy(0.1, "ef21"))
        opt = OptimizerConfig(kind="adamw", lr=1e-3, weight_decay=0.01,
                              schedule="constant", grad_clip=1.0)
        step = make_lm_train_step(cfg, pol, opt, remat=False, donate=False)

        def init():
            params = transformer.init_params(jax.random.PRNGKey(0), cfg)
            return (params, init_opt_state(opt, params),
                    [init_boundary_state(pol.at(0), (16, cfg.d_model),
                                         batch=2, dtype=jnp.bfloat16)])

        def run(state, start, n):
            params, ostate, bst = state
            stream = synthetic_stream(cfg, 2, 16, seed=0, start_step=start)
            for _ in range(n):
                toks, ids = next(stream)
                params, ostate, bst, _ = step(params, ostate, bst,
                                              make_batch(cfg, toks),
                                              jnp.asarray(ids))
            return params, ostate, bst

        straight = run(init(), 0, 6)
        half = run(init(), 0, 3)
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_train_state(path, *half, step=3)
        p, o, b, step_no = ckpt_io.restore_train_state(path, *init())
        assert step_no == 3
        resumed = run((p, o, b), 3, 3)
        for name, s, r in zip(("params", "opt", "bstates"), straight,
                              resumed):
            for ls, lr in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
                np.testing.assert_array_equal(
                    np.asarray(ls, np.float32), np.asarray(lr, np.float32),
                    err_msg=f"{name} diverged after resume")

    def test_restore_params_reads_both_formats(self, tmp_path):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        old = str(tmp_path / "old.npz")
        new = str(tmp_path / "new.npz")
        ckpt_io.save(old, params, step=5)                     # params-only
        ckpt_io.save_train_state(new, params, {"step": jnp.zeros((),
                                                           jnp.int32)},
                                 [], step=9)                  # train-state
        for path, want in ((old, 5), (new, 9)):
            got, step_no = ckpt_io.restore_params(path, params)
            assert step_no == want
            np.testing.assert_array_equal(
                np.asarray(got["embed"], np.float32),
                np.asarray(params["embed"], np.float32))


class TestRestoreErrors:
    def _saved(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save(path, {"a": jnp.zeros((2, 3)), "b": jnp.ones((4,))},
                     step=1)
        return path

    def test_missing_extra_and_mismatch_all_listed(self, tmp_path):
        path = self._saved(tmp_path)
        like = {"a": jnp.zeros((9, 9)),         # shape mismatch
                "c": jnp.zeros((1,))}           # missing ("b" is extra)
        with pytest.raises(ckpt_io.CheckpointMismatch) as ei:
            ckpt_io.restore(path, like)
        msg = str(ei.value)
        assert re.search(r"missing keys \(1\): c", msg)
        assert "a: saved (2, 3) != expected (9, 9)" in msg
        assert re.search(r"extra keys in file \(1\): b", msg)

    def test_subset_restore_ignores_extras(self, tmp_path):
        path = self._saved(tmp_path)
        got, _ = ckpt_io.restore(path, {"b": jnp.zeros((4,))})
        np.testing.assert_array_equal(np.asarray(got["b"]), np.ones((4,)))

    def test_train_state_restore_is_strict(self, tmp_path):
        """Resuming with a DIFFERENT configuration (fewer boundaries here)
        must raise, not silently drop the leftover feedback buffers —
        dropping state fakes an exact resume."""
        path = str(tmp_path / "ck.npz")
        opt = {"step": jnp.zeros((), jnp.int32)}
        two_cuts = [{"fw": jnp.ones((2, 4)), "bw": jnp.ones((2, 4))}
                    for _ in range(2)]
        ckpt_io.save_train_state(path, {"w": jnp.ones((3,))}, opt,
                                 two_cuts, step=5)
        with pytest.raises(ckpt_io.CheckpointMismatch,
                           match=r"extra keys in file"):
            ckpt_io.restore_train_state(path, {"w": jnp.zeros((3,))}, opt,
                                        two_cuts[:1])   # one cut expected
        p, o, b, step = ckpt_io.restore_train_state(
            path, {"w": jnp.zeros((3,))}, opt, two_cuts)
        assert step == 5


class TestDPStateRoundtrip:
    """The DP gradient-reduce residuals (transport/collectives.py) are
    part of the trajectory: the train-state format saves them under a
    ``dp`` key and exact resume restores them."""

    def _state(self):
        from repro.transport.collectives import init_dp_state
        params = {"w": jnp.ones((3, 4))}
        opt = {"step": jnp.zeros((), jnp.int32)}
        dp_state = init_dp_state(params, 2, "ef21")
        dp_state = dp_state.replace(
            resid={"w": dp_state.resid["w"].at[0, 0, 0].set(3.5)},
            agg={"w": dp_state.agg["w"].at[1, 1].set(-2.0)})
        return params, opt, dp_state

    def test_dp_residuals_roundtrip_exactly(self, tmp_path):
        from repro.transport.collectives import init_dp_state
        params, opt, dp_state = self._state()
        path = str(tmp_path / "dp.npz")
        ckpt_io.save_train_state(path, params, opt, [], step=7,
                                 dp_state=dp_state)
        like = init_dp_state(params, 2, "ef21")
        p, o, b, dp2, step = ckpt_io.restore_train_state(
            path, params, opt, [], dp_like=like)
        assert step == 7 and b == []
        for a, c in zip(jax.tree.leaves(dp_state), jax.tree.leaves(dp2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_dp_file_without_dp_like_is_rejected(self, tmp_path):
        """Resuming a dp run without --dp must fail loudly, not silently
        drop the residuals."""
        params, opt, dp_state = self._state()
        path = str(tmp_path / "dp.npz")
        ckpt_io.save_train_state(path, params, opt, [], dp_state=dp_state)
        with pytest.raises(ckpt_io.CheckpointMismatch,
                           match=r"extra keys in file"):
            ckpt_io.restore_train_state(path, params, opt, [])

    def test_non_dp_file_with_dp_like_is_rejected(self, tmp_path):
        from repro.transport.collectives import init_dp_state
        params, opt, _ = self._state()
        path = str(tmp_path / "plain.npz")
        ckpt_io.save_train_state(path, params, opt, [])
        with pytest.raises(ckpt_io.CheckpointMismatch,
                           match=r"missing keys"):
            ckpt_io.restore_train_state(
                path, params, opt, [],
                dp_like=init_dp_state(params, 2, "ef"))

    def test_non_dp_format_unchanged(self, tmp_path):
        """dp_state=None writes the PR-4 file layout (no dp keys)."""
        params, opt, _ = self._state()
        path = str(tmp_path / "plain.npz")
        ckpt_io.save_train_state(path, params, opt, [])
        flat, _ = ckpt_io._load_flat(path)
        assert not any(k == "dp" or k.startswith("dp/") for k in flat)


class TestTrainDriverResume:
    def test_cli_save_every_and_resume(self, tmp_path):
        """--ckpt '{step}' templating + --resume continue the run from the
        right step and keep the deprecated --ckpt-every alias working."""
        import warnings
        from repro.launch.train import main
        tpl = str(tmp_path / "ck-{step}.npz")
        rc = main(["--arch", "gpt2-small", "--smoke", "--steps", "4",
                   "--batch", "2", "--seq", "16", "--log-every", "2",
                   "--ckpt", tpl, "--save-every", "2", "--no-remat"])
        assert rc == 0
        assert (tmp_path / "ck-2.npz").exists()
        assert (tmp_path / "ck-4.npz").exists()
        js = str(tmp_path / "resume.json")
        rc = main(["--arch", "gpt2-small", "--smoke", "--steps", "4",
                   "--batch", "2", "--seq", "16", "--log-every", "2",
                   "--resume", str(tmp_path / "ck-2.npz"), "--json", js,
                   "--no-remat"])
        assert rc == 0
        import json
        hist = json.load(open(js))
        assert [m["step"] for m in hist] == [4]   # resumed at 3, logged 4
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            rc = main(["--arch", "gpt2-small", "--smoke", "--steps", "2",
                       "--batch", "2", "--seq", "16", "--log-every", "2",
                       "--ckpt", str(tmp_path / "alias.npz"),
                       "--ckpt-every", "2", "--no-remat"])
        assert rc == 0
        assert any(issubclass(x.category, DeprecationWarning) for x in w)

class TestLegacyFormatMigration:
    """Files written before the unified ``feedback`` schema (PR-4 era
    ``bstates`` raw arrays / PR-5 era pipeline ``send``/``recv`` dicts +
    top-level ``dp``) must restore BITWISE through the key migration."""

    def _params_opt(self):
        params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "h": jnp.ones((2, 2), jnp.bfloat16) * 1.5}
        opt = {"step": jnp.asarray(4, jnp.int32)}
        return params, opt

    def test_simulated_era_bstates_restore_bitwise(self, tmp_path):
        from repro.core.feedback import init_feedback
        params, opt = self._params_opt()
        fw_buf = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
        # what PR-4's save_train_state flattened: raw per-direction arrays
        legacy = {"params": params, "opt": opt,
                  "bstates": [{"fw": fw_buf, "bw": jnp.zeros((0,))}]}
        path = str(tmp_path / "old.npz")
        ckpt_io.save(path, legacy, step=9,
                     extra={"format": "train-state"})
        like = [{"fw": init_feedback("ef", (16,), direction="fw", batch=8),
                 "bw": init_feedback("none", (), direction="bw")}]
        p, o, b, step = ckpt_io.restore_train_state(path, params, opt, like)
        assert step == 9
        np.testing.assert_array_equal(np.asarray(b[0]["fw"].resid),
                                      np.asarray(fw_buf))
        assert b[0]["fw"].mode == "ef" and b[0]["fw"].direction == "fw"
        assert b[0]["fw"].mirror.size == 0 and b[0]["fw"].agg.size == 0
        for a, c in zip(jax.tree.leaves(params), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_pipeline_era_send_recv_and_dp_restore_bitwise(self, tmp_path):
        from repro.core.feedback import FeedbackState
        from repro.transport.collectives import init_dp_state
        params, opt = self._params_opt()
        k = jax.random.PRNGKey(2)
        send = jax.random.normal(k, (2, 2, 4, 16))
        recv = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 4, 16))
        legacy = {
            "params": params, "opt": opt,
            "bstates": {"fw": {"send": send, "recv": recv},
                        "bw": {"send": jnp.zeros((2, 0)),
                               "recv": jnp.zeros((2, 0))}},
            "dp": {"resid": {"w": jnp.full((2, 3, 4), 0.25)},
                   "agg": jnp.zeros((0,))},
        }
        path = str(tmp_path / "old_pipe.npz")
        ckpt_io.save(path, legacy, step=5, extra={"format": "train-state"})
        z = jnp.zeros((0,))
        like = {"fw": FeedbackState(resid=jnp.zeros_like(send),
                                    mirror=jnp.zeros_like(recv), agg=z,
                                    mode="ef21", direction="fw"),
                "bw": FeedbackState(resid=jnp.zeros((2, 0)),
                                    mirror=jnp.zeros((2, 0)), agg=z,
                                    mode="none", direction="bw")}
        dp_like = init_dp_state({"w": jnp.zeros((3, 4))}, 2, "ef")
        p, o, b, dp, step = ckpt_io.restore_train_state(
            path, params, opt, like, dp_like=dp_like)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(b["fw"].resid),
                                      np.asarray(send))
        np.testing.assert_array_equal(np.asarray(b["fw"].mirror),
                                      np.asarray(recv))
        np.testing.assert_array_equal(np.asarray(dp.resid["w"]),
                                      np.full((2, 3, 4), 0.25))
        assert dp.scope == "dp" and dp.mode == "ef"
        assert dp.mirror.size == 0

    def test_new_format_has_unified_feedback_keys(self, tmp_path):
        from repro.core.feedback import init_feedback
        params, opt = self._params_opt()
        bst = [{"fw": init_feedback("ef", (4,), direction="fw", batch=2),
                "bw": init_feedback("none", (), direction="bw")}]
        path = str(tmp_path / "new.npz")
        ckpt_io.save_train_state(path, params, opt, bst, step=1)
        flat, _ = ckpt_io._load_flat(path)
        assert "feedback/boundary/0/fw/resid" in flat
        assert not any(k.startswith("bstates") for k in flat)

    def test_mismatch_lists_all_offending_keys(self, tmp_path):
        """CheckpointMismatch must name EVERY missing/extra key, not just
        the first — resuming with the wrong config should be one-shot
        debuggable."""
        from repro.core.feedback import init_feedback
        params, opt = self._params_opt()
        path = str(tmp_path / "plain.npz")
        ckpt_io.save_train_state(path, params, opt, [], step=1)
        like = [{"fw": init_feedback("ef", (4,), direction="fw", batch=2),
                 "bw": init_feedback("ef", (4,), direction="bw", batch=2)}]
        with pytest.raises(ckpt_io.CheckpointMismatch) as ei:
            ckpt_io.restore_train_state(path, {"bad": params["w"]}, opt,
                                        like)
        msg = str(ei.value)
        assert re.search(r"missing keys \(\d+\): .*bad", msg)
        assert "feedback/boundary/0/fw/resid" in msg
        assert re.search(r"extra keys in file \(\d+\): .*params/h", msg)
