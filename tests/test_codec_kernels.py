"""Kernel/jnp parity for the fused Pallas wire kernels (interpret mode).

Covers the four new kernel families and their transport-layer dispatch:

  * q4 pack/unpack (kernels/pack4.py)       — BIT-exact vs the jnp wire
    format, including odd feature dims (the in-kernel pad lane);
  * TopK select (kernels/topk_select.py)    — value/index SETS equal to
    ``lax.top_k`` modulo the documented tie order (ascending index vs
    descending value), dense scatter roundtrip bit-identical, and the
    uint16/int32 index boundary at n = 2**16 exactly;
  * payload framing (kernels/framing.py)    — byte-identical to the
    concat path, both directions;
  * DP decode+sum (kernels/dp_reduce.py)    — static rank-ordered fold:
    deterministic, replica-identical, and within 1 ulp of FMA rounding of
    the unfused reference loop;
  * ``unpack_payload`` exact key-SET dispatch + every registered codec's
    payload round-tripping through it;
  * the ``_pallas_tiling`` pow2 fix (kernels/tiling.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

import repro.core.compressors as C
from repro.kernels.tiling import full_row_block, pow2_row_block, wire_tiling
from repro.transport import codecs


@pytest.fixture
def pallas_backend():
    prev = C.KERNEL_BACKEND
    C.KERNEL_BACKEND = "pallas"
    yield
    C.KERNEL_BACKEND = prev


def _pack_both(name, x, k_frac=0.25):
    """(jnp payload, pallas payload) for one codec."""
    prev = C.KERNEL_BACKEND
    try:
        C.KERNEL_BACKEND = "jnp"
        pj = codecs.get_codec(name).pack(x, k_frac)
        C.KERNEL_BACKEND = "pallas"
        pp = codecs.get_codec(name).pack(x, k_frac)
    finally:
        C.KERNEL_BACKEND = prev
    return pj, pp


# ---------------------------------------------------------------------------
# tiling (the _pallas_tiling satellite fix)
# ---------------------------------------------------------------------------

class TestTiling:
    def test_pow2_row_block(self):
        assert pow2_row_block(256) == 256
        assert pow2_row_block(48) == 16
        assert pow2_row_block(13) == 1      # prime: O(1), no O(m) scan
        assert pow2_row_block(1 << 20) == 256

    def test_wire_tiling_underfilled_returns_none(self):
        assert wire_tiling((12, 256)) is None      # pow2(12)=4 < 8 sublanes
        assert wire_tiling((13, 256)) is None      # prime m
        assert wire_tiling((2, 1024)) is None
        assert wire_tiling((1, 128)) is None       # the DP (1, n) leaves

    def test_wire_tiling_fits(self):
        assert wire_tiling((16, 256)) == (16, 256)
        assert wire_tiling((8, 128)) == (8, 128)
        assert wire_tiling((512, 384)) == (256, 128)

    def test_wire_tiling_non_lane_multiple(self):
        assert wire_tiling((16, 100)) is None

    def test_codecs_delegate(self):
        assert codecs._pallas_tiling((16, 256)) == wire_tiling((16, 256))
        assert codecs._pallas_tiling((13, 256)) is None

    def test_full_row_block_divides_and_fits(self):
        for m in (1, 2, 12, 48, 256, 1000):
            for n in (7, 129, 4096):
                bm = full_row_block(m, n)
                assert m % bm == 0 and bm >= 1


# ---------------------------------------------------------------------------
# q4: bit-exact, including odd feature dims
# ---------------------------------------------------------------------------

Q4_SHAPES = [(4, 255), (8, 129), (2, 7), (8, 256), (1, 33), (16, 512)]


class TestQ4Kernel:
    @pytest.mark.parametrize("shape", Q4_SHAPES)
    def test_pack_bit_exact(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(0), shape)
        pj, pp = _pack_both("q4", x)
        assert set(pj) == set(pp) == {"codes4", "min", "scale"}
        for k in pj:
            np.testing.assert_array_equal(np.asarray(pj[k]),
                                          np.asarray(pp[k]), err_msg=k)

    @pytest.mark.parametrize("shape", Q4_SHAPES)
    def test_unpack_parity(self, shape, pallas_backend):
        # bytes-on-wire are bit-exact (above); the fused dequant may round
        # 1 ulp tighter where the compiler emits an FMA for codes*sc+mn.
        x = jax.random.normal(jax.random.PRNGKey(1), shape)
        p = codecs.get_codec("q4").pack(x)
        got = codecs.get_codec("q4").unpack(p, x.shape, jnp.float32)
        C.KERNEL_BACKEND = "jnp"
        want = np.asarray(codecs.get_codec("q4").unpack(p, x.shape,
                                                        jnp.float32))
        tol = 1.2e-7 * max(float(np.abs(want).max()), 1.0)
        np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=tol)

    def test_constant_tensor(self):
        x = jnp.full((4, 129), 3.25)
        pj, pp = _pack_both("q4", x)
        for k in pj:
            np.testing.assert_array_equal(np.asarray(pj[k]),
                                          np.asarray(pp[k]))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 9),
           n=st.integers(1, 300))
    def test_property_bit_exact(self, seed, m, n):
        x = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) \
            * jnp.exp(jax.random.normal(jax.random.PRNGKey(seed + 1), ()))
        pj, pp = _pack_both("q4", x)
        for k in pj:
            np.testing.assert_array_equal(np.asarray(pj[k]),
                                          np.asarray(pp[k]), err_msg=k)


# ---------------------------------------------------------------------------
# TopK: sets equal modulo documented tie order; u16/i32 boundary at 2**16
# ---------------------------------------------------------------------------

class TestTopKKernel:
    @pytest.mark.parametrize("shape,k_frac", [((4, 100), 0.25),
                                              ((8, 512), 0.1),
                                              ((2, 33), 0.5)])
    def test_sets_and_dense_roundtrip(self, shape, k_frac):
        x = jax.random.normal(jax.random.PRNGKey(2), shape)
        pj, pp = _pack_both("topk", x, k_frac)
        assert pj["idx"].shape == pp["idx"].shape
        assert pj["idx"].dtype == pp["idx"].dtype
        assert pj["vals"].dtype == pp["vals"].dtype == jnp.bfloat16
        for r in range(shape[0]):
            ij = set(np.asarray(pj["idx"][r]).tolist())
            ip = set(np.asarray(pp["idx"][r]).tolist())
            assert ij == ip, f"row {r}: index sets differ"
        dj = codecs.get_codec("topk").unpack(pj, x.shape, jnp.float32)
        dp = codecs.get_codec("topk").unpack(pp, x.shape, jnp.float32)
        np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))

    def test_exact_tie_handling(self):
        # more threshold ties than slots: the kernel must keep top_k's
        # lowest-index tie subset so the SET still matches exactly.
        x = jnp.array([[1.0, -2.0, 2.0, -2.0, 2.0, 0.5, -2.0, 0.0]])
        pj, pp = _pack_both("topk", x, 3 / 8)
        ij = set(np.asarray(pj["idx"][0]).tolist())
        ip = set(np.asarray(pp["idx"][0]).tolist())
        assert ij == ip == {1, 2, 3}

    @pytest.mark.parametrize("n,want_dtype", [(1 << 16, jnp.uint16),
                                              ((1 << 16) + 2, jnp.int32)])
    def test_index_dtype_boundary(self, n, want_dtype):
        x = jax.random.normal(jax.random.PRNGKey(3), (1, n))
        for backend in ("jnp", "pallas"):
            prev = C.KERNEL_BACKEND
            try:
                C.KERNEL_BACKEND = backend
                p = codecs.get_codec("topk").pack(x, 0.001)
            finally:
                C.KERNEL_BACKEND = prev
            assert p["idx"].dtype == want_dtype, backend
            d = codecs.get_codec("topk").unpack(p, x.shape, jnp.float32)
            kept = np.asarray(d != 0).sum()
            assert kept == max(1, int(round(0.001 * n))), backend

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           k=st.sampled_from([0.05, 0.1, 0.3, 0.5]),
           n=st.integers(4, 200))
    def test_property_set_parity(self, seed, k, n):
        x = jax.random.normal(jax.random.PRNGKey(seed), (3, n))
        pj, pp = _pack_both("topk", x, k)
        for r in range(3):
            assert (set(np.asarray(pj["idx"][r]).tolist())
                    == set(np.asarray(pp["idx"][r]).tolist()))
        dj = codecs.get_codec("topk").unpack(pj, x.shape, jnp.float32)
        dp = codecs.get_codec("topk").unpack(pp, x.shape, jnp.float32)
        np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))


# ---------------------------------------------------------------------------
# framing: byte-identical to the concat path
# ---------------------------------------------------------------------------

class TestFraming:
    PAYLOAD = {
        "a": jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4),
        "b": jnp.array([True, False, True]),
        "c": jnp.arange(7, dtype=jnp.uint8),
        "d": jnp.arange(5, dtype=jnp.bfloat16),
    }

    def test_fuse_byte_identical(self, pallas_backend):
        fp = codecs.fuse_payload(self.PAYLOAD)
        C.KERNEL_BACKEND = "jnp"
        fj = codecs.fuse_payload(self.PAYLOAD)
        assert fp.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(fp), np.asarray(fj))

    def test_unfuse_roundtrip(self, pallas_backend):
        buf = codecs.fuse_payload(self.PAYLOAD)
        out = codecs.unfuse_payload(buf, self.PAYLOAD)
        assert set(out) == set(self.PAYLOAD)
        for k, v in self.PAYLOAD.items():
            assert out[k].dtype == v.dtype and out[k].shape == v.shape
            np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))

    def test_real_codec_payloads(self, pallas_backend):
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 129))
        for name in codecs.registered_codecs():
            p = codecs.get_codec(name).pack(x, 0.25)
            buf = codecs.fuse_payload(p)
            C.KERNEL_BACKEND = "jnp"
            ref = codecs.fuse_payload(p)
            C.KERNEL_BACKEND = "pallas"
            np.testing.assert_array_equal(np.asarray(buf), np.asarray(ref),
                                          err_msg=name)
            out = codecs.unfuse_payload(buf, p)
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(p)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_single_leaf_skips_kernel(self, pallas_backend):
        p = {"raw": jnp.arange(6, dtype=jnp.bfloat16)}
        buf = codecs.fuse_payload(p)
        assert buf.size == 12


# ---------------------------------------------------------------------------
# unpack_payload: exact key-set dispatch, every registered codec
# ---------------------------------------------------------------------------

class TestUnpackDispatch:
    @pytest.mark.parametrize("name", codecs.registered_codecs())
    def test_every_codec_roundtrips(self, name):
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 256))
        p = codecs.get_codec(name).pack(x, 0.25)
        got = codecs.unpack_payload(p, x.shape, jnp.float32)
        want = codecs.get_codec(name).unpack(p, x.shape, jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_q8_tile_payload_dispatches(self, pallas_backend):
        # the per-tile Pallas q8 format {codes, tile_meta} must dispatch on
        # its own key set, not ride on "codes" probing first.
        x = jax.random.normal(jax.random.PRNGKey(6), (16, 256))
        p = codecs.get_codec("q8").pack(x)
        assert set(p) == {"codes", "tile_meta"}
        got = codecs.unpack_payload(p, x.shape, jnp.float32)
        err = np.abs(np.asarray(got) - np.asarray(x))
        assert err.max() < float(x.max() - x.min()) / 255 + 1e-5

    def test_unknown_keyset_raises(self):
        with pytest.raises(ValueError, match="no registered codec"):
            codecs.unpack_payload({"bogus": jnp.zeros(3)}, (1, 3))
        # a SUBSET of a known key set must not silently dispatch either
        with pytest.raises(ValueError, match="no registered codec"):
            codecs.unpack_payload({"codes": jnp.zeros((1, 4), jnp.uint8)},
                                  (1, 4))

    def test_keyset_collision_rejected(self):
        class Dup(codecs.NoneCodec):
            name = "dup"
        with pytest.raises(ValueError, match="already registered"):
            codecs.register_codec(Dup())
        assert "dup" in codecs._REGISTRY   # name slot written before check
        del codecs._REGISTRY["dup"]


# ---------------------------------------------------------------------------
# DP decode+sum: deterministic rank-ordered fold, ulp-close to the loop
# ---------------------------------------------------------------------------

GRADS_LIKE = {"w": jnp.zeros((4, 33)), "b": jnp.zeros((7,)),
              "v": jnp.zeros((2, 64))}


def _mesh(dp):
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:dp]).reshape(dp, 1)
    return Mesh(devs, ("data", "stages"))


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices")
class TestFusedDpDecodeSum:
    @pytest.mark.parametrize("codec", ["q8", "q4"])
    @pytest.mark.parametrize("feedback", ["none", "ef", "ef21"])
    def test_matches_reference_loop(self, codec, feedback):
        from repro.transport.collectives import (init_dp_state,
                                                 make_grad_all_reduce)
        dp = min(jax.device_count(), 4)
        mesh = _mesh(dp)
        g_dp = jax.tree.map(
            lambda a: jax.random.normal(jax.random.PRNGKey(7),
                                        (dp, *a.shape)), GRADS_LIKE)
        outs = {}
        prev = C.KERNEL_BACKEND
        try:
            for backend in ("jnp", "pallas"):
                C.KERNEL_BACKEND = backend
                red = make_grad_all_reduce(mesh, "data", codec,
                                           feedback=feedback)
                state = init_dp_state(GRADS_LIKE, dp, feedback)
                r, _ = red(g_dp, state)
                outs[backend] = jax.tree.map(np.asarray, r)
            # deterministic: the fused kernel twice -> bitwise equal
            C.KERNEL_BACKEND = "pallas"
            red = make_grad_all_reduce(mesh, "data", codec,
                                       feedback=feedback)
            state = init_dp_state(GRADS_LIKE, dp, feedback)
            r2, _ = red(g_dp, state)
        finally:
            C.KERNEL_BACKEND = prev
        for k in GRADS_LIKE:
            a, b = outs["jnp"][k], outs["pallas"][k]
            # static rank-ordered fold: only FMA contraction may differ,
            # bounded by 1 ulp per dequant across the dp-term sum.
            tol = dp * 1.2e-7 * max(np.abs(a).max(), 1.0)
            np.testing.assert_allclose(a, b, rtol=0, atol=tol)
            np.testing.assert_array_equal(outs["pallas"][k],
                                          np.asarray(r2[k]))

    @pytest.mark.parametrize("codec", ["q8", "q4"])
    def test_plans_engage_for_dp_leaves(self, codec, pallas_backend):
        from repro.kernels.dp_reduce import build_decode_plans
        from repro.transport.collectives import grad_payload_structs
        structs = grad_payload_structs(GRADS_LIKE, codec)
        plans = build_decode_plans(
            structs, [a.shape for a in jax.tree.leaves(GRADS_LIKE)])
        assert plans is not None
        kinds = {p.kind for p in plans}
        assert kinds == {codec}
        # odd leaf (7,): q4 codes are (n+1)//2 bytes
        ns = sorted(p.n for p in plans)
        assert ns == [7, 128, 132]

    def test_plans_reject_unsupported(self):
        from repro.kernels.dp_reduce import build_decode_plans
        from repro.transport.collectives import grad_payload_structs
        for codec in ("none", "topk"):
            structs = grad_payload_structs(GRADS_LIKE, codec)
            assert build_decode_plans(
                structs,
                [a.shape for a in jax.tree.leaves(GRADS_LIKE)]) is None

    def test_decode_sum_kernel_direct(self, pallas_backend):
        """Kernel vs hand loop on manually packed slots, incl. odd leaf."""
        from repro.kernels.dp_reduce import (build_decode_plans,
                                             decode_sum_fused)
        from repro.transport.collectives import (pack_grad_leaf,
                                                 unpack_grad_leaf)
        codec = codecs.get_codec("q4")
        dp = 3
        leaves = [jax.random.normal(jax.random.PRNGKey(i), (5, 33))
                  for i in range(dp)]
        payloads = [[pack_grad_leaf(codec, a)] for a in leaves]
        slots = jnp.stack([codecs.fuse_payload(p) for p in payloads])
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payloads[0])
        plans = build_decode_plans(struct, [(5, 33)])
        assert plans is not None
        got = decode_sum_fused(slots, plans, dp)[0].reshape(5, 33)
        want = sum(unpack_grad_leaf(codec, p[0], (5, 33))
                   for p in payloads)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=dp * 1.2e-7 * 10)
