"""Hypothesis property tests on the error-feedback algebra (paper Sec 2.4,
2.5) — the invariants that make compensation 'not lose information'."""
import numpy as np
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.core.compressors import topk, quant
from repro.core.feedback import (aqsgd_message, ef21_message, ef_message,
                                 efmixed_message)


def _x(seed, b=2, n=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n), jnp.float32)


class TestEFInvariants:
    @given(st.integers(0, 50), st.sampled_from([0.1, 0.25, 0.5]))
    @settings(max_examples=15, deadline=None)
    def test_ef_conserves_mass_exactly(self, seed, k):
        """m + e' == x + e — nothing is ever lost, only delayed."""
        x, e = _x(seed), _x(seed + 1)
        m, e2 = ef_message(topk(k), x, e)
        np.testing.assert_allclose(np.asarray(m + e2), np.asarray(x + e),
                                   rtol=1e-6)

    @given(st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_ef21_buffer_is_last_message(self, seed):
        x, g = _x(seed), _x(seed + 1)
        m, g2 = ef21_message(topk(0.25), x, g)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(g2))

    @given(st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_ef21_contracts_on_constant_stream(self, seed):
        """Repeatedly feeding the SAME x drives ||x - g|| -> 0 (the EF21
        convergence mechanism the paper relies on)."""
        x = _x(seed)
        g = jnp.zeros_like(x)
        errs = []
        for _ in range(12):
            _, g = ef21_message(topk(0.25), x, g)
            errs.append(float(jnp.linalg.norm(x - g)))
        assert errs[-1] < 0.25 * errs[0]
        assert errs[-1] <= errs[0]

    @given(st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_efmixed_mass_identity(self, seed):
        """EF-mixed keeps the same invariant as EF: m + e' == x + e."""
        x, e = _x(seed), _x(seed + 1)
        m, e2 = efmixed_message(topk(0.2), x, e)
        np.testing.assert_allclose(np.asarray(m + e2), np.asarray(x + e),
                                   rtol=1e-6)

    @given(st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_ef_with_quant_bounded_buffer(self, seed):
        """With unbiased-ish quantization the EF buffer stays bounded by
        one quantization step per element."""
        x = _x(seed)
        e = jnp.zeros_like(x)
        comp = quant(8)
        for _ in range(10):
            _, e = ef_message(comp, x, e)
        span = float(x.max() - x.min()) + 1.0
        assert float(jnp.abs(e).max()) < span  # no runaway growth


class TestAQSGDInvariants:
    @given(st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_per_example_isolation(self, seed):
        """Updating examples {0,1} must not touch buffers of {2,3}."""
        buf = jax.random.normal(jax.random.PRNGKey(seed), (4, 8))
        x = _x(seed + 1, b=2, n=8)
        ids = jnp.array([0, 1], jnp.int32)
        _, buf2 = aqsgd_message(topk(0.5), x, buf, ids)
        np.testing.assert_array_equal(np.asarray(buf[2:]),
                                      np.asarray(buf2[2:]))

    def test_second_visit_sends_smaller_residual(self):
        """The AQ-SGD premise: activations drift slowly, so the residual
        C(x - b) shrinks on revisits when x changes little."""
        buf = jnp.zeros((2, 64))
        x = _x(0, b=2)
        m1, buf = aqsgd_message(topk(0.25), x, buf, jnp.array([0, 1]))
        x2 = x + 0.01 * _x(1, b=2)
        m2, _ = aqsgd_message(topk(0.25), x2, buf, jnp.array([0, 1]))
        r1 = float(jnp.linalg.norm(m1 - x))
        r2 = float(jnp.linalg.norm(m2 - x2))
        assert r2 < r1
