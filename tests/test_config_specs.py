"""Pin the 10 assigned architecture configs to the assignment sheet."""
import pytest

from repro.configs.registry import ASSIGNED, get

# (layers, d_model, heads, kv, d_ff, vocab, family)
SPEC = {
    "glm4-9b":       (40, 4096, 32, 2, 13696, 151552, "dense"),
    "granite-8b":    (36, 4096, 32, 8, 14336, 49152, "dense"),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048, "moe"),
    "whisper-small": (12, 768, 12, 12, 3072, 51865, "audio"),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152, "dense"),
    "mixtral-8x7b":  (32, 4096, 32, 8, 14336, 32000, "moe"),
    "hymba-1.5b":    (32, 1600, 25, 5, 5504, 32001, "hybrid"),
    "gemma2-27b":    (46, 4608, 32, 16, 36864, 256000, "dense"),
    "pixtral-12b":   (40, 5120, 32, 8, 14336, 131072, "vlm"),
    "rwkv6-3b":      (32, 2560, 0, 0, 8960, 65536, "ssm"),
}


def test_all_assigned_present():
    assert sorted(ASSIGNED) == sorted(SPEC)


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_config_matches_assignment(arch):
    l, d, h, kv, ff, v, fam = SPEC[arch]
    cfg = get(arch)
    assert cfg.num_layers == l
    assert cfg.d_model == d
    if fam != "ssm":                      # rwkv6 is attention-free
        assert cfg.num_heads == h
        assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.family == fam
    assert cfg.source, f"{arch} must cite its source"


def test_moe_shapes():
    mix = get("mixtral-8x7b")
    assert (mix.num_experts, mix.top_k) == (8, 2)
    l4 = get("llama4-maverick-400b-a17b")
    assert (l4.num_experts, l4.top_k) == (128, 1)


def test_hymba_ssm_state():
    assert get("hymba-1.5b").ssm_state == 16


def test_smoke_reduction_bounds():
    for arch in SPEC:
        r = get(arch, smoke=True)
        assert r.num_layers <= 2 * r.group_size
        assert r.d_model <= 512
        assert (r.num_experts or 0) <= 4


def test_param_counts_in_ballpark():
    """Analytic param counts should land near the advertised sizes."""
    from repro.models.config import active_param_count, param_count
    expect = {"glm4-9b": (9, 0.35), "granite-8b": (8, 0.35),
              "starcoder2-7b": (7, 0.45), "gemma2-27b": (27, 0.35),
              "pixtral-12b": (12, 0.35), "rwkv6-3b": (3, 0.45),
              "hymba-1.5b": (1.5, 0.45), "mixtral-8x7b": (46.7, 0.25)}
    for arch, (bn, tol) in expect.items():
        n = param_count(get(arch)) / 1e9
        assert abs(n - bn) / bn < tol, (arch, n, bn)
    # llama4 maverick: ~400B total, ~17B active
    l4 = get("llama4-maverick-400b-a17b")
    assert 250e9 < param_count(l4) < 550e9
    assert 10e9 < active_param_count(l4) < 25e9
