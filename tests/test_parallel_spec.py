"""The unified ParallelSpec API (core/parallel.py) + the legacy-kwarg shim.

In-process: spec/axis validation, CLI ``--mesh``/``--wire`` parsing
(accept + reject), rule-codec resolution, the deprecation shim on
``make_lm_train_step``/``run_lm_experiment`` — legacy kwargs produce
BIT-IDENTICAL steps (same lowered HLO, same losses) and warn with
``ParallelDeprecationWarning``.  The dp=2 shim equivalence and the CLI
conflict/deprecation-notice checks run in subprocesses (forced host
devices / real argv).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.parallel import (AxisSpec, ParallelDeprecationWarning,
                                 ParallelSpec, canonical_axis, from_legacy,
                                 parse_mesh_spec, parse_wire_item,
                                 parse_wire_spec, spec_from_cli)
from repro.core.policy import NO_POLICY, CompressionPolicy, quant_policy


class TestAxisSpec:
    def test_defaults_uncompressed(self):
        a = AxisSpec()
        assert (a.size, a.codec, a.feedback, a.k_frac) == (1, "none",
                                                           "none", 0.1)
        assert not a.is_rules

    @pytest.mark.parametrize("size", (0, -1, 1.5, "2"))
    def test_bad_size_rejected(self, size):
        with pytest.raises(ValueError, match="size"):
            AxisSpec(size=size)

    @pytest.mark.parametrize("k", (0.0, -0.1, 1.5))
    def test_bad_k_frac_rejected(self, k):
        with pytest.raises(ValueError, match="k_frac"):
            AxisSpec(k_frac=k)

    def test_unknown_codec_rejected(self):
        with pytest.raises(ValueError, match="codec"):
            AxisSpec(codec="zstd")

    def test_unknown_feedback_rejected(self):
        with pytest.raises(ValueError, match="feedback"):
            AxisSpec(feedback="momentum")

    def test_rule_codec_accepted_and_resolves(self):
        a = AxisSpec(size=2, codec="none@bandwidth>=100e9; q4")
        assert a.is_rules
        fast = a.resolve(4096, bandwidth=200e9)
        slow = a.resolve(4096, bandwidth=1e6)
        assert fast.codec == "none" and slow.codec == "q4"
        assert not fast.is_rules

    def test_malformed_rule_rejected_at_construction(self):
        with pytest.raises(ValueError):
            AxisSpec(codec="q8@color=red")

    def test_resolve_plain_codec_is_identity(self):
        a = AxisSpec(size=2, codec="q8")
        assert a.resolve(10**6) is a


class TestParallelSpec:
    def test_missing_axes_default_to_solo(self):
        s = ParallelSpec({"tensor": AxisSpec(size=2)})
        assert (s.dp, s.stages, s.tp) == (1, 1, 2)
        assert s.num_devices == 2
        assert s.data == AxisSpec()

    def test_axis_aliases(self):
        s = ParallelSpec({"dp": 2, "pp": 3, "model": 4})
        assert (s.dp, s.stages, s.tp) == (2, 3, 4)
        assert s.axis("tp").size == 4
        assert canonical_axis("model") == "tensor"

    def test_unknown_axis_rejected(self):
        with pytest.raises(ValueError, match="unknown parallel axis"):
            ParallelSpec({"expert": 2})

    def test_duplicate_axis_via_alias_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            ParallelSpec({"tensor": 2, "model": 2})

    def test_int_shorthand(self):
        assert ParallelSpec({"data": 4}).data == AxisSpec(size=4)

    def test_feedback_scope_per_axis(self):
        # aqsgd buffers are boundary-scoped: not valid on data/tensor
        with pytest.raises(ValueError, match="aqsgd"):
            ParallelSpec({"data": AxisSpec(size=2, codec="topk",
                                           feedback="aqsgd")})
        # ef is valid everywhere
        ParallelSpec({"tensor": AxisSpec(size=2, codec="q8",
                                         feedback="ef")})

    def test_hashable_and_name(self):
        s = ParallelSpec({"data": AxisSpec(size=2, codec="q8"),
                          "tensor": AxisSpec(size=2)})
        assert hash(s) == hash(ParallelSpec(dict(s.axes)))
        assert s.name == "data=2(q8),tensor=2"
        assert ParallelSpec().name == "solo"

    def test_resolved_maps_wire_sizes_per_axis(self):
        s = ParallelSpec({
            "data": AxisSpec(size=2, codec="q4@size>=65536; none"),
            "tensor": AxisSpec(size=2, codec="q4@size>=65536; none"),
        })
        r = s.resolved({"data": 10**6, "tensor": 4096})
        assert r.data.codec == "q4" and r.tensor.codec == "none"

    def test_stage_policy_none_when_uncompressed(self):
        assert ParallelSpec({"stage": 4}).stage_policy() is None

    def test_stage_policy_builds_boundary_policy(self):
        s = ParallelSpec({"stage": AxisSpec(size=4, codec="q8")})
        p = s.stage_policy()
        assert isinstance(p, CompressionPolicy)
        assert p.num_stages == 4
        assert p.boundary.fw.name.startswith("q8")


class TestCLISpecs:
    def test_mesh_spec_parses(self):
        assert parse_mesh_spec("data=2,stage=2,tensor=2") == {
            "data": 2, "stage": 2, "tensor": 2}
        assert parse_mesh_spec("dp=4") == {"data": 4}

    @pytest.mark.parametrize("bad", ("data", "data=x", "data=0",
                                     "data=2,data=3", "", "expert=2"))
    def test_mesh_spec_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)

    def test_wire_item_parses(self):
        assert parse_wire_item("q8+ef:0.1") == ("q8", "ef", 0.1)
        assert parse_wire_item("q4") == ("q4", "none", None)
        assert parse_wire_item("topk:0.3") == ("topk", "none", 0.3)

    def test_wire_spec_parses(self):
        assert parse_wire_spec("data=q8+ef:0.1,tensor=q4") == {
            "data": ("q8", "ef", 0.1), "tensor": ("q4", "none", None)}

    @pytest.mark.parametrize("bad", ("q8", "data=q8:x", "",
                                     "data=q8,data=q4"))
    def test_wire_spec_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_wire_spec(bad)

    def test_spec_from_cli(self):
        s = spec_from_cli("data=2,tensor=2", "data=q8+ef:0.2,tensor=q4")
        assert s.dp == 2 and s.tp == 2 and s.stages == 1
        assert s.data == AxisSpec(size=2, codec="q8", feedback="ef",
                                  k_frac=0.2)
        assert s.tensor.codec == "q4"

    def test_spec_from_cli_bad_codec_rejected(self):
        with pytest.raises(ValueError, match="codec"):
            spec_from_cli(None, "tensor=zstd")


class TestLegacyShim:
    def test_from_legacy_round_trip(self):
        s = from_legacy(dp=2, dp_codec="q8", dp_feedback="ef",
                        dp_k_frac=0.3, num_stages=2, tp=2, tp_codec="q4")
        assert s.data == AxisSpec(size=2, codec="q8", feedback="ef",
                                  k_frac=0.3)
        assert s.stages == 2 and s.tensor.codec == "q4"

    def test_resolve_parallel_conflict(self):
        from repro.train.steps import _resolve_parallel
        with pytest.raises(ValueError, match="both parallel="):
            _resolve_parallel("api", ParallelSpec(), NO_POLICY,
                              "simulated", {"dp": 2})

    def test_resolve_parallel_rejects_unresolved_rules(self):
        from repro.train.steps import _resolve_parallel
        spec = ParallelSpec({"tensor": AxisSpec(size=2, codec="q4@size<8;q8")})
        with pytest.raises(ValueError, match="unresolved rule"):
            _resolve_parallel("api", spec, NO_POLICY, "simulated", {})

    def test_resolve_parallel_stage_wire_vs_policy_conflict(self):
        from repro.train.steps import _resolve_parallel
        spec = ParallelSpec({"stage": AxisSpec(size=2, codec="q8")})
        pol = CompressionPolicy(num_stages=2, boundary=quant_policy(8, 8))
        with pytest.raises(ValueError, match="ONE place"):
            _resolve_parallel("api", spec, pol, "pipeline", {})

    def test_stage_axis_implies_pipeline_transport(self):
        from repro.train.steps import _resolve_parallel
        spec = ParallelSpec({"stage": AxisSpec(size=2, codec="q8")})
        _, pol, transport = _resolve_parallel("api", spec, NO_POLICY,
                                              "simulated", {})
        assert transport == "pipeline"
        assert pol.num_stages == 2


def _toks(n=3, b=4, s=32, lo=0, hi=64, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(lo, hi, size=(b, s)) for _ in range(n)]


def _lm_fixture():
    from repro.configs.registry import get
    from repro.models import transformer
    from repro.optim.optimizers import OptimizerConfig, init_opt_state
    cfg = get("gpt2-small", smoke=True)
    opt = OptimizerConfig(kind="adamw", lr=1e-3, weight_decay=0.0,
                          schedule="constant")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, opt, params, init_opt_state(opt, params)


class TestShimEquivalence:
    """Legacy kwargs and parallel= build the SAME program: identical
    lowered HLO (one jit cache entry) and bit-identical training."""

    def _run(self, step, params, opt_state, n_extra=0):
        losses = []
        for t in _toks():
            batch = {"tokens": jnp.asarray(t)}
            ids = jnp.zeros((t.shape[0],), jnp.int32)
            out = step(params, opt_state, [], batch, ids)
            params, opt_state = out[0], out[1]
            losses.append(float(out[-1]["loss"]))
        return losses, params

    def test_legacy_kwargs_warn_and_match_parallel_bitwise(self):
        from repro.train.steps import make_lm_train_step
        cfg, opt, params, opt_state = _lm_fixture()
        with pytest.warns(ParallelDeprecationWarning, match="deprecated"):
            legacy = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                        donate=False, dp=1,
                                        dp_codec="none")
        new = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                 donate=False, parallel=ParallelSpec())
        batch = {"tokens": jnp.asarray(_toks(1)[0])}
        ids = jnp.zeros((4,), jnp.int32)
        hlo_a = legacy.lower(params, opt_state, [], batch, ids).as_text()
        hlo_b = new.lower(params, opt_state, [], batch, ids).as_text()
        assert hlo_a == hlo_b
        la, pa = self._run(legacy, params, opt_state)
        lb, pb = self._run(new, params, opt_state)
        assert la == lb, (la, lb)
        for ka, kb in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))

    def test_no_legacy_kwargs_no_warning(self):
        from repro.train.steps import make_lm_train_step
        cfg, opt, _, _ = _lm_fixture()
        with warnings.catch_warnings():
            warnings.simplefilter("error",
                                  category=ParallelDeprecationWarning)
            make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                               donate=False)

    def test_run_lm_experiment_legacy_warns_and_matches(self):
        from repro.data.synthetic import LMData
        from repro.train.loop import run_lm_experiment
        cfg, _, _, _ = _lm_fixture()
        data = LMData(num_train=32, seq_len=32)
        with pytest.warns(ParallelDeprecationWarning, match="deprecated"):
            r_legacy = run_lm_experiment(cfg, NO_POLICY, epochs=1, batch=8,
                                         data=data, dp=1)
        r_new = run_lm_experiment(cfg, NO_POLICY, epochs=1, batch=8,
                                  data=data, parallel=ParallelSpec())
        assert r_legacy.train_curve == r_new.train_curve

    def test_both_families_rejected(self):
        from repro.train.steps import make_lm_train_step
        cfg, opt, _, _ = _lm_fixture()
        with pytest.raises(ValueError, match="both parallel="):
            make_lm_train_step(cfg, NO_POLICY, opt, dp=2,
                               parallel=ParallelSpec({"data": 2}))


# ---------------------------------------------------------------------------
# Subprocess checks: dp=2 shim equivalence + the real CLI
# ---------------------------------------------------------------------------

DP2_SHIM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import warnings
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs.registry import get
    from repro.core.parallel import (AxisSpec, ParallelDeprecationWarning,
                                     ParallelSpec)
    from repro.core.policy import NO_POLICY
    from repro.models import transformer
    from repro.optim.optimizers import OptimizerConfig, init_opt_state
    from repro.train.loop import init_lm_dp_state
    from repro.train.steps import make_lm_train_step

    cfg = get("gpt2-small", smoke=True)
    opt = OptimizerConfig(kind="adamw", lr=1e-3, weight_decay=0.0,
                          schedule="constant")
    params0 = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    toks = [rng.randint(0, 64, size=(8, 32)) for _ in range(3)]

    def run(**kw):
        step = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                  donate=False, **kw)
        params = jax.tree.map(jnp.asarray, params0)
        opt_state = init_opt_state(opt, params)
        dp_state = init_lm_dp_state(cfg, params, NO_POLICY, 2,
                                    dp_feedback="ef")
        losses = []
        for t in toks:
            batch = {"tokens": jnp.asarray(t)}
            ids = jnp.zeros((8,), jnp.int32)
            params, opt_state, _, dp_state, m = step(
                params, opt_state, [], batch, ids, dp_state)
            losses.append(float(m["loss"]))
        return losses, params

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        la, pa = run(dp=2, dp_codec="topk", dp_feedback="ef",
                     dp_k_frac=0.3)
    assert any(issubclass(x.category, ParallelDeprecationWarning)
               for x in w), [str(x.message) for x in w]
    spec = ParallelSpec({"data": AxisSpec(size=2, codec="topk",
                                          feedback="ef", k_frac=0.3)})
    lb, pb = run(parallel=spec)
    assert la == lb, (la, lb)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    print("DP2_SHIM_OK")
""")


def _run_sub(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_dp2_shim_equivalence_subprocess():
    """dp=2 with the compressed+EF reduce: legacy kwargs and the
    equivalent ParallelSpec train bit-identically (2 host devices)."""
    r = _run_sub(DP2_SHIM_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "DP2_SHIM_OK" in r.stdout


def _run_cli(*args):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        env=env, capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_cli_mesh_conflicts_with_legacy_flags():
    r = _run_cli("--arch", "gpt2-small", "--smoke", "--steps", "1",
                 "--mesh", "data=2", "--dp", "2")
    assert r.returncode != 0
    assert "--mesh/--wire conflict" in r.stderr


@pytest.mark.slow
def test_cli_bad_wire_spec_rejected():
    r = _run_cli("--arch", "gpt2-small", "--smoke", "--steps", "1",
                 "--wire", "tensor=zstd")
    assert r.returncode != 0
    assert "codec" in r.stderr


@pytest.mark.slow
def test_cli_help_marks_legacy_flags_deprecated():
    r = _run_cli("--help")
    assert r.returncode == 0
    assert "DEPRECATED" in r.stdout
    assert "--mesh" in r.stdout and "--wire" in r.stdout
