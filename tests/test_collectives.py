"""DP gradient all-reduce (transport/collectives.py) + 2D mesh tests.

In-process tests run under plain ``jit`` on the single default device
(codec roundtrips on ragged/odd-sized parameter leaves — the q4 pad path —
mesh construction/validation, dp=1 reduce identities, EF semantics).  The
2x2 (dp=2, stages=2) acceptance runs in a subprocess with 4 forced host
devices: ``dp_codec=none`` training is BIT-IDENTICAL to the serial
single-replica reference, compressed reduces track it within tolerance,
and per-reduce wire bytes match each codec's cost model.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.compressors import quantize_dequantize, topk_compress
from repro.launch.mesh import make_data_mesh, make_dp_pipeline_mesh
from repro.transport.codecs import (fuse_payload, get_codec, unfuse_payload,
                                    wire_bytes)
from repro.transport.collectives import (dp_wire_report, grad_payload_structs,
                                         init_dp_state, make_grad_all_reduce,
                                         pack_grad_leaf, unpack_grad_leaf)


def _ragged_tree(seed=0):
    """Odd/ragged parameter-leaf shapes: odd flat n (q4 pad path), a
    rank-3 stack, a scalar-ish vector, and a bf16 leaf."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w": jax.random.normal(ks[0], (2, 16, 32), jnp.float32),
        "gamma": jax.random.normal(ks[1], (33,), jnp.float32),
        "b": jax.random.normal(ks[2], (7,), jnp.float32),
        "h": jax.random.normal(ks[3], (3, 5), jnp.float32)
            .astype(jnp.bfloat16),
    }


class TestDPMesh:
    def test_data_mesh_axis_and_size(self):
        m = make_data_mesh(1)
        assert m.axis_names == ("data",) and m.shape["data"] == 1

    def test_dp_pipeline_mesh_axes(self):
        m = make_dp_pipeline_mesh(1, 1)
        assert m.axis_names == ("data", "stage")
        assert m.shape == {"data": 1, "stage": 1}
        m2 = make_dp_pipeline_mesh(1, 1, data_axis="dp", stage_axis="pp")
        assert m2.axis_names == ("dp", "pp")

    def test_invalid_sizes_rejected(self):
        with pytest.raises(ValueError, match=">= 1"):
            make_dp_pipeline_mesh(0, 2)
        with pytest.raises(ValueError, match=">= 1"):
            make_data_mesh(0)

    def test_insufficient_devices_rejected(self):
        need = jax.device_count() + 1
        with pytest.raises(RuntimeError, match="devices"):
            make_data_mesh(need)
        with pytest.raises(RuntimeError, match="DPxPP mesh"):
            make_dp_pipeline_mesh(need, 1)


class TestGradPackRoundtrip:
    """Codec roundtrips on ragged/odd-sized parameter leaves, plain jit."""

    def test_none_is_raw_passthrough_bitwise(self):
        codec = get_codec("none")
        for leaf in jax.tree.leaves(_ragged_tree()):
            p = pack_grad_leaf(codec, leaf)
            y = unpack_grad_leaf(codec, p, leaf.shape)
            assert y.dtype == leaf.dtype        # no bf16 downcast
            np.testing.assert_array_equal(np.asarray(y), np.asarray(leaf))

    @pytest.mark.parametrize("bits", (4, 8))
    def test_quant_matches_dense_compressor_on_odd_leaves(self, bits):
        """Per-leaf per-tensor scales; the 33-element leaf hits the q4
        odd-dim pad path."""
        codec = get_codec(f"q{bits}")
        for leaf in jax.tree.leaves(_ragged_tree()):
            p = pack_grad_leaf(codec, leaf)
            y = unpack_grad_leaf(codec, p, leaf.shape)
            flat = leaf.reshape(1, -1).astype(jnp.float32)
            ref = quantize_dequantize(flat, bits).reshape(leaf.shape)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))

    def test_topk_support_matches_dense_compressor(self):
        codec = get_codec("topk")
        for leaf in jax.tree.leaves(_ragged_tree()):
            p = pack_grad_leaf(codec, leaf, 0.3)
            y = unpack_grad_leaf(codec, p, leaf.shape)
            flat = leaf.reshape(1, -1).astype(jnp.float32)
            ref = topk_compress(flat, 0.3).reshape(leaf.shape)
            assert (np.asarray(y != 0) == np.asarray(ref != 0)).all()
            np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                       rtol=1e-2, atol=1e-2)

    def test_topk_idx_dtype_per_leaf(self):
        """Ragged leaves pick their index dtype independently."""
        codec = get_codec("topk")
        small = jnp.zeros((33,)).at[3].set(1.0)
        big = jnp.zeros(((1 << 16) + 8,)).at[70000].set(1.0)
        assert pack_grad_leaf(codec, small, 0.1)["idx"].dtype == jnp.uint16
        assert pack_grad_leaf(codec, big, 0.001)["idx"].dtype == jnp.int32

    @pytest.mark.parametrize("codec_name", ("none", "q8", "q4", "topk"))
    def test_fused_payload_roundtrip_bitwise(self, codec_name):
        """All leaf payloads fuse into ONE uint8 buffer, byte-identical."""
        codec = get_codec(codec_name)
        tree = _ragged_tree()
        payloads = [pack_grad_leaf(codec, a, 0.3)
                    for a in jax.tree.leaves(tree)]
        buf = fuse_payload(payloads)
        assert buf.dtype == jnp.uint8
        assert buf.size == wire_bytes(payloads)
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payloads)
        back = unfuse_payload(buf, struct)
        for a, b in zip(jax.tree.leaves(payloads), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("codec_name", ("none", "q8", "q4", "topk"))
    def test_wire_report_matches_cost_model(self, codec_name):
        tree = _ragged_tree()
        rep = dp_wire_report(tree, codec_name, k_frac=0.3, dp=2)
        slack = 16 * rep["n_param_leaves"] + 0.01 * max(rep["model_bytes"],
                                                        1)
        assert abs(rep["payload_bytes_per_hop"]
                   - rep["model_bytes"]) <= slack, rep
        assert rep["wire_bytes_per_reduce"] == \
            (rep["dp"] - 1) * rep["payload_bytes_per_hop"]
        structs = grad_payload_structs(tree, codec_name, 0.3)
        assert rep["payload_bytes_per_hop"] == wire_bytes(structs)
        if codec_name == "none":
            raw = sum(a.size * a.dtype.itemsize
                      for a in jax.tree.leaves(tree))
            assert rep["payload_bytes_per_hop"] == raw == rep["model_bytes"]


class TestDPStateAndValidation:
    def test_state_structure(self):
        tree = _ragged_tree()
        st = init_dp_state(tree, 2, "none")
        assert st.resid.shape == (2, 0) and st.agg.shape == (0,)
        st = init_dp_state(tree, 3, "ef")
        assert st.resid["w"].shape == (3, 2, 16, 32)
        assert st.agg.shape == (0,)
        st = init_dp_state(tree, 2, "ef21")
        assert st.agg["gamma"].shape == (33,)

    def test_unknown_feedback_rejected(self):
        with pytest.raises(ValueError, match="unknown dp feedback"):
            init_dp_state(_ragged_tree(), 2, "aqsgd")
        mesh = make_data_mesh(1)
        with pytest.raises(ValueError, match="unknown dp feedback"):
            make_grad_all_reduce(mesh, "data", "q8", feedback="momentum")

    def test_feedback_requires_lossy_codec(self):
        mesh = make_data_mesh(1)
        with pytest.raises(ValueError, match="LOSSY"):
            make_grad_all_reduce(mesh, "data", "none", feedback="ef")


class TestAllReduceSingleReplica:
    """dp=1 semantics under plain jit: the reduce degenerates to the
    codec roundtrip, EF residuals accumulate exactly."""

    def test_none_is_identity_bitwise(self):
        mesh = make_data_mesh(1)
        fn = make_grad_all_reduce(mesh, "data", "none")
        tree = _ragged_tree()
        g_dp = jax.tree.map(lambda a: a[None], tree)
        st = init_dp_state(tree, 1, "none")
        reduced, st2 = jax.jit(fn)(g_dp, st)
        for a, b in zip(jax.tree.leaves(reduced), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert st2.resid.shape == (1, 0)

    def test_q8_is_codec_roundtrip(self):
        mesh = make_data_mesh(1)
        fn = make_grad_all_reduce(mesh, "data", "q8")
        tree = _ragged_tree()
        codec = get_codec("q8")
        reduced, _ = jax.jit(fn)(jax.tree.map(lambda a: a[None], tree),
                                 init_dp_state(tree, 1, "none"))
        for got, leaf in zip(jax.tree.leaves(reduced),
                             jax.tree.leaves(tree)):
            ref = unpack_grad_leaf(codec, pack_grad_leaf(codec, leaf),
                                   leaf.shape).astype(leaf.dtype)
            # fused in-shard_map dequant vs eager: fma rounding only
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(ref, np.float32),
                atol=1e-6, rtol=1e-5)

    def test_ef_residual_accumulates(self):
        """e' = g + e - C(g + e): after one reduce the residual holds the
        compression error; a second reduce of the SAME gradient sends the
        compensated message, driving cumulative error toward zero."""
        mesh = make_data_mesh(1)
        fn = jax.jit(make_grad_all_reduce(mesh, "data", "topk",
                                          k_frac=0.25, feedback="ef"))
        tree = {"w": _ragged_tree()["w"]}
        g_dp = jax.tree.map(lambda a: a[None], tree)
        st = init_dp_state(tree, 1, "ef")
        r1, st = fn(g_dp, st)
        e = np.asarray(st.resid["w"][0])
        np.testing.assert_allclose(
            e, np.asarray(tree["w"]) - np.asarray(r1["w"]), atol=1e-5)
        r2, st = fn(g_dp, st)
        got2 = np.asarray(r1["w"]) + np.asarray(r2["w"])
        want2 = 2 * np.asarray(tree["w"])
        err1 = np.abs(np.asarray(tree["w"]) - np.asarray(r1["w"])).sum()
        err2 = np.abs(want2 - got2).sum()
        assert err2 < 2 * err1          # residual stays bounded, no blow-up
        # and the classic EF telescoping: g1 + g2 - (m1 + m2) == e2
        np.testing.assert_allclose(np.asarray(st.resid["w"][0]),
                                   want2 - got2, atol=1e-4)

    def test_ef21_aggregate_tracks_reduced(self):
        mesh = make_data_mesh(1)
        fn = jax.jit(make_grad_all_reduce(mesh, "data", "q4",
                                          feedback="ef21"))
        tree = {"w": _ragged_tree()["w"], "gamma": _ragged_tree()["gamma"]}
        g_dp = jax.tree.map(lambda a: a[None], tree)
        st = init_dp_state(tree, 1, "ef21")
        r1, st = fn(g_dp, st)
        for k in tree:
            # G' == reduced, and w_r' == G' with one replica
            np.testing.assert_allclose(np.asarray(st.agg[k]),
                                       np.asarray(r1[k]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(st.resid[k][0]),
                                       np.asarray(r1[k]), atol=1e-5)
        # repeated identical grads converge: C(g - w) has shrinking error
        r2, st = fn(g_dp, st)
        d2 = max(float(np.abs(np.asarray(r2[k])
                              - np.asarray(tree[k])).max()) for k in tree)
        d1 = max(float(np.abs(np.asarray(r1[k])
                              - np.asarray(tree[k])).max()) for k in tree)
        assert d2 <= d1 + 1e-6, (d1, d2)


# ---------------------------------------------------------------------------
# 2x2 DPxPP acceptance (subprocess: 4 host devices)
# ---------------------------------------------------------------------------

DP_ACCEPT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_dp_pipeline_mesh
    from repro.transport.pipeline import pipeline_apply
    from repro.transport.collectives import (dp_wire_report, init_dp_state,
                                             make_grad_all_reduce)

    DP, S, B, D, MB = 2, 2, 8, 16, 2
    mesh = make_dp_pipeline_mesh(DP, S)
    mesh1 = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params0 = {"w1": jax.random.normal(k1, (S, D, 2 * D)) * 0.1,
               "w2": jax.random.normal(k2, (S, 2 * D, D)) * 0.1}
    stage_fn = lambda p, h: h + jnp.tanh(h @ p["w1"]) @ p["w2"]
    LR = 0.05

    def make_dp_step(codec, feedback):
        reduce_fn = make_grad_all_reduce(mesh, "data", codec, k_frac=0.3,
                                         feedback=feedback)

        @jax.jit
        def step(params, dp_state, x):
            pdp = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (DP, *a.shape)), params)

            def loss_fn(pdp):
                y = pipeline_apply(stage_fn, pdp, x, mesh, "stage",
                                   scheme="q8", microbatches=MB,
                                   dp_axis="data")
                return jnp.sum(y.astype(jnp.float32) ** 2) / B
            loss, g_dp = jax.value_and_grad(loss_fn)(pdp)
            g, new_dp = reduce_fn(g_dp, dp_state)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            return params, new_dp, loss
        return step

    def run_dp(codec, steps, feedback="none"):
        step = make_dp_step(codec, feedback)
        dp_state = init_dp_state(params0, DP, feedback)
        params, losses = params0, []
        rng = np.random.RandomState(0)
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            params, dp_state, l = step(params, dp_state, x)
            losses.append(float(l))
        return losses, params

    def run_serial(steps):
        '''Single-replica reference: the SAME per-shard pipeline program
        on a stages-only mesh, shard gradients summed serially.'''
        @jax.jit
        def step(params, x):
            def shard_loss(p, xs):
                y = pipeline_apply(stage_fn, p, xs, mesh1, "stage",
                                   scheme="q8", microbatches=MB)
                return jnp.sum(y.astype(jnp.float32) ** 2) / B
            ltot, g = 0.0, None
            for r in range(DP):
                xs = x[r * (B // DP):(r + 1) * (B // DP)]
                l, gr = jax.value_and_grad(shard_loss)(params, xs)
                ltot = ltot + l
                g = gr if g is None else jax.tree.map(jnp.add, g, gr)
            params = jax.tree.map(lambda p, gg: p - LR * gg, params, g)
            return params, ltot
        params, losses = params0, []
        rng = np.random.RandomState(0)
        for t in range(steps):
            x = jnp.asarray(rng.randn(B, D), jnp.float32)
            params, l = step(params, x)
            losses.append(float(l))
        return losses, params

    # (a) dp_codec=none == single-replica training BIT-FOR-BIT, through
    # the q8-compressed activation pipeline: both regimes live on one mesh
    dl, dparams = run_dp("none", 8)
    sl, sparams = run_serial(8)
    assert dl == sl, (dl, sl)
    for k in dparams:
        assert np.array_equal(np.asarray(dparams[k]), np.asarray(sparams[k])), k
    print("dp=none bitwise == serial reference:", dl[-1])

    # (b) compressed DP reduces track the uncompressed trajectory
    # step-for-step within tolerance
    for codec, fb, tol in (("q8", "none", 0.02), ("topk", "ef", 0.15),
                           ("q4", "ef21", 0.15)):
        cl, _ = run_dp(codec, 8, fb)
        for t, (a, b) in enumerate(zip(cl, dl)):
            assert abs(a - b) <= tol * max(abs(b), 1.0), \\
                (codec, fb, t, cl, dl)
        assert cl[-1] < cl[0], (codec, cl)
        print(codec, "+", fb, "tracks uncompressed:", cl[-1], dl[-1])

    # (c) wire bytes per reduce match each codec's wire_bytes_per_elem
    for codec in ("none", "q8", "q4", "topk"):
        rep = dp_wire_report(params0, codec, k_frac=0.3, dp=DP)
        slack = 16 * rep["n_param_leaves"] + 0.01 * rep["model_bytes"]
        assert abs(rep["payload_bytes_per_hop"]
                   - rep["model_bytes"]) <= slack, rep
        assert rep["wire_bytes_per_reduce"] == rep["payload_bytes_per_hop"]
        print(codec, "wire bytes/reduce:", rep["wire_bytes_per_reduce"])

    print("DP_ACCEPT_OK")
""")


LM_DP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs.registry import get
    from repro.core.policy import CompressionPolicy, NO_POLICY, quant_policy
    from repro.models import transformer
    from repro.optim.optimizers import OptimizerConfig, init_opt_state
    from repro.train.loop import init_lm_dp_state
    from repro.train.steps import make_lm_train_step

    cfg = get("gpt2-small", smoke=True)
    B, SEQ = 8, 32
    opt = OptimizerConfig(kind="adamw", lr=1e-3, weight_decay=0.0,
                          schedule="constant")
    params0 = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    toks = [rng.randint(0, 64, size=(B, SEQ)) for _ in range(4)]

    def run(dp, transport, dp_codec="none", grad_accum=1, stages=2):
        policy = (CompressionPolicy(num_stages=stages,
                                    boundary=quant_policy(8, 8))
                  if transport == "pipeline" else NO_POLICY)
        step = make_lm_train_step(cfg, policy, opt, remat=False,
                                  donate=False, transport=transport,
                                  grad_accum=grad_accum, dp=dp,
                                  dp_codec=dp_codec)
        params = jax.tree.map(jnp.asarray, params0)
        opt_state = init_opt_state(opt, params)
        dp_state = (init_lm_dp_state(cfg, params, policy, dp,
                                     transport=transport)
                    if dp > 1 else None)
        losses, bstates = [], []
        for t in toks:
            batch = {"tokens": jnp.asarray(t)}
            ids = jnp.zeros((B,), jnp.int32)
            if dp > 1:
                params, opt_state, bstates, dp_state, m = step(
                    params, opt_state, bstates, batch, ids, dp_state)
            else:
                params, opt_state, bstates, m = step(
                    params, opt_state, bstates, batch, ids)
            losses.append(float(m["loss"]))
        return losses

    # simulated transport: dp=2 vmap lanes + uncompressed reduce == the
    # single-replica step to float accumulation error; grad-accum composes
    base = run(1, "simulated")
    for tag, losses in [("dp2", run(2, "simulated")),
                        ("dp2+accum2", run(2, "simulated", grad_accum=2)),
                        ("dp2+q8", run(2, "simulated", dp_codec="q8"))]:
        for t, (a, b) in enumerate(zip(losses, base)):
            tol = 1e-3 if tag != "dp2+q8" else 0.02
            assert abs(a - b) <= tol * max(abs(b), 1.0), \\
                (tag, t, losses, base)
        print(tag, "tracks single-replica:", losses[-1], base[-1])

    # pipeline transport on the 2D mesh: q8 activations + q8 DP gradients
    pl = run(2, "pipeline", dp_codec="q8")
    assert all(np.isfinite(pl)), pl
    assert pl[-1] < pl[0], pl
    print("2D mesh q8+q8 LM training decreases:", pl)
    print("LM_DP_OK")
""")


def _run_sub(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_dp_pipeline_matches_serial_reference_subprocess():
    """Acceptance (run explicitly in CI, 4 host devices): on the 2x2
    (dp=2, stages=2) mesh, dp_codec=none training is bit-identical to the
    serial single-replica reference; q8 / topk+EF / q4+EF21 DP reduces
    track the uncompressed trajectory step-for-step; per-reduce wire
    bytes match each codec's ``wire_bytes_per_elem``."""
    r = _run_sub(DP_ACCEPT_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DP_ACCEPT_OK" in r.stdout


@pytest.mark.slow
def test_lm_train_step_dp_subprocess():
    """DP threading through train/steps.py: simulated-transport vmap
    lanes (+ grad-accum composition, + q8 reduce) track the
    single-replica step; the 2D DPxPP pipeline LM step trains."""
    r = _run_sub(LM_DP_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "LM_DP_OK" in r.stdout
