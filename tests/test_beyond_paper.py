"""Beyond-paper features: int8 expert-dispatch quantization, enc-dec
chunked hidden loss, pipeline payload wire-cost ordering."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.core.policy import NO_POLICY
from repro.models import encdec, moe, transformer


class TestDispatchQuant:
    def _setup(self):
        key = jax.random.PRNGKey(0)
        params = moe.moe_init(key, 64, 128, 4, "swiglu")
        x = jax.random.normal(key, (2, 32, 64)).astype(jnp.bfloat16)
        return params, x

    def test_output_close_to_unquantized(self):
        params, x = self._setup()
        y1, _ = moe.moe_apply(params, x, num_experts=4, top_k=2,
                              mlp_kind="swiglu")
        y2, _ = moe.moe_apply(params, x, num_experts=4, top_k=2,
                              mlp_kind="swiglu", dispatch_quant=True)
        scale = float(jnp.max(jnp.abs(y1.astype(jnp.float32)))) + 1e-9
        err = float(jnp.max(jnp.abs((y1 - y2).astype(jnp.float32)))) / scale
        assert err < 0.05, err

    def test_gradients_flow_and_are_close(self):
        params, x = self._setup()

        def loss(x, dq):
            y, aux = moe.moe_apply(params, x, num_experts=4, top_k=2,
                                   mlp_kind="swiglu", dispatch_quant=dq)
            return (y.astype(jnp.float32) ** 2).mean() + 0.01 * aux

        g1 = jax.grad(loss)(x, False).astype(jnp.float32)
        g2 = jax.grad(loss)(x, True).astype(jnp.float32)
        assert bool(jnp.isfinite(g2).all())
        denom = float(jnp.linalg.norm(g1.reshape(-1))) + 1e-9
        rel = float(jnp.linalg.norm((g1 - g2).reshape(-1))) / denom
        assert rel < 0.2, rel

    def test_jit_and_smoke_config_flag(self):
        import dataclasses
        cfg = dataclasses.replace(get("mixtral-8x7b", smoke=True),
                                  moe_dispatch_quant=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((2, 8), jnp.int32)
        logits = jax.jit(lambda p, b: transformer.forward_eval(
            p, b, cfg, NO_POLICY))(params, {"tokens": toks})
        assert logits.shape == (2, 8, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


class TestEncDecHiddenLoss:
    def test_hidden_matches_logits_path(self):
        cfg = get("whisper-small", smoke=True)
        params = encdec.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
                 "enc_embeds": jnp.ones((2, cfg.enc_seq, cfg.d_model),
                                        jnp.bfloat16)}
        x, aux, _ = encdec.forward_hidden(params, batch, cfg, NO_POLICY,
                                          None, None, remat=False)
        logits_direct, _, _ = encdec.forward_train(params, batch, cfg,
                                                   NO_POLICY, None, None,
                                                   remat=False)
        from repro.models.transformer import _lm_logits
        np.testing.assert_allclose(
            np.asarray(_lm_logits(params, x, cfg), np.float32),
            np.asarray(logits_direct, np.float32), atol=1e-2)

    def test_train_step_encdec_runs(self):
        from repro.optim.optimizers import OptimizerConfig, init_opt_state
        from repro.train.steps import make_lm_train_step
        cfg = get("whisper-small", smoke=True)
        params = encdec.init_params(jax.random.PRNGKey(0), cfg)
        opt = OptimizerConfig(kind="adamw", lr=1e-3, schedule="constant")
        ostate = init_opt_state(opt, params)
        step = make_lm_train_step(cfg, NO_POLICY, opt, remat=False,
                                  donate=False)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32),
                 "enc_embeds": jnp.ones((2, cfg.enc_seq, cfg.d_model),
                                        jnp.bfloat16)}
        params, ostate, _, m = step(params, ostate, [], batch,
                                    jnp.zeros((2,), jnp.int32))
        assert np.isfinite(float(m["loss"]))


class TestPipelineWireModel:
    def test_scheme_byte_ordering(self):
        from repro.core.pipeline import pack_payload, wire_bytes
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))
        b = {s: wire_bytes(pack_payload(x, s, 0.10))
             for s in ("none", "q8", "q4", "topk")}
        # q4 is half of q8 (plus shared tiny meta); topk10 = 0.1*(2+4)/2
        assert b["q4"] < 0.6 * b["q8"]
        assert b["topk"] < 0.4 * b["none"]
