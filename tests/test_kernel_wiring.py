"""The boundary Compressor must run on the Pallas kernels when forced
(TPU path, interpret=True on CPU) and match the jnp reference within the
documented tolerance (per-tile scales / block-local TopK)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import compressors as C


@pytest.fixture
def pallas_backend():
    old = C.KERNEL_BACKEND
    C.KERNEL_BACKEND = "pallas"
    yield
    C.KERNEL_BACKEND = old


def test_quant_compressor_uses_kernel(pallas_backend):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024), jnp.float32)
    y = C.quant(8)(x)
    # per-TILE scales are at least as accurate as the global-scale ref
    ref = C.quantize_dequantize(x, 8)
    assert float(jnp.abs(y - x).max()) <= float(jnp.abs(ref - x).max()) + 1e-6


def test_topk_compressor_uses_kernel(pallas_backend):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2048), jnp.float32)
    y = C.topk(0.25)(x)
    # block-local TopK keeps the same per-example sparsity budget
    nz = float((y != 0).mean())
    assert abs(nz - 0.25) < 0.02
    # and every kept entry is an original entry
    kept = np.asarray(y)[np.asarray(y) != 0]
    allx = set(np.asarray(x).reshape(-1).tolist())
    assert all(v in allx for v in kept.tolist()[:50])


def test_boundary_with_pallas_quant(pallas_backend):
    """Full custom_vjp boundary with the kernel-backed compressor."""
    from repro.core.boundary import boundary_apply
    from repro.core.feedback import FeedbackState
    from repro.core.policy import quant_policy
    bp = quant_policy(8, 8)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 512), jnp.float32)
    zero = jnp.zeros((0,), x.dtype)
    fw = FeedbackState(resid=zero, mirror=zero, agg=zero, direction="fw")
    bw = FeedbackState(resid=zero, mirror=zero, agg=zero, direction="bw")
    ids = jnp.zeros((2,), jnp.int32)

    def f(x):
        y, _ = boundary_apply(bp, x, fw, bw, ids)
        return (y ** 2).sum()

    g = jax.grad(f)(x)
    assert bool(jnp.isfinite(g).all())


def test_auto_backend_is_jnp_on_cpu():
    assert C.KERNEL_BACKEND == "auto"
    assert not C._use_pallas()
