"""Continuous-batching serve subsystem tests.

Core guarantees under test:
  * a request's output is TOKEN-FOR-TOKEN what it gets served alone,
    regardless of which requests share the batch (mixed prompt lengths,
    mixed max-new-tokens, greedy and sampled) — per-slot positions,
    per-slot pad masks, per-request PRNG keys, per-request codec packing;
  * slot eviction/refill never recompiles (jit cache sizes frozen after
    warmup);
  * paper finding F3 end-to-end: a TopK-trained toy model served through
    the engine performs only with compression on, while an EF-trained one
    serves uncompressed with no quality drop.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get
from repro.core.boundary import init_boundary_state
from repro.core.policy import CompressionPolicy, ef_policy, topk_policy
from repro.launch.train import make_batch
from repro.models import transformer
from repro.models.transformer import lm_loss
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.serve.engine import ContinuousEngine
from repro.serve.sampling import SamplingConfig
from repro.serve.scheduler import Scheduler
from repro.train.steps import make_lm_train_step

TOP10 = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_seq", 96)
    return ContinuousEngine(params, cfg, kw.pop("policy", TOP10), **kw)


def _serve(engine, prompts, news, eos=None, seeds=None):
    for i, (p, n) in enumerate(zip(prompts, news)):
        engine.submit(p, max_new_tokens=n, eos_token=eos,
                      seed=0 if seeds is None else seeds[i])
    done = engine.drain()
    return {r.req_id: r.out.copy() for r in done}


class TestContinuousMatchesSolo:
    """Mixed-length, mixed-max-token streams == solo serving, bit-exact."""

    def _check(self, cfg, params, sampling=None, eos=None):
        kw = {} if sampling is None else {"sampling": sampling}
        rng = np.random.RandomState(7)
        lens = [5, 19, 7, 30, 12, 3, 26, 9]
        news = [6, 3, 9, 4, 1, 7, 5, 8]
        seeds = list(range(100, 108))
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in lens]
        eng = _engine(cfg, params, **kw)
        batched = _serve(eng, prompts, news, eos=eos, seeds=seeds)
        assert len(batched) == len(prompts)
        solo_eng = _engine(cfg, params, **kw)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n, eos_token=eos,
                            seed=seeds[i])
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(
                solo.out, batched[i],
                err_msg=f"req {i} (len={lens[i]}, new={news[i]}) differs "
                        "batched vs alone")

    def test_greedy_compressed(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        self._check(cfg, params)

    def test_sampled_per_slot_keys(self):
        """Temperature/top-k/top-p sampling stays a pure function of the
        request (its seed), not of batch composition or slot index."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        self._check(cfg, params,
                    sampling=SamplingConfig(temperature=1.0, top_k=50,
                                            top_p=0.9))

    def test_greedy_second_rope_arch(self):
        """A second RoPE family (GQA + different norms) through the same
        machinery."""
        cfg = get("granite-8b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (4, 17, 11)]
        news = [5, 2, 7]
        eng = _engine(cfg, params, num_slots=2)
        batched = _serve(eng, prompts, news)
        solo_eng = _engine(cfg, params, num_slots=2)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n)
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(solo.out, batched[i])

    def test_swa_ring_cache_and_moe(self):
        """Sliding-window ring caches with PER-SLOT positions (slot =
        pos % window, per-slot age/validity) + MoE blocks: mixtral."""
        cfg = get("mixtral-8x7b", smoke=True)
        assert cfg.window          # the smoke config keeps a 16-slot ring
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(2)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (5, 30, 12, 21)]
        news = [9, 4, 14, 6]
        eng = _engine(cfg, params, num_slots=2)
        batched = _serve(eng, prompts, news)
        solo_eng = _engine(cfg, params, num_slots=2)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n)
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(solo.out, batched[i])

    def test_eos_completion_frees_slot_early(self):
        """EOS ends a request before max_new_tokens; output includes the
        stop token and the freed slot refills."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(11)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (6, 13, 9, 21)]
        eng = _engine(cfg, params, num_slots=2)
        ref = _serve(eng, prompts, [10, 10, 10, 10])
        # pick an eos that appears mid-output for request 0
        eos = int(ref[0][4])
        eng2 = _engine(cfg, params, num_slots=2)
        out = _serve(eng2, prompts, [10, 10, 10, 10], eos=eos)
        stop = np.nonzero(ref[0] == eos)[0][0]
        np.testing.assert_array_equal(out[0], ref[0][:stop + 1])
        for i in (1, 2, 3):
            trunc = np.nonzero(ref[i] == eos)[0]
            ref_i = ref[i][:trunc[0] + 1] if len(trunc) else ref[i]
            np.testing.assert_array_equal(out[i], ref_i)


class TestNoRecompiles:
    def test_eviction_refill_zero_recompiles(self):
        """After warmup, an entire mixed workload — evictions, refills,
        every prompt bucket — adds ZERO entries to the jit caches."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params)
        warm = eng.warmup()
        assert warm["decode_compiles"] == 1
        assert warm["decode_chunk_compiles"] == 1   # multi-tick program
        assert warm["insert_compiles"] == len(eng.buckets)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (3, 40, 8, 22, 15, 5, 33, 11, 7, 19)]
        news = [4, 2, 9, 1, 6, 3, 8, 5, 2, 7]
        _serve(eng, prompts, news)
        assert eng.compile_stats() == warm, \
            "slot eviction/refill recompiled a decode or insert program"
        assert eng.stats()["completed"] == 10


class TestSchedulerHostLogic:
    def test_fifo_admission_and_metrics(self):
        s = Scheduler(2)
        for i in range(4):
            s.submit(np.arange(3), max_new_tokens=2, now=float(i))
        fills = s.fills()
        assert [(slot, r.req_id) for slot, r in fills] == [(0, 0), (1, 1)]
        assert s.fills() == []                     # no free slot
        assert s.started(0, 5, now=10.0) is None   # 1 of 2 tokens
        done = s.token(0, 6, now=11.0)
        assert done.req_id == 0 and done.tokens == [5, 6]
        assert done.ttft_s == 10.0 and done.decode_tok_per_s == 1.0
        # slot 0 freed -> next fill takes req 2 there
        assert [(sl, r.req_id) for sl, r in s.fills()] == [(0, 2)]

    def test_eos_and_max_tokens_complete(self):
        s = Scheduler(1)
        s.submit(np.arange(2), max_new_tokens=5, eos_token=9)
        s.fills()
        assert s.started(0, 1) is None
        assert s.token(0, 9).tokens == [1, 9]      # eos appended + done
        s.submit(np.arange(2), max_new_tokens=1)
        s.fills()
        assert s.started(0, 3).tokens == [3]       # max_new on first token
        assert s.idle


class TestFindingF3ThroughEngine:
    """Paper finding F3 over the NEW engine: models trained with TopK
    boundaries only perform when served with compression on; EF-trained
    models serve uncompressed with no quality drop (the --no-compress
    ablation).  The toy model memorizes a fixed batch THROUGH the
    compressed boundary, so the compressed forward is the function it
    actually learned."""

    CFG = None
    DATA = None

    @classmethod
    def _data(cls):
        if cls.CFG is None:
            cls.CFG = get("gpt2-small", smoke=True)
            rng = np.random.RandomState(0)
            cls.DATA = rng.randint(1, cls.CFG.vocab_size,
                                   (8, 32)).astype(np.int32)
        return cls.CFG, cls.DATA

    @classmethod
    def _overfit(cls, bp, steps=200):
        # 200 steps memorizes the batch to ~4.1 nats through the top-5%
        # boundary; the compressed-vs-uncompressed serve gap (~0.7 nats)
        # only emerges once memorization bites — 150 steps is not enough
        cfg, toks = cls._data()
        pol = CompressionPolicy(num_stages=2, boundary=bp)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        opt = OptimizerConfig(kind="adamw", lr=3e-3, weight_decay=0.0,
                              schedule="constant", grad_clip=1.0)
        ostate = init_opt_state(opt, params)
        step = make_lm_train_step(cfg, pol, opt, remat=False, donate=False)
        bst = ([init_boundary_state(pol.at(0), (32, cfg.d_model), batch=8,
                                    dtype=jnp.bfloat16)]
               if (bp.needs_fw_buffer or bp.needs_bw_buffer) else [])
        batch = make_batch(cfg, toks)
        ids = jnp.arange(8, dtype=jnp.int32)
        for _ in range(steps):
            params, ostate, bst, _ = step(params, ostate, bst, batch, ids)
        return params, pol

    @classmethod
    def _nll(cls, params, pol, compress):
        cfg, toks = cls._data()
        logits = transformer.forward_eval(params, make_batch(cfg, toks),
                                          cfg, pol, compress=compress,
                                          wire=True)
        return float(lm_loss(logits[:, :-1], jnp.asarray(toks)[:, 1:]))

    @classmethod
    def _engine_token_acc(cls, params, pol, compress):
        """Serve the memorized rows' prefixes through the engine and score
        the generated continuation against the memorized suffix."""
        cfg, toks = cls._data()
        eng = ContinuousEngine(params, cfg, pol, compress=compress,
                               num_slots=4, max_seq=96)
        for row in toks[:4]:
            eng.submit(row[:16], max_new_tokens=15)
        done = {r.req_id: r.out for r in eng.drain()}
        hits = sum(int(np.sum(done[i] == toks[i, 16:31]))
                   for i in range(4))
        return hits / (4 * 15)

    def test_topk_trained_needs_compression_at_serve(self):
        params, pol = self._overfit(topk_policy(0.05))
        nll_c = self._nll(params, pol, compress=True)
        nll_u = self._nll(params, pol, compress=False)
        # measured gap ~0.7 nats at these settings; 0.15 leaves slack
        assert nll_u - nll_c > 0.15, \
            "TopK-trained model should degrade served uncompressed " \
            f"(F3): nll_c={nll_c:.4f} nll_u={nll_u:.4f}"
        acc_c = self._engine_token_acc(params, pol, compress=True)
        acc_u = self._engine_token_acc(params, pol, compress=False)
        assert acc_c > acc_u, \
            "engine-served memorized continuation: compressed acc " \
            f"{acc_c:.3f} should beat uncompressed {acc_u:.3f}"

    def test_ef_trained_serves_uncompressed_without_drop(self):
        params, pol = self._overfit(ef_policy(0.05, "ef"))
        nll_c = self._nll(params, pol, compress=True)
        nll_u = self._nll(params, pol, compress=False)
        # EF compensates the compression error during training, so the
        # learned function is the UNCOMPRESSED one (measured: nll_u is
        # ~3.8 nats BETTER; assert merely "no drop")
        assert nll_u - nll_c < 0.15, \
            "EF-trained model should serve uncompressed without a " \
            f"quality drop: nll_c={nll_c:.4f} nll_u={nll_u:.4f}"


class TestWireEvalMatchesSimulated:
    def test_topk_wire_matches_in_process(self):
        """The codec-routed stage cut reproduces the simulated TopK
        boundary up to bf16 magnitude TIES: the wire payload carries
        exactly k (values, indices) pairs while the in-process mask keeps
        every entry >= the k-th magnitude, so on tied magnitudes the
        simulated C(x) may keep a few extra.  Everything else is equal."""
        from repro.core.boundary import boundary_eval, boundary_wire_eval
        cfg = get("gpt2-small", smoke=True)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.d_model),
                              jnp.bfloat16)
        sim = np.asarray(boundary_eval(TOP10.at(0), x, True), np.float32)
        wire = np.asarray(boundary_wire_eval(TOP10.at(0), x, True),
                          np.float32)
        k = int(round(0.10 * 24 * cfg.d_model))
        assert (wire != 0).sum(axis=(1, 2)).tolist() == [k, k]  # exactly k
        assert (sim != 0).sum() >= (wire != 0).sum()            # ties extra
        agree = (sim == wire).mean()
        assert agree > 0.995, "wire and simulated TopK disagree on " \
                              f"{(1 - agree):.2%} of elements (ties only " \
                              "should differ)"
        # end-to-end logits stay close through the full stack
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.random.RandomState(5).randint(
            1, cfg.vocab_size, (2, 24)).astype(np.int32)
        lw = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                      TOP10, compress=True, wire=True)
        ls = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                      TOP10, compress=True, wire=False)
        np.testing.assert_allclose(np.asarray(lw, np.float32),
                                   np.asarray(ls, np.float32), atol=0.5)

    def test_q8_wire_close_to_in_process(self):
        """q8 packs per request on the wire (per-tensor in-process) —
        close, not identical."""
        from repro.core.policy import quant_policy
        cfg = get("gpt2-small", smoke=True)
        pol = CompressionPolicy(num_stages=2, boundary=quant_policy(8, 8))
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.random.RandomState(5).randint(
            1, cfg.vocab_size, (2, 24)).astype(np.int32)
        wire = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                        pol, compress=True, wire=True)
        sim = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                       pol, compress=True, wire=False)
        np.testing.assert_allclose(np.asarray(wire, np.float32),
                                   np.asarray(sim, np.float32),
                                   atol=0.25, rtol=0.25)


class TestEngineGuards:
    def test_recurrent_arch_rejected(self):
        cfg = get("rwkv6-3b", smoke=True)
        params = {"stub": jnp.zeros(())}
        with pytest.raises(ValueError, match="continuous batching"):
            ContinuousEngine(params, cfg, num_slots=2)

    def test_vision_arch_rejected(self):
        """The vision patch prefix splices into the sequence FRONT — the
        region bucket left-padding occupies — so pixtral must be refused,
        not silently served with masked/clobbered patches."""
        cfg = get("pixtral-12b", smoke=True)
        params = {"stub": jnp.zeros(())}
        with pytest.raises(ValueError, match="vision"):
            ContinuousEngine(params, cfg, num_slots=2)

    def test_warmup_compiles_chunk_despite_tight_headroom(self):
        """Geometry where no warmup request ever satisfies the chunkable
        condition (largest bucket leaves < tick_chunk headroom): the
        multi-tick program must still be compiled by warmup, or the first
        long production request recompiles mid-serving."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ContinuousEngine(params, cfg, TOP10, num_slots=4,
                               max_seq=64, max_prompt=60)
        warm = eng.warmup()
        assert warm["decode_chunk_compiles"] == 1
        rng = np.random.RandomState(1)
        eng.submit(rng.randint(1, cfg.vocab_size, 4).astype(np.int32),
                   max_new_tokens=20)
        eng.drain()
        assert eng.compile_stats() == warm

    def test_overlong_request_rejected(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params, max_seq=64)
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(np.zeros(30, np.int32), max_new_tokens=60)

    def test_throughput_probe_reports_split(self):
        from repro.serve.engine import ServeEngine
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, max_batch=2, max_seq=64)
        probe = eng.throughput_probe(2, 8, 4)
        for key in ("prefill_tok_per_s", "decode_tok_per_s", "tok_per_s",
                    "warm_s"):
            assert key in probe and probe[key] >= 0
        assert probe["decode_tok_per_s"] > 0
