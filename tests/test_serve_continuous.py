"""Continuous-batching serve subsystem tests.

Core guarantees under test:
  * a request's output is TOKEN-FOR-TOKEN what it gets served alone,
    regardless of which requests share the batch (mixed prompt lengths,
    mixed max-new-tokens, greedy and sampled) — per-slot positions,
    per-slot pad masks, per-request PRNG keys, per-request codec packing;
  * slot eviction/refill never recompiles (jit cache sizes frozen after
    warmup);
  * paper finding F3 end-to-end: a TopK-trained toy model served through
    the engine performs only with compression on, while an EF-trained one
    serves uncompressed with no quality drop.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get
from repro.core.boundary import init_boundary_state
from repro.core.policy import CompressionPolicy, ef_policy, topk_policy
from repro.launch.train import make_batch
from repro.models import transformer
from repro.models.transformer import lm_loss
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.serve.engine import ContinuousEngine
from repro.serve.sampling import SamplingConfig
from repro.serve.scheduler import Scheduler
from repro.train.steps import make_lm_train_step

TOP10 = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_seq", 96)
    return ContinuousEngine(params, cfg, kw.pop("policy", TOP10), **kw)


def _serve(engine, prompts, news, eos=None, seeds=None):
    for i, (p, n) in enumerate(zip(prompts, news)):
        engine.submit(p, max_new_tokens=n, eos_token=eos,
                      seed=0 if seeds is None else seeds[i])
    done = engine.drain()
    return {r.req_id: r.out.copy() for r in done}


class TestContinuousMatchesSolo:
    """Mixed-length, mixed-max-token streams == solo serving, bit-exact."""

    def _check(self, cfg, params, sampling=None, eos=None):
        kw = {} if sampling is None else {"sampling": sampling}
        rng = np.random.RandomState(7)
        lens = [5, 19, 7, 30, 12, 3, 26, 9]
        news = [6, 3, 9, 4, 1, 7, 5, 8]
        seeds = list(range(100, 108))
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in lens]
        eng = _engine(cfg, params, **kw)
        batched = _serve(eng, prompts, news, eos=eos, seeds=seeds)
        assert len(batched) == len(prompts)
        solo_eng = _engine(cfg, params, **kw)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n, eos_token=eos,
                            seed=seeds[i])
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(
                solo.out, batched[i],
                err_msg=f"req {i} (len={lens[i]}, new={news[i]}) differs "
                        "batched vs alone")

    def test_greedy_compressed(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        self._check(cfg, params)

    def test_sampled_per_slot_keys(self):
        """Temperature/top-k/top-p sampling stays a pure function of the
        request (its seed), not of batch composition or slot index."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        self._check(cfg, params,
                    sampling=SamplingConfig(temperature=1.0, top_k=50,
                                            top_p=0.9))

    def test_greedy_second_rope_arch(self):
        """A second RoPE family (GQA + different norms) through the same
        machinery."""
        cfg = get("granite-8b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (4, 17, 11)]
        news = [5, 2, 7]
        eng = _engine(cfg, params, num_slots=2)
        batched = _serve(eng, prompts, news)
        solo_eng = _engine(cfg, params, num_slots=2)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n)
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(solo.out, batched[i])

    def test_swa_ring_cache_and_moe(self):
        """Sliding-window ring caches with PER-SLOT positions (slot =
        pos % window, per-slot age/validity) + MoE blocks: mixtral."""
        cfg = get("mixtral-8x7b", smoke=True)
        assert cfg.window          # the smoke config keeps a 16-slot ring
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(2)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (5, 30, 12, 21)]
        news = [9, 4, 14, 6]
        eng = _engine(cfg, params, num_slots=2)
        batched = _serve(eng, prompts, news)
        solo_eng = _engine(cfg, params, num_slots=2)
        for i, (p, n) in enumerate(zip(prompts, news)):
            solo_eng.submit(p, max_new_tokens=n)
            (solo,) = solo_eng.drain()
            np.testing.assert_array_equal(solo.out, batched[i])

    def test_eos_completion_frees_slot_early(self):
        """EOS ends a request before max_new_tokens; output includes the
        stop token and the freed slot refills."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(11)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (6, 13, 9, 21)]
        eng = _engine(cfg, params, num_slots=2)
        ref = _serve(eng, prompts, [10, 10, 10, 10])
        # pick an eos that appears mid-output for request 0
        eos = int(ref[0][4])
        eng2 = _engine(cfg, params, num_slots=2)
        out = _serve(eng2, prompts, [10, 10, 10, 10], eos=eos)
        stop = np.nonzero(ref[0] == eos)[0][0]
        np.testing.assert_array_equal(out[0], ref[0][:stop + 1])
        for i in (1, 2, 3):
            trunc = np.nonzero(ref[i] == eos)[0]
            ref_i = ref[i][:trunc[0] + 1] if len(trunc) else ref[i]
            np.testing.assert_array_equal(out[i], ref_i)


class TestNoRecompiles:
    def test_eviction_refill_zero_recompiles(self):
        """After warmup, an entire mixed workload — evictions, refills,
        every prompt bucket — adds ZERO entries to the jit caches."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params)
        warm = eng.warmup()
        assert warm["decode_compiles"] == 1
        assert warm["decode_chunk_compiles"] == 1   # multi-tick program
        assert warm["insert_compiles"] == len(eng.buckets)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (3, 40, 8, 22, 15, 5, 33, 11, 7, 19)]
        news = [4, 2, 9, 1, 6, 3, 8, 5, 2, 7]
        _serve(eng, prompts, news)
        assert eng.compile_stats() == warm, \
            "slot eviction/refill recompiled a decode or insert program"
        assert eng.stats()["completed"] == 10


class TestSchedulerHostLogic:
    def test_fifo_admission_and_metrics(self):
        s = Scheduler(2)
        for i in range(4):
            s.submit(np.arange(3), max_new_tokens=2, now=float(i))
        fills = s.fills()
        assert [(slot, r.req_id) for slot, r in fills] == [(0, 0), (1, 1)]
        assert s.fills() == []                     # no free slot
        assert s.started(0, 5, now=10.0) is None   # 1 of 2 tokens
        done = s.token(0, 6, now=11.0)
        assert done.req_id == 0 and done.tokens == [5, 6]
        assert done.ttft_s == 10.0 and done.decode_tok_per_s == 1.0
        # slot 0 freed -> next fill takes req 2 there
        assert [(sl, r.req_id) for sl, r in s.fills()] == [(0, 2)]

    def test_eos_and_max_tokens_complete(self):
        s = Scheduler(1)
        s.submit(np.arange(2), max_new_tokens=5, eos_token=9)
        s.fills()
        assert s.started(0, 1) is None
        assert s.token(0, 9).tokens == [1, 9]      # eos appended + done
        s.submit(np.arange(2), max_new_tokens=1)
        s.fills()
        assert s.started(0, 3).tokens == [3]       # max_new on first token
        assert s.idle


class TestFindingF3ThroughEngine:
    """Paper finding F3 over the NEW engine: models trained with TopK
    boundaries only perform when served with compression on; EF-trained
    models serve uncompressed with no quality drop (the --no-compress
    ablation).  The toy model memorizes a fixed batch THROUGH the
    compressed boundary, so the compressed forward is the function it
    actually learned."""

    CFG = None
    DATA = None

    @classmethod
    def _data(cls):
        if cls.CFG is None:
            cls.CFG = get("gpt2-small", smoke=True)
            rng = np.random.RandomState(0)
            cls.DATA = rng.randint(1, cls.CFG.vocab_size,
                                   (8, 32)).astype(np.int32)
        return cls.CFG, cls.DATA

    @classmethod
    def _overfit(cls, bp, steps=200):
        # 200 steps memorizes the batch to ~4.1 nats through the top-5%
        # boundary; the compressed-vs-uncompressed serve gap (~0.7 nats)
        # only emerges once memorization bites — 150 steps is not enough
        cfg, toks = cls._data()
        pol = CompressionPolicy(num_stages=2, boundary=bp)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        opt = OptimizerConfig(kind="adamw", lr=3e-3, weight_decay=0.0,
                              schedule="constant", grad_clip=1.0)
        ostate = init_opt_state(opt, params)
        step = make_lm_train_step(cfg, pol, opt, remat=False, donate=False)
        bst = ([init_boundary_state(pol.at(0), (32, cfg.d_model), batch=8,
                                    dtype=jnp.bfloat16)]
               if (bp.needs_fw_buffer or bp.needs_bw_buffer) else [])
        batch = make_batch(cfg, toks)
        ids = jnp.arange(8, dtype=jnp.int32)
        for _ in range(steps):
            params, ostate, bst, _ = step(params, ostate, bst, batch, ids)
        return params, pol

    @classmethod
    def _nll(cls, params, pol, compress):
        cfg, toks = cls._data()
        logits = transformer.forward_eval(params, make_batch(cfg, toks),
                                          cfg, pol, compress=compress,
                                          wire=True)
        return float(lm_loss(logits[:, :-1], jnp.asarray(toks)[:, 1:]))

    @classmethod
    def _engine_token_acc(cls, params, pol, compress):
        """Serve the memorized rows' prefixes through the engine and score
        the generated continuation against the memorized suffix."""
        cfg, toks = cls._data()
        eng = ContinuousEngine(params, cfg, pol, compress=compress,
                               num_slots=4, max_seq=96)
        for row in toks[:4]:
            eng.submit(row[:16], max_new_tokens=15)
        done = {r.req_id: r.out for r in eng.drain()}
        hits = sum(int(np.sum(done[i] == toks[i, 16:31]))
                   for i in range(4))
        return hits / (4 * 15)

    def test_topk_trained_needs_compression_at_serve(self):
        params, pol = self._overfit(topk_policy(0.05))
        nll_c = self._nll(params, pol, compress=True)
        nll_u = self._nll(params, pol, compress=False)
        # measured gap ~0.7 nats at these settings; 0.15 leaves slack
        assert nll_u - nll_c > 0.15, \
            "TopK-trained model should degrade served uncompressed " \
            f"(F3): nll_c={nll_c:.4f} nll_u={nll_u:.4f}"
        acc_c = self._engine_token_acc(params, pol, compress=True)
        acc_u = self._engine_token_acc(params, pol, compress=False)
        assert acc_c > acc_u, \
            "engine-served memorized continuation: compressed acc " \
            f"{acc_c:.3f} should beat uncompressed {acc_u:.3f}"

    def test_ef_trained_serves_uncompressed_without_drop(self):
        params, pol = self._overfit(ef_policy(0.05, "ef"))
        nll_c = self._nll(params, pol, compress=True)
        nll_u = self._nll(params, pol, compress=False)
        # EF compensates the compression error during training, so the
        # learned function is the UNCOMPRESSED one (measured: nll_u is
        # ~3.8 nats BETTER; assert merely "no drop")
        assert nll_u - nll_c < 0.15, \
            "EF-trained model should serve uncompressed without a " \
            f"quality drop: nll_c={nll_c:.4f} nll_u={nll_u:.4f}"


class TestWireEvalMatchesSimulated:
    def test_topk_wire_matches_in_process(self):
        """The codec-routed stage cut reproduces the simulated TopK
        boundary up to bf16 magnitude TIES: the wire payload carries
        exactly k (values, indices) pairs while the in-process mask keeps
        every entry >= the k-th magnitude, so on tied magnitudes the
        simulated C(x) may keep a few extra.  Everything else is equal."""
        from repro.core.boundary import boundary_eval, boundary_wire_eval
        cfg = get("gpt2-small", smoke=True)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.d_model),
                              jnp.bfloat16)
        sim = np.asarray(boundary_eval(TOP10.at(0), x, True), np.float32)
        wire = np.asarray(boundary_wire_eval(TOP10.at(0), x, True),
                          np.float32)
        k = int(round(0.10 * 24 * cfg.d_model))
        assert (wire != 0).sum(axis=(1, 2)).tolist() == [k, k]  # exactly k
        assert (sim != 0).sum() >= (wire != 0).sum()            # ties extra
        agree = (sim == wire).mean()
        assert agree > 0.995, "wire and simulated TopK disagree on " \
                              f"{(1 - agree):.2%} of elements (ties only " \
                              "should differ)"
        # end-to-end logits stay close through the full stack
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.random.RandomState(5).randint(
            1, cfg.vocab_size, (2, 24)).astype(np.int32)
        lw = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                      TOP10, compress=True, wire=True)
        ls = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                      TOP10, compress=True, wire=False)
        np.testing.assert_allclose(np.asarray(lw, np.float32),
                                   np.asarray(ls, np.float32), atol=0.5)

    def test_q8_wire_close_to_in_process(self):
        """q8 packs per request on the wire (per-tensor in-process) —
        close, not identical."""
        from repro.core.policy import quant_policy
        cfg = get("gpt2-small", smoke=True)
        pol = CompressionPolicy(num_stages=2, boundary=quant_policy(8, 8))
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.random.RandomState(5).randint(
            1, cfg.vocab_size, (2, 24)).astype(np.int32)
        wire = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                        pol, compress=True, wire=True)
        sim = transformer.forward_eval(params, make_batch(cfg, toks), cfg,
                                       pol, compress=True, wire=False)
        np.testing.assert_allclose(np.asarray(wire, np.float32),
                                   np.asarray(sim, np.float32),
                                   atol=0.25, rtol=0.25)


class TestEngineGuards:
    def test_recurrent_arch_rejected(self):
        cfg = get("rwkv6-3b", smoke=True)
        params = {"stub": jnp.zeros(())}
        with pytest.raises(ValueError, match="continuous batching"):
            ContinuousEngine(params, cfg, num_slots=2)

    def test_vision_arch_rejected(self):
        """The vision patch prefix splices into the sequence FRONT — the
        region bucket left-padding occupies — so pixtral must be refused,
        not silently served with masked/clobbered patches."""
        cfg = get("pixtral-12b", smoke=True)
        params = {"stub": jnp.zeros(())}
        with pytest.raises(ValueError, match="vision"):
            ContinuousEngine(params, cfg, num_slots=2)

    def test_warmup_compiles_chunk_despite_tight_headroom(self):
        """Geometry where no warmup request ever satisfies the chunkable
        condition (largest bucket leaves < tick_chunk headroom): the
        multi-tick program must still be compiled by warmup, or the first
        long production request recompiles mid-serving."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ContinuousEngine(params, cfg, TOP10, num_slots=4,
                               max_seq=64, max_prompt=60)
        warm = eng.warmup()
        assert warm["decode_chunk_compiles"] == 1
        rng = np.random.RandomState(1)
        eng.submit(rng.randint(1, cfg.vocab_size, 4).astype(np.int32),
                   max_new_tokens=20)
        eng.drain()
        assert eng.compile_stats() == warm

    def test_overlong_request_rejected(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params, max_seq=64)
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(np.zeros(30, np.int32), max_new_tokens=60)

    def test_throughput_probe_reports_split(self):
        from repro.serve.engine import ServeEngine
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, max_batch=2, max_seq=64)
        probe = eng.throughput_probe(2, 8, 4)
        for key in ("prefill_tok_per_s", "decode_tok_per_s", "tok_per_s",
                    "warm_s"):
            assert key in probe and probe[key] >= 0
        assert probe["decode_tok_per_s"] > 0


class TestPagedEngine:
    """Prefix-sharing paged KV + chunked prefill (serve/pages.py wired
    through ContinuousEngine paged mode)."""

    def _outs(self, eng, prompts, news, eos=None):
        for p, n in zip(prompts, news):
            eng.submit(p, max_new_tokens=n, eos_token=eos)
        return {r.req_id: r.out.copy() for r in eng.drain()}

    def test_paged_solo_identity_prefix_on_and_off(self):
        """Batched paged serving == solo paged serving, with the prefix
        cache both enabled and disabled."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(7)
        lens, news = [5, 19, 7, 30, 12, 3], [6, 3, 9, 4, 1, 7]
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in lens]
        for prefix in (True, False):
            kw = dict(prefix_cache=prefix, prefill_chunk=8, page_size=8)
            batched = self._outs(_engine(cfg, params, **kw), prompts, news)
            solo_eng = _engine(cfg, params, **kw)
            for i, (p, n) in enumerate(zip(prompts, news)):
                solo_eng.submit(p, max_new_tokens=n)
                (solo,) = solo_eng.drain()
                np.testing.assert_array_equal(
                    solo.out, batched[i],
                    err_msg=f"req {i} differs batched vs alone "
                            f"(prefix_cache={prefix})")

    def test_chunk_size_never_changes_output(self):
        """Per-(request, token) wire packing makes the output independent
        of prefill chunking — chunked, whole-prompt and prefix-cached
        ingestion all produce the same tokens."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(1)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (5, 23, 17, 30)]
        ref = None
        for chunk, prefix in ((None, True), (4, False), (8, True),
                              (16, False)):
            eng = _engine(cfg, params, num_slots=2, prefix_cache=prefix,
                          prefill_chunk=chunk)
            out = self._outs(eng, prompts, [6] * 4)
            if ref is None:
                ref = out
            for i in ref:
                np.testing.assert_array_equal(
                    ref[i], out[i],
                    err_msg=f"chunk={chunk} prefix={prefix} changed req "
                            f"{i}'s output")

    def test_prefix_hits_reuse_pages_and_keep_output(self):
        """Requests sharing a prompt prefix skip its prefill (counted in
        prefix_hits/prefix_hit_tokens) and still produce exactly the
        cold output."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(2)
        shared = rng.randint(1, cfg.vocab_size, 24).astype(np.int32)
        prompts = [np.concatenate([shared, rng.randint(
            1, cfg.vocab_size, n).astype(np.int32)]) for n in (5, 9, 3)]
        cold = {}
        for i, p in enumerate(prompts):
            eng = _engine(cfg, params, prefix_cache=True, prefill_chunk=8,
                          page_size=8)
            eng.submit(p, max_new_tokens=6)
            cold[i] = eng.drain()[0].out.copy()
        eng = _engine(cfg, params, num_slots=2, prefix_cache=True,
                      prefill_chunk=8, page_size=8)
        warm = self._outs(eng, prompts, [6] * 3)
        warm2 = self._outs(eng, prompts, [6] * 3)
        for i in cold:
            np.testing.assert_array_equal(cold[i], warm[i])
            np.testing.assert_array_equal(cold[i], warm2[i + 3])
        s = eng.stats()
        assert s["prefix_hits"] >= 3              # every resubmit hits
        assert s["prefix_hit_tokens"] >= 3 * 16   # >= 2 shared pages each
        eng.pages.check_invariants()

    def test_tight_pool_backpressure_same_output(self):
        """A pool far smaller than slots x max_seq forces admission
        waits and LRU eviction — outputs must not change."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size,
                               rng.randint(3, 30)).astype(np.int32)
                   for _ in range(8)]
        kw = dict(num_slots=2, max_seq=64, prefix_cache=True,
                  prefill_chunk=8, page_size=8)
        big = self._outs(_engine(cfg, params, **kw), prompts, [6] * 8)
        tight_eng = _engine(cfg, params, num_pages=12, **kw)
        tight = self._outs(tight_eng, prompts, [6] * 8)
        for i in big:
            np.testing.assert_array_equal(big[i], tight[i])
        tight_eng.pages.check_invariants()
        assert tight_eng.stats()["active_pages"] == 0

    def test_paged_zero_recompiles(self):
        """Warmup compiles the full paged program set; a mixed workload
        with evictions, refills and prefix hits adds ZERO jit entries."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params, prefix_cache=True, prefill_chunk=8,
                      page_size=8)
        warm = eng.warmup()
        assert warm["decode_compiles"] == 1
        assert warm["span_compiles"] == 1         # one fixed chunk shape
        rng = np.random.RandomState(0)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (3, 40, 8, 22, 15, 5, 33, 11, 7, 19)]
        # duplicates of prompts with >= 1 full page (len > page_size=8)
        # guarantee prefix hits inside the measured window
        prompts += [prompts[1], prompts[3], prompts[6]]
        self._outs(eng, prompts, [4, 2, 9, 1, 6, 3, 8, 5, 2, 7, 3, 4, 5])
        assert eng.compile_stats() == warm, \
            "paged eviction/refill/prefix-hit recompiled a program"
        assert eng.stats()["prefix_hits"] >= 3

    def test_window_arch_rejected(self):
        cfg = get("mixtral-8x7b", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="sliding-window"):
            _engine(cfg, params, prefix_cache=True)


class TestSpeculativeDecoding:
    """Draft-proposed, target-verified greedy decoding: output must be
    EXACTLY the non-speculative greedy stream — for any draft."""

    def _pair(self, arch, spec_k=3, draft_seed=9, **kw):
        cfg = get(arch, smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        draft = transformer.init_params(jax.random.PRNGKey(draft_seed),
                                        cfg)
        spec = _engine(cfg, params, draft_params=draft, draft_cfg=cfg,
                       draft_policy=TOP10, spec_k=spec_k, **kw)
        # the non-speculative reference must also be PAGED: ingestion mode
        # sets the wire-packing granularity (chunk size itself does not —
        # see test_chunk_size_never_changes_output)
        plain = _engine(cfg, params, prefix_cache=True,
                        prefill_chunk=kw.get("prefill_chunk"))
        return cfg, spec, plain

    def _assert_equal(self, cfg, spec, plain, lens, news, eos=None):
        rng = np.random.RandomState(5)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in lens]
        for p, n in zip(prompts, news):
            spec.submit(p, max_new_tokens=n, eos_token=eos)
            plain.submit(p, max_new_tokens=n, eos_token=eos)
        a = {r.req_id: r.out for r in spec.drain()}
        b = {r.req_id: r.out for r in plain.drain()}
        for i in b:
            np.testing.assert_array_equal(
                a[i], b[i], err_msg=f"speculative output differs from "
                                    f"plain greedy for req {i}")

    def test_spec_equals_greedy_gpt2(self):
        cfg, spec, plain = self._pair("gpt2-small", prefix_cache=True,
                                      prefill_chunk=8)
        self._assert_equal(cfg, spec, plain, [5, 19, 7, 30, 12],
                           [6, 3, 9, 1, 8])
        st = spec.stats()
        assert st["proposed"] > 0 and 0 <= st["acceptance_rate"] <= 1

    def test_spec_equals_greedy_granite(self):
        cfg, spec, plain = self._pair("granite-8b", spec_k=2)
        self._assert_equal(cfg, spec, plain, [4, 17, 11], [5, 2, 7])

    def test_perfect_draft_still_exact(self):
        """Draft == target params: high acceptance, same output."""
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        spec = _engine(cfg, params, draft_params=params, draft_cfg=cfg,
                       draft_policy=TOP10, spec_k=3)
        plain = _engine(cfg, params, prefix_cache=True)
        self._assert_equal(cfg, spec, plain, [5, 12, 25], [8, 8, 8])
        assert spec.stats()["acceptance_rate"] > 0.2

    def test_spec_with_eos_truncates_identically(self):
        cfg, spec, plain = self._pair("gpt2-small")
        # find an eos mid-stream from a plain run, then replay both
        probe = _engine(cfg, transformer.init_params(
            jax.random.PRNGKey(0), cfg), prefix_cache=True)
        rng = np.random.RandomState(5)
        p0 = rng.randint(1, cfg.vocab_size, 5).astype(np.int32)
        probe.submit(p0, max_new_tokens=6)
        eos = int(probe.drain()[0].out[3])
        self._assert_equal(cfg, spec, plain, [5, 19, 7], [6, 9, 8],
                           eos=eos)

    def test_spec_zero_recompiles(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        draft = transformer.init_params(jax.random.PRNGKey(9), cfg)
        eng = _engine(cfg, params, prefix_cache=True, prefill_chunk=8,
                      draft_params=draft, draft_cfg=cfg,
                      draft_policy=TOP10, spec_k=3)
        warm = eng.warmup()
        assert warm["verify_compiles"] == 1
        assert warm["propose_compiles"] == 1
        rng = np.random.RandomState(0)
        for l, n in zip((3, 25, 8, 14, 30), (4, 7, 2, 9, 5)):
            eng.submit(rng.randint(1, cfg.vocab_size, l).astype(np.int32),
                       max_new_tokens=n)
        eng.drain()
        assert eng.compile_stats() == warm

    def test_spec_requires_greedy(self):
        cfg = get("gpt2-small", smoke=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="greedy"):
            _engine(cfg, params, draft_params=params, draft_cfg=cfg,
                    sampling=SamplingConfig(temperature=1.0))

    def test_accept_greedy_semantics(self):
        from repro.serve.speculative import accept_greedy
        props = np.asarray([7, 8, 9])
        # target agrees on 7, 8 then diverges
        assert accept_greedy(props, np.asarray([7, 8, 5, 1]), 3) == 2
        # full agreement: a == k (emission then caps at k)
        assert accept_greedy(props, np.asarray([7, 8, 9, 4]), 3) == 3
        # immediate divergence
        assert accept_greedy(props, np.asarray([1, 2, 3, 4]), 3) == 0
