"""Pallas kernel validation (interpret mode on CPU; TPU is the target).

Sweeps shapes x dtypes, asserts allclose (mostly bit-exact) against the
pure-jnp oracles in kernels/ref.py, plus property tests tying the bisection
TopK to the exact sort-based TopK.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from conftest import hypothesis_or_stubs
given, settings, st = hypothesis_or_stubs()

from repro.kernels import ref
from repro.kernels.ops import (quant_dequant_op, quant_dequant_st,
                               topk_block_op, topk_block_st)
from repro.kernels.quantize import (dequantize_wire, quant_dequant,
                                    quantize_wire)
from repro.kernels.topk_mask import topk_block

SHAPES = [(8, 128), (32, 256), (64, 512), (256, 1024), (16, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_kernel_matches_ref(shape, dtype, bits):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    got = np.asarray(quant_dequant(x, bits, block=(8, 128), interpret=True),
                     np.float32)
    want = np.asarray(ref.quant_dequant_ref(x, bits, block=(8, 128)),
                      np.float32)
    # XLA may fuse (x-min)/scale as (x-min)*(1/scale): a value sitting
    # exactly on a rounding tie can land one level apart.  Allow <=0.1% of
    # entries to differ by at most one quantization step.
    step = float((x.max() - x.min()).astype(np.float32)) / ((1 << bits) - 1)
    diff = np.abs(got - want)
    assert diff.max() <= step * 1.01 + 1e-6
    # bf16 inputs at 8 bits: step ~ bf16 ulp, so ties are denser
    assert (diff > 1e-6).mean() <= 5e-3


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_topk_kernel_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    got = topk_block(x, 0.1, block=(8, 128), interpret=True)
    want = ref.topk_block_ref(x, 0.1, block=(8, 128))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32))


def test_quantize_wire_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 512))
    codes, meta = quantize_wire(x, 8, block=(8, 128), interpret=True)
    rcodes, rmeta = ref.quantize_wire_ref(x, 8, block=(8, 128))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
    np.testing.assert_allclose(np.asarray(meta), np.asarray(rmeta), rtol=1e-6)
    y = dequantize_wire(codes, meta, block=(8, 128))
    err = np.abs(np.asarray(y - x))
    # per-tile 8-bit error bound
    assert err.max() < (x.max() - x.min()) / 255 + 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       k=st.sampled_from([0.5, 0.3, 0.2, 0.1, 0.05]),
       bn=st.sampled_from([128, 256, 512]))
def test_bisection_topk_close_to_exact(seed, k, bn):
    """Property: bisection TopK keeps the same entries as exact sort-based
    TopK per tile (ties at the threshold may add a few extra)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, bn * 2))
    approx = np.asarray(ref.topk_block_ref(x, k, block=(16, bn)))
    exact = np.asarray(ref.topk_exact_block_ref(x, k, block=(16, bn)))
    # every exact-kept entry is kept by the bisection
    kept_exact = exact != 0
    assert np.all(approx[kept_exact] == exact[kept_exact])
    # and the bisection keeps at most a whisker more
    n_extra = (approx != 0).sum() - kept_exact.sum()
    assert 0 <= n_extra <= 0.01 * x.size + 16


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 6, 8]))
def test_per_tile_quant_no_worse_than_global(seed, bits):
    """Property: per-tile scaling error <= per-tensor scaling error."""
    from repro.core.compressors import quantize_dequantize
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 256)) \
        * jnp.linspace(0.1, 10.0, 32)[:, None]     # heteroscedastic rows
    tile = ref.quant_dequant_ref(x, bits, block=(8, 128))
    glob = quantize_dequantize(x, bits)
    assert (float(jnp.abs(tile - x).mean())
            <= float(jnp.abs(glob - x).mean()) + 1e-7)


class TestOpsWrappers:
    def test_any_rank(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 256))
        y = quant_dequant_op(x, 4)
        assert y.shape == x.shape
        z = topk_block_op(x, 0.2)
        assert z.shape == x.shape
        frac = float((z != 0).mean())
        assert 0.19 < frac < 0.25

    def test_fallback_when_not_128_divisible(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 100))
        y = quant_dequant_op(x, 8)
        assert y.shape == x.shape
        z = topk_block_op(x, 0.5)
        assert abs(float((z != 0).mean()) - 0.5) < 0.1

    def test_straight_through_grads(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 256))
        g1 = jax.grad(lambda x: quant_dequant_st(x, 4).sum())(x)
        np.testing.assert_allclose(np.asarray(g1), 1.0)
        g2 = jax.grad(lambda x: topk_block_st(x, 0.1).sum())(x)
        np.testing.assert_allclose(np.asarray(g2), 1.0)

    def test_jit_compiles_once(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (8, 512))
        y1 = quant_dequant_op(x, 4)
        y2 = quant_dequant_op(x + 1, 4)
        assert y1.shape == y2.shape
