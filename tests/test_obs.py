"""Wire-level telemetry subsystem tests (repro/obs + the closed loop).

Core guarantees under test:
  * the tracer is ZERO-cost when disabled (module helpers no-op, shared
    null context, no events) and bounded when enabled (ring buffer drops
    oldest, counts drops);
  * exporters: JSONL round-trips through the schema validator; the
    Chrome-trace JSON carries the phase-specific fields Perfetto needs;
  * probes key ring pairs EXACTLY like ``collective_counts
    (by_pairs=True)`` keys the HLO audit — one vocabulary between the
    measurement and the compiled-program launch table;
  * ``bandwidth>=X`` policy rules close the loop: two different probe
    measurements flip the resolved codec between epochs, while a no-probe
    run resolves bit-identically to the static PR-7 rule engine;
  * tracing ON does not change serve-engine outputs or its jit caches.
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.policy import (CompressionPolicy, parse_policy_rules,
                               quant_policy, resolve_policy, topk_policy)
from repro.obs import trace
from repro.obs.export import (EVENT_SCHEMA, to_chrome_trace, to_jsonl,
                              validate_events, validate_jsonl)
from repro.obs.probes import (LinkMeasurement, boundary_bandwidth,
                              pairs_key, ring_pairs)
from repro.obs.quality import QualityTap, feedback_norms, relative_error


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts AND ends with the global tracer disabled."""
    trace.disable()
    yield
    trace.disable()


class TestTracer:
    def test_span_counter_instant_phases(self):
        tr = trace.enable()
        with trace.span("a.span", cat="t", k=1) as args:
            args["late"] = 2
        trace.counter("a.counter", cat="t", depth=3)
        trace.instant("a.instant", cat="t", tag="x")
        evs = tr.drain()
        assert [(e.name, e.ph) for e in evs] == [
            ("a.span", "X"), ("a.counter", "C"), ("a.instant", "i")]
        assert evs[0].args == {"k": 1, "late": 2}
        assert evs[0].dur >= 0 and evs[0].ts >= 0
        assert tr.drain() == []                    # drain pops

    def test_ring_buffer_drops_oldest_and_counts(self):
        tr = trace.enable(capacity=4)
        for i in range(7):
            trace.instant(f"e{i}")
        assert tr.dropped == 3
        assert [e.name for e in tr.snapshot()] == ["e3", "e4", "e5", "e6"]
        assert tr.stats() == {"buffered": 4, "dropped": 3, "capacity": 4}

    def test_disabled_helpers_are_noops(self):
        assert trace.get_tracer() is None
        trace.counter("x", v=1)
        trace.instant("x")
        with trace.span("x") as args:
            args["k"] = 1                          # writes to shared null
        # enabling afterwards shows none of the above was recorded
        tr = trace.enable()
        assert tr.snapshot() == []

    def test_span_times_the_block(self):
        import time
        tr = trace.enable()
        with trace.span("timed"):
            time.sleep(0.01)
        (ev,) = tr.drain()
        assert ev.dur >= 0.009

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match="capacity"):
            trace.enable(capacity=0)


class TestExport:
    def _events(self):
        tr = trace.enable()
        with trace.span("s", cat="train", loss=1.5):
            pass
        trace.counter("c", cat="serve", depth=2)
        trace.instant("i", cat="wire", codec="q8")
        return tr.drain()

    def test_jsonl_roundtrip_validates(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        assert to_jsonl(self._events(), p) == 3
        assert validate_jsonl(p) == 3
        rows = [json.loads(x) for x in open(p)]
        assert [r["ph"] for r in rows] == ["X", "C", "i"]
        assert set(rows[0]) == set(EVENT_SCHEMA)

    def test_chrome_trace_phase_fields(self, tmp_path):
        p = str(tmp_path / "t.json")
        assert to_chrome_trace(self._events(), p) == 3
        doc = json.load(open(p))
        x, c, i = doc["traceEvents"]
        assert "dur" in x and x["ph"] == "X"
        assert i["s"] == "t" and i["ph"] == "i"
        # counter args must be numeric-or-stringified for the viewer
        assert all(isinstance(v, (int, float, str))
                   for v in c["args"].values())

    def test_validator_rejects_bad_events(self):
        ok = {"name": "n", "cat": "c", "ph": "i", "ts_us": 1.0,
              "dur_us": 0.0, "args": {}}
        assert validate_events([ok]) == 1
        for bad, msg in [
            ({**ok, "ph": "Z"}, "phase"),
            ({**ok, "ts_us": -1.0}, "negative"),
            ({**ok, "args": "notadict"}, "args"),
            ({k: v for k, v in ok.items() if k != "name"}, "missing"),
            ({**ok, "extra": 1}, "unknown"),
            ({**ok, "ts_us": True}, "ts_us"),      # bool is not numeric
        ]:
            with pytest.raises(ValueError, match=msg):
                validate_events([bad])


class TestQuality:
    def test_relative_error_zero_for_identity(self):
        x = jnp.ones((4, 8), jnp.float32)
        none = CompressionPolicy(num_stages=2).boundary.fw
        assert relative_error(x, none) == 0.0
        q4 = quant_policy(4, 4).fw
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        assert 0.0 < relative_error(x, q4) < 1.0

    def test_feedback_norms_skips_nonfloat(self):
        state = {"resid": jnp.ones((2, 3)), "ids": jnp.zeros((2,), jnp.int32),
                 "empty": jnp.zeros((0,))}
        norms = feedback_norms(state)
        assert set(norms) == {"['resid']"}
        assert norms["['resid']"] == pytest.approx(np.sqrt(6.0))

    def test_tap_gates_on_tracer_and_stride(self):
        tap = QualityTap((2, 16), every=2, dtype=jnp.float32)
        pol = CompressionPolicy(num_stages=3, boundary=quant_policy(8, 8))
        assert tap.maybe_sample(0, pol) is None    # tracing off
        tr = trace.enable()
        assert tap.maybe_sample(1, pol) is None    # off-stride
        rows = tap.maybe_sample(2, pol)
        assert [r["boundary"] for r in rows] == [0, 1]
        assert all(0.0 < r["fw_rel_err"] < 1.0 for r in rows)
        names = {e.name for e in tr.drain()}
        assert "quality.boundary0" in names
        assert "quality.codec.boundary1" in names

    def test_tap_validates_stride(self):
        with pytest.raises(ValueError, match="every"):
            QualityTap((2, 4), every=0)


class TestProbeKeying:
    """probes.pairs_key and dryrun.collective_counts(by_pairs=True) must
    speak the same ring vocabulary (pure parsers — no devices needed)."""

    HLO = """
  ENTRY main {
    p0 = bf16[8]{0} parameter(0)
    cp1 = bf16[8]{0} collective-permute(p0), source_target_pairs={{0,2},{2,0},{1,3},{3,1}}
    cp2 = bf16[8]{0} collective-permute(cp1), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}
    cp3 = bf16[8]{0} collective-permute-start(cp2), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}
    ar = bf16[8]{0} all-reduce(p0), replica_groups={{0,1,2,3}}
  }
    """

    def test_by_pairs_separates_rings(self):
        from repro.launch.dryrun import collective_counts
        counts = collective_counts(self.HLO, by_pairs=True)
        dp_ring = "collective-permute|{{0,2},{1,3},{2,0},{3,1}}"
        pp_ring = "collective-permute|{{0,1},{1,0},{2,3},{3,2}}"
        # NOTE: keys preserve the HLO's own pair order; the dp ring above
        # appears exactly as printed in the canned text
        assert counts["collective-permute|{{0,2},{2,0},{1,3},{3,1}}"] == 1
        assert counts[pp_ring] == 2                # -start counts once
        assert counts["all-reduce|{{0,1,2,3}}"] == 1
        assert dp_ring not in counts               # sorted != HLO order

    def test_pairs_key_is_sorted_and_formatted(self):
        key = pairs_key({(2, 0), (0, 2), (3, 1), (1, 3)})
        assert key == "{{0,2},{1,3},{2,0},{3,1}}"

    def test_ring_pairs_on_1d_mesh(self):
        mesh = jax.make_mesh((jax.device_count(),), ("stage",))
        n = jax.device_count()
        pairs = ring_pairs(mesh, "stage")
        ids = [d.id for d in np.asarray(mesh.devices).ravel()]
        want = {(ids[r], ids[(r + 1) % n]) for r in range(n)}
        assert pairs == want

    def test_boundary_bandwidth_accessors(self):
        m = LinkMeasurement("stage", "{{0,1}}", payload_bytes=1000,
                            seconds=0.001)
        assert m.bytes_per_s == pytest.approx(1e6)
        assert boundary_bandwidth(None) is None
        assert boundary_bandwidth(2.5e9) == 2.5e9
        assert boundary_bandwidth(m) == pytest.approx(1e6)
        slow = LinkMeasurement("data", "{{0,1}}", 1000, 0.01)
        assert boundary_bandwidth({"stage": m, "data": slow}) \
            == pytest.approx(1e6)                  # stage axis preferred
        assert boundary_bandwidth({"data": slow, "x": m}) \
            == pytest.approx(1e5)                  # else slowest ring
        assert boundary_bandwidth({}) is None


class TestBandwidthRules:
    def test_parse_and_resolve_with_bandwidth(self):
        rules = parse_policy_rules("none@bandwidth>=5e9;q4@bandwidth<1e6;q8")
        sizes = 4096
        # no probe: bandwidth terms never fire -> q8 everywhere, exactly
        # the static resolution (degenerate no-probe identity)
        static = resolve_policy(rules, sizes)
        assert static.boundary.fw.name == "q8"
        assert resolve_policy(rules, sizes, bandwidth=None).name \
            == static.name
        fast = resolve_policy(rules, sizes, bandwidth=6e9)
        assert fast.boundary.fw.name == "none"
        slow = resolve_policy(rules, sizes, bandwidth=1e3)
        assert slow.boundary.fw.name == "q4"

    def test_bandwidth_conds_in_rule_name(self):
        rules = parse_policy_rules("q8@bandwidth>=1e9")
        assert "bandwidth>=1e+09" in rules.rules[0].name

    def test_integer_thresholds_still_required(self):
        with pytest.raises(ValueError, match="integers"):
            parse_policy_rules("q8@size>=1.5")

    def test_unknown_cond_rejected(self):
        with pytest.raises(ValueError, match="bad rule condition"):
            parse_policy_rules("q8@latency>=3")


class TestClosedLoop:
    """The tentpole acceptance: probe measurements flip the chosen codec
    between epochs; without a probe the run matches static resolution."""

    CFG = None

    @classmethod
    def _cfg_data(cls):
        from repro.data.synthetic import LMData
        from repro.models.config import ModelConfig
        cfg = ModelConfig(
            arch_id="obs-loop", family="dense", num_layers=4, d_model=32,
            num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
            vocab_size=64, pos_embed="rope", norm="layernorm", mlp="gelu",
            max_seq=16)
        data = LMData(num_train=32, num_test=8, seq_len=16, vocab=64)
        return cfg, data

    def test_probe_flips_codec_between_epochs(self):
        from repro.train.loop import run_lm_experiment
        cfg, data = self._cfg_data()
        rules = parse_policy_rules("none@bandwidth>=5e9;q8")
        meas = iter([6e9, 1e3, 1e3])               # fast, then congested
        tr = trace.enable()
        res = run_lm_experiment(cfg, rules, epochs=3, batch=8, data=data,
                                bandwidth_probe=lambda: next(meas))
        assert len(res.policy_curve) == 3
        assert res.policy_curve[0] != res.policy_curve[1]  # the flip
        assert res.policy_curve[1] == res.policy_curve[2]  # ...then held
        flips = [e for e in tr.drain() if e.name == "policy.flip"]
        assert len(flips) == 1 and flips[0].args["epoch"] == 1
        assert all(np.isfinite(res.train_curve))

    def test_no_probe_matches_static_resolution_exactly(self):
        from repro.train.loop import run_lm_experiment
        cfg, data = self._cfg_data()
        rules = parse_policy_rules("none@bandwidth>=5e9;q8")
        static = resolve_policy(rules, data.seq_len * cfg.d_model)
        r_rules = run_lm_experiment(cfg, rules, epochs=1, batch=8,
                                    data=data)
        r_static = run_lm_experiment(cfg, static, epochs=1, batch=8,
                                     data=data)
        assert r_rules.policy_curve == [static.name]
        assert r_rules.train_curve == r_static.train_curve  # bit-identical
        assert r_rules.loss_on == r_static.loss_on


class TestServeTracingIdentity:
    """Tracing ON must not change tokens or compile counts."""

    def test_tokens_and_jit_caches_unchanged(self):
        from repro.configs.registry import get
        from repro.serve.engine import ContinuousEngine
        cfg = get("gpt2-small", smoke=True)
        from repro.models import transformer
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        pol = CompressionPolicy(num_stages=2, boundary=topk_policy(0.10))
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size, l).astype(np.int32)
                   for l in (5, 11, 7)]

        def serve():
            eng = ContinuousEngine(params, cfg, pol, num_slots=2,
                                   max_seq=64)
            eng.warmup()
            warm = eng.compile_stats()
            for i, p in enumerate(prompts):
                eng.submit(p, max_new_tokens=4, seed=i)
            done = eng.drain()
            assert eng.compile_stats() == warm     # no tick recompiles
            return {r.req_id: r.out.copy() for r in done}

        base = serve()
        tr = trace.enable()
        traced = serve()
        for rid in base:
            np.testing.assert_array_equal(base[rid], traced[rid])
        names = {e.name for e in tr.snapshot()}
        assert {"serve.decode", "serve.sched",
                "serve.request_done"} <= names


class TestSchedulerSnapshot:
    def test_snapshot_counts(self):
        from repro.serve.scheduler import Scheduler
        s = Scheduler(3)
        assert s.snapshot() == {"queued": 0, "active_slots": 0,
                                "free_slots": 3, "completed": 0}
        for i in range(4):
            s.submit(np.array([1, 2], np.int32), max_new_tokens=1)
        placed = s.fills()
        assert len(placed) == 3
        snap = s.snapshot()
        assert snap["queued"] == 1 and snap["active_slots"] == 3
        assert snap["free_slots"] == 0
        s.started(placed[0][0], 7)                 # 1-token req completes
        snap = s.snapshot()
        assert snap["completed"] == 1 and snap["free_slots"] == 1
