"""Trace exporters: JSONL event log + Chrome-trace (Perfetto) JSON.

One event schema, two serializations:

  * JSONL — one event object per line, machine-diffable, streamed by the
    CI metrics-smoke step and validated against :data:`EVENT_SCHEMA`;
  * Chrome trace — ``{"traceEvents": [...]}`` loadable by Perfetto
    (https://ui.perfetto.dev) and ``chrome://tracing``; spans ("X")
    carry microsecond ts/dur, counters ("C") render as tracks.

The schema is deliberately flat so downstream tooling needs no codegen:

  name    str   event name, dotted namespace ("train.step", "serve.tick")
  cat     str   category ("train" | "serve" | "wire" | "policy" | ...)
  ph      str   phase: "X" complete span, "C" counter, "i" instant
  ts_us   num   start time, microseconds since tracer epoch
  dur_us  num   duration in microseconds (0 for C / i)
  args    dict  event payload (codec names, byte counts, depths, ...)
"""
from __future__ import annotations

import json
from typing import Iterable, List, Union

from repro.obs.trace import PHASES, TraceEvent

# field name -> (allowed types, required)
EVENT_SCHEMA = {
    "name": (str, True),
    "cat": (str, True),
    "ph": (str, True),
    "ts_us": ((int, float), True),
    "dur_us": ((int, float), True),
    "args": (dict, True),
}


def _dicts(events: Iterable) -> List[dict]:
    return [e.to_dict() if isinstance(e, TraceEvent) else dict(e)
            for e in events]


def to_jsonl(events: Iterable, path: str) -> int:
    """Write one JSON object per line; returns the event count."""
    rows = _dicts(events)
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return len(rows)


def to_chrome_trace(events: Iterable, path: str, *,
                    pid: int = 0, tid: int = 0) -> int:
    """Write the Chrome-trace/Perfetto JSON format.

    Counter args must be numeric in this format; non-numeric arg values
    (codec names etc.) are stringified into the args dict, which both
    viewers render in the detail pane."""
    rows = []
    for e in _dicts(events):
        rec = {"name": e["name"], "cat": e["cat"], "ph": e["ph"],
               "ts": e["ts_us"], "pid": pid, "tid": tid,
               "args": e["args"]}
        if e["ph"] == "X":
            rec["dur"] = e["dur_us"]
        if e["ph"] == "i":
            rec["s"] = "t"                     # instant scope: thread
        if e["ph"] == "C":
            rec["args"] = {k: (v if isinstance(v, (int, float))
                               and not isinstance(v, bool) else str(v))
                           for k, v in e["args"].items()}
        rows.append(rec)
    with open(path, "w") as f:
        json.dump({"traceEvents": rows,
                   "displayTimeUnit": "ms"}, f)
    return len(rows)


def validate_events(events: Iterable[Union[dict, TraceEvent]]) -> int:
    """Validate events against :data:`EVENT_SCHEMA`; returns the count.

    Raises ``ValueError`` naming the first offending event and field —
    the CI metrics-smoke gate."""
    n = 0
    for i, e in enumerate(_dicts(events)):
        for field, (types, required) in EVENT_SCHEMA.items():
            if field not in e:
                if required:
                    raise ValueError(
                        f"event {i} ({e.get('name', '?')!r}): missing "
                        f"required field {field!r}")
                continue
            if not isinstance(e[field], types) or isinstance(e[field], bool):
                raise ValueError(
                    f"event {i} ({e.get('name', '?')!r}): field {field!r} "
                    f"has type {type(e[field]).__name__}, expected {types}")
        if e["ph"] not in PHASES:
            raise ValueError(f"event {i} ({e['name']!r}): phase "
                             f"{e['ph']!r} not in {PHASES}")
        if e["ts_us"] < 0 or e["dur_us"] < 0:
            raise ValueError(f"event {i} ({e['name']!r}): negative "
                             "ts_us/dur_us")
        extra = set(e) - set(EVENT_SCHEMA)
        if extra:
            raise ValueError(f"event {i} ({e['name']!r}): unknown "
                             f"fields {sorted(extra)}")
        n += 1
    return n


def validate_jsonl(path: str) -> int:
    """Parse + schema-validate a JSONL trace file; returns the count."""
    with open(path) as f:
        events = [json.loads(line) for line in f if line.strip()]
    return validate_events(events)
