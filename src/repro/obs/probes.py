"""Bandwidth probes: measured bytes/s per link pair, closing the loop.

Agarwal et al. (2103.00543): whether compression pays off is a function
of the MEASURED link bandwidth, not the nominal one.  This module times a
real ``ppermute`` ring hop per mesh axis and reports achieved bytes/s per
link-pair set, keyed exactly like ``launch.dryrun.collective_counts
(by_pairs=True)`` keys the HLO audit — ``"{{src,dst},...}"`` — so a probe
measurement, the compiled-HLO launch audit, and a ``bandwidth>=X``
:class:`~repro.core.policy.PolicyRule` predicate all speak about the same
ring.

The loop closes in ``train/loop.py``: a ``bandwidth_probe`` callable is
invoked between epochs, its measurement re-resolves the ``PolicyRules``
(a trace-time static re-resolution — an UNCHANGED resolved policy keeps
the jit cache, a changed one re-traces, exactly like the PR-7 rule
engine), and the chosen codec follows the wire.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Set, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.obs import trace
from repro.transport.base import shard_map_compat as _shard_map


def ring_pairs(mesh: Mesh, axis: str) -> Set[Tuple[int, int]]:
    """Source->target device-id pairs of the ``axis`` ring on ``mesh``:
    within every slice along the other axes, position r sends to r+1
    (mod n).  Generalizes the benchmark's DP-ring helper to any axis of
    any mesh — the same pairs XLA records as ``source_target_pairs``."""
    dev = mesh.devices
    ax = mesh.axis_names.index(axis)
    n = dev.shape[ax]
    cols = np.moveaxis(dev, ax, 0).reshape(n, -1)
    pairs = set()
    for c in range(cols.shape[1]):
        for r in range(n):
            pairs.add((int(cols[r, c].id), int(cols[(r + 1) % n, c].id)))
    return pairs


def pairs_key(pairs: Set[Tuple[int, int]]) -> str:
    """``{{src,dst},...}`` formatting (sorted) — the suffix
    ``collective_counts(by_pairs=True)`` keys launches by."""
    return ("{" + ",".join("{%d,%d}" % p for p in sorted(pairs)) + "}")


@dataclasses.dataclass(frozen=True)
class LinkMeasurement:
    """Achieved bandwidth of one ring's links (the slowest link bounds a
    synchronous ring hop, so one number per ring is the honest grain)."""
    axis: str
    pairs: str                   # pairs_key(...) of the measured ring
    payload_bytes: int           # bytes each device put on the wire
    seconds: float               # best-of-repeats wall time of one hop
    hops: int = 1

    @property
    def bytes_per_s(self) -> float:
        return (self.payload_bytes * self.hops / self.seconds
                if self.seconds > 0 else float("inf"))

    def to_dict(self) -> dict:
        return {"axis": self.axis, "pairs": self.pairs,
                "payload_bytes": self.payload_bytes,
                "seconds": round(self.seconds, 6),
                "bytes_per_s": round(self.bytes_per_s, 1)}


def probe_ring(mesh: Mesh, axis: str, *, payload_bytes: int = 1 << 22,
               repeats: int = 3) -> LinkMeasurement:
    """Time one fused uint8 ring hop over ``axis`` (the exact shape of
    the transports' wire traffic: one packed buffer per hop) and report
    achieved bytes/s.  Best-of-``repeats`` after a warmup dispatch."""
    n = int(mesh.shape[axis])
    per = max(1, payload_bytes)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(b):
        return jax.lax.ppermute(b, axis, perm)

    shapes = tuple(mesh.shape[a] for a in mesh.axis_names)
    buf = jnp.zeros((*shapes, per), jnp.uint8)   # (…mesh dims…, per)/device
    spec = P(*mesh.axis_names)
    fn = jax.jit(_shard_map(hop, mesh, (spec,), spec))
    jax.block_until_ready(fn(buf))                        # compile + warm
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(buf))
        best = min(best, time.perf_counter() - t0)
    m = LinkMeasurement(axis=axis, pairs=pairs_key(ring_pairs(mesh, axis)),
                        payload_bytes=per, seconds=best)
    trace.instant("probe.ring", cat="probe", **m.to_dict())
    return m


def probe_mesh(mesh: Mesh, *, payload_bytes: int = 1 << 22,
               repeats: int = 3) -> Dict[str, LinkMeasurement]:
    """One ring measurement per mesh axis (stage hops vs DP ring on the
    2D ``(data, stage)`` mesh), keyed by axis name."""
    return {a: probe_ring(mesh, a, payload_bytes=payload_bytes,
                          repeats=repeats)
            for a in mesh.axis_names}


def boundary_bandwidth(measurements,
                       stage_axis: str = "stage") -> Optional[float]:
    """The single bytes/s number a ``bandwidth>=X`` policy predicate
    consumes: the stage-hop ring's achieved bandwidth (boundary payloads
    ride that ring), falling back to the slowest measured ring when no
    axis matches.  Accepts a measurement dict from :func:`probe_mesh`,
    one :class:`LinkMeasurement`, a plain float, or None."""
    if measurements is None:
        return None
    if isinstance(measurements, (int, float)):
        return float(measurements)
    if isinstance(measurements, LinkMeasurement):
        return measurements.bytes_per_s
    if stage_axis in measurements:
        return measurements[stage_axis].bytes_per_s
    if not measurements:
        return None
    return min(m.bytes_per_s for m in measurements.values())
