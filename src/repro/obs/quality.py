"""Compression-quality metrics: the paper's asymmetry as a live signal.

The paper's central findings are distortion findings — activations
tolerate less compression than gradients (Tables 1-3), AQ-SGD's
per-example buffers shrink the effective error over training (Sec. 2.5).
This tap samples them LIVE every N steps instead of only at end-of-run
loss curves:

  * per-boundary RELATIVE compression error — the codec roundtrip
    ``||x - C(x)|| / ||x||`` of each boundary's fw/bw compressor, run on
    the plain jnp reference path (``Compressor.__call__``), never the
    Pallas wire kernels: a debug tap, not the hot path;
  * feedback-buffer norms — L2 norms of every EF/EF21/AQ-SGD residual
    leaf in the training state, keyed by its pytree path (Wang et al.:
    the AQ-SGD buffer norm decaying over time IS the compensation
    working).

Everything here costs device compute, so it only runs when explicitly
sampled (``QualityTap`` gates on the step counter AND on tracing being
enabled); a disabled tracer short-circuits before any jnp call.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import CompressionPolicy
from repro.obs import trace


def relative_error(x, compressor) -> float:
    """``||x - C(x)||_2 / ||x||_2`` on the jnp reference codec path."""
    xf = x.astype(jnp.float32)
    err = jnp.linalg.norm((xf - compressor(x).astype(jnp.float32)).ravel())
    return float(err / jnp.maximum(jnp.linalg.norm(xf.ravel()), 1e-12))


def boundary_quality(policy: CompressionPolicy, x) -> List[dict]:
    """Per-boundary fw/bw relative compression error on sample tensor
    ``x`` ((batch, *feat); the transformer's uniform boundary shape —
    heterogeneous stacks call per boundary with each cut's shape)."""
    rows = []
    for i in range(policy.num_boundaries):
        bp = policy.at(i)
        rows.append({
            "boundary": i, "fw_codec": bp.fw.name, "bw_codec": bp.bw.name,
            "fw_rel_err": relative_error(x, bp.fw),
            "bw_rel_err": relative_error(x, bp.bw),
        })
    return rows


def feedback_norms(state) -> dict:
    """L2 norm of every float leaf in a feedback-state pytree, keyed by
    pytree path (empty leaves and integer leaves are skipped)."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        if not hasattr(leaf, "dtype") or leaf.size == 0 \
                or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        key = jax.tree_util.keystr(path).strip(".") or "leaf"
        out[key] = float(jnp.linalg.norm(
            leaf.astype(jnp.float32).ravel()))
    return out


class QualityTap:
    """Every-N-steps sampler wiring the metrics into the tracer.

    ``sample_shape``: the boundary tensor shape ((batch, *feat)) the
    roundtrip error is measured on; the sample is a fixed seeded normal
    (the codec's distortion on a reference distribution), so the series
    isolates POLICY changes — a codec flip between epochs moves the
    line, batch noise does not.
    """

    def __init__(self, sample_shape, *, every: int = 50,
                 dtype=jnp.bfloat16, seed: int = 0):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = every
        self._x = jax.random.normal(jax.random.PRNGKey(seed),
                                    sample_shape).astype(dtype)

    def maybe_sample(self, step: int, policy: CompressionPolicy,
                     bstates=None) -> Optional[List[dict]]:
        """Emit quality counters when tracing is on and ``step`` is on
        the sampling grid; returns the rows it emitted (None when
        skipped — the disabled path does no device work)."""
        tr = trace.get_tracer()
        if tr is None or step % self.every != 0:
            return None
        rows = boundary_quality(policy, self._x)
        for r in rows:
            tr.counter(f"quality.boundary{r['boundary']}", cat="quality",
                       fw_rel_err=round(r["fw_rel_err"], 6),
                       bw_rel_err=round(r["bw_rel_err"], 6))
            tr.instant(f"quality.codec.boundary{r['boundary']}",
                       cat="quality", step=step, fw_codec=r["fw_codec"],
                       bw_codec=r["bw_codec"])
        if bstates is not None:
            norms = feedback_norms(bstates)
            if norms:
                tr.counter("quality.feedback_norms", cat="quality",
                           **{k: round(v, 6) for k, v in norms.items()})
        return rows
