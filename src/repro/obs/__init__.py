"""Wire-level telemetry: traces, metrics and bandwidth probes.

Host-side observability for the train and serve loops.  Everything here
runs OUTSIDE the jit'd programs — instrumentation sites read static facts
at trace time (``eval_shape`` payload structs, codec names) and wall
clocks around the jit'd calls, so the telemetry layer adds ZERO device
ops and is free when disabled (the default).

  trace.py    span/counter API over a host-side ring buffer
  export.py   JSONL + Chrome-trace (Perfetto) exporters, event schema
  quality.py  per-boundary compression error / feedback-norm debug tap
  probes.py   achieved-bytes/s link probes feeding PolicyRules
"""
from repro.obs.trace import (Tracer, disable, enable, get_tracer,  # noqa: F401
                             instant, counter, span)
from repro.obs.export import (EVENT_SCHEMA, to_chrome_trace,  # noqa: F401
                              to_jsonl, validate_events, validate_jsonl)
