"""Lightweight span/counter tracing over a host-side ring buffer.

Design constraints (ISSUE 9 acceptance):

  * ZERO cost when disabled — the module-level helpers check one global
    and return a shared no-op; no event objects, no clock reads, and
    never any device ops (instrumentation sites only touch host state
    and trace-time static facts like ``eval_shape`` structs);
  * bounded memory when enabled — a ``deque(maxlen=capacity)`` ring
    buffer drops the OLDEST events and counts the drops, so a long run
    can leave tracing on without growing without bound;
  * exporter-agnostic events — one flat :class:`TraceEvent` record maps
    1:1 onto both the JSONL schema and the Chrome-trace format
    (obs/export.py).

Usage::

    from repro.obs import trace
    tracer = trace.enable()
    with trace.span("train.step", cat="train", step=3):
        ...                       # timed wall-clock span
    trace.counter("queue", cat="serve", depth=4)
    trace.instant("policy.resolved", cat="policy", name="q8")
    events = tracer.drain()

Timestamps are seconds since the tracer's epoch (``perf_counter`` based,
monotonic); exporters convert to microseconds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

# Chrome-trace phases we emit: X = complete span (ts + dur),
# C = counter sample, i = instant event.
PHASES = ("X", "C", "i")


@dataclasses.dataclass
class TraceEvent:
    """One telemetry record: a span, counter sample or instant marker."""
    name: str
    cat: str
    ph: str                      # one of PHASES
    ts: float                    # seconds since tracer epoch
    dur: float = 0.0             # seconds (spans only)
    args: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat, "ph": self.ph,
                "ts_us": round(self.ts * 1e6, 1),
                "dur_us": round(self.dur * 1e6, 1), "args": self.args}


class Tracer:
    """Host-side ring buffer of :class:`TraceEvent` records."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.events: Deque[TraceEvent] = deque(maxlen=capacity)
        self.dropped = 0
        self.t0 = time.perf_counter()

    def _now(self) -> float:
        return time.perf_counter() - self.t0

    def _append(self, ev: TraceEvent) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)

    # -- emit ---------------------------------------------------------------

    def instant(self, name: str, cat: str = "default", **args) -> None:
        self._append(TraceEvent(name, cat, "i", self._now(), 0.0, args))

    def counter(self, name: str, cat: str = "default", **values) -> None:
        """A counter sample: ``values`` are the tracked numeric series."""
        self._append(TraceEvent(name, cat, "C", self._now(), 0.0, values))

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "default", **args):
        """Wall-clock a ``with`` block as one complete ("X") event.

        Yields the event's mutable ``args`` dict so the block can attach
        results it only knows at the end (e.g. a loss value)."""
        t0 = self._now()
        try:
            yield args
        finally:
            self._append(TraceEvent(name, cat, "X", t0,
                                    self._now() - t0, args))

    # -- read ---------------------------------------------------------------

    def drain(self) -> List[TraceEvent]:
        """Pop and return every buffered event (oldest first)."""
        out = list(self.events)
        self.events.clear()
        return out

    def snapshot(self) -> List[TraceEvent]:
        """Buffered events without clearing (oldest first)."""
        return list(self.events)

    def stats(self) -> dict:
        return {"buffered": len(self.events), "dropped": self.dropped,
                "capacity": self.capacity}


# ---------------------------------------------------------------------------
# Global tracer: default-off; the module helpers are the instrumentation
# surface (one global check, a shared nullcontext when disabled)
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None
_NULL = contextlib.nullcontext({})


def enable(capacity: int = 65536) -> Tracer:
    """Install (and return) the global tracer; idempotent per-process
    enablement replaces any previous tracer."""
    global _TRACER
    _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def get_tracer() -> Optional[Tracer]:
    """The active tracer, or None when tracing is off (the default)."""
    return _TRACER


def span(name: str, cat: str = "default", **args):
    """Module-level span: a real timed span when tracing is enabled, a
    shared no-op context (no clock read, no allocation) otherwise."""
    t = _TRACER
    return t.span(name, cat, **args) if t is not None else _NULL


def instant(name: str, cat: str = "default", **args) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


def counter(name: str, cat: str = "default", **values) -> None:
    t = _TRACER
    if t is not None:
        t.counter(name, cat, **values)
