"""Mesh context for in-model sharding constraints.

Model code calls :func:`constrain` with a logical spec; when a mesh has been
installed (launcher / dry-run) this becomes
``jax.lax.with_sharding_constraint``, otherwise it is a no-op — so smoke
tests and single-device runs never touch device state.

Logical axis names used by model code:
  "batch"   -> ("pod", "data") (or ("data",) single-pod)
  "model"   -> tensor-parallel axis
  "expert"  -> expert-parallel axis (mapped onto "data")
  "seq"     -> sequence/cache sharding for batch=1 decode (mapped onto "data")
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None) -> None:
    _state.mesh = mesh
    _state.rules = rules or default_rules(mesh)


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> dict:
    return getattr(_state, "rules", {})


def default_rules(mesh: Optional[Mesh]) -> dict:
    """Map logical axes -> mesh axes for the production meshes."""
    if mesh is None:
        return {}
    names = mesh.axis_names
    rules = {}
    if "pod" in names:
        rules["batch"] = ("pod", "data")
    else:
        rules["batch"] = ("data",)
    # canonical tensor axis is "tensor" (core/parallel.py); the legacy
    # "model" mesh-axis name keeps resolving as an alias
    for tp_axis in ("tensor", "model"):
        if tp_axis in names:
            rules["model"] = (tp_axis,)
            break
    if "data" in names:
        rules["expert"] = ("data",)
        rules["seq"] = ("data",)
    return rules


class use_mesh:
    """``with use_mesh(mesh):`` installs mesh + rules for model code."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[dict] = None):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        self.prev = (get_mesh(), get_rules())
        set_mesh(self.mesh, self.rules)
        return self.mesh

    def __exit__(self, *exc):
        _state.mesh, _state.rules = self.prev
        return False


def _resolve(axis) -> Optional[Tuple[str, ...]]:
    if axis is None:
        return None
    rules = get_rules()
    if isinstance(axis, str):
        got = rules.get(axis)
        return got
    out = []
    for a in axis:
        got = rules.get(a)
        if got:
            out.extend(got)
    return tuple(out) or None


def constrain(x, *logical_axes):
    """Apply a sharding constraint by logical axis names (None = replicated).

    A logical axis that does not divide the corresponding dim is dropped
    (e.g. batch=1 decode cannot shard over "batch").
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    used = set()        # a mesh axis may shard at most ONE dim
    for dim, logical in zip(x.shape, logical_axes):
        resolved = _resolve(logical)
        if resolved is None:
            spec.append(None)
            continue
        # earlier dims win ties: e.g. ("batch","seq",...) with both mapping
        # onto "data" shards batch when it divides, else falls back to seq
        # (the batch=1 long-decode case).
        resolved = tuple(a for a in resolved if a not in used)
        size = 1
        for a in resolved:
            size *= axis_sizes[a]
        if resolved and dim % size == 0 and dim >= size:
            spec.append(resolved)
            used.update(resolved)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
