"""Parameter / batch / cache sharding rules for the production meshes.

Strategy (DESIGN.md §5): DP over ("pod","data") for the batch, TP over
the tensor axis for heads / d_ff / vocab, FSDP weight sharding over
"data", expert-parallel over "data" for MoE experts.  Rules are
name+shape based and degrade per-dim to replication when a dim is not
divisible by the axis.

Axis names route through core/parallel.py: the canonical tensor axis is
"tensor" (ParallelSpec / make_3d_mesh), with the historical "model" name
accepted as an alias — a rule naming either resolves to whichever the
mesh actually has, and to replication when the mesh has neither.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.parallel import AXIS_ALIASES


def tensor_axis(mesh: Mesh) -> Optional[str]:
    """The mesh's tensor-parallel axis name ("tensor", or the legacy
    "model" alias), or None when the mesh has no tensor axis."""
    for name in ("tensor", "model"):
        if name in mesh.axis_names:
            return name
    return None


def _resolve_names(mesh: Mesh, names) -> tuple:
    """Map logical axis names (+ aliases) onto the mesh's axes; names the
    mesh does not carry drop out (that dim replicates over them)."""
    out = []
    for n in (names if isinstance(names, tuple) else (names,)):
        if AXIS_ALIASES.get(n, n) == "tensor":
            n = tensor_axis(mesh)
        if n is not None and n in mesh.axis_names and n not in out:
            out.append(n)
    return tuple(out)


def _axis_size(mesh: Mesh, names) -> int:
    s = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for n in (names if isinstance(names, tuple) else (names,)):
        s *= sizes[n]
    return s


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(dim: int, mesh: Mesh, names) -> Optional[tuple]:
    if names is None:
        return None
    names = _resolve_names(mesh, names)
    if not names:
        return None
    return names if dim % _axis_size(mesh, names) == 0 else None


# parameter matrices whose FIRST trailing dim is the model-sharded
# contraction (outputs of TP regions): y = h @ W with h model-sharded.
_OUT_NAMES = ("wo", "out_proj", "lora_B", "w_lora_B", "wv@cm", "proj")


def _is_out(path: str) -> bool:
    if path.endswith("cm/wv"):
        return True
    name = path.rsplit("/", 1)[-1]
    return name in ("wo", "out_proj", "lora_B", "w_lora_B")


def param_spec(path: str, shape, mesh: Mesh) -> P:
    nd = len(shape)
    if nd <= 1:
        return P()
    # embeddings / heads: (V, d) -> vocab over model, d FSDP over data
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("embed", "lm_head", "dec_pos"):
        return P(_fit(shape[0], mesh, "model"), None)
    if leaf == "router":
        return P(*([None] * (nd - 2)), _fit(shape[-2], mesh, "model"), None)
    if "/experts/" in path and nd >= 3:
        # (..., E, in, out): experts over data (EP) + TP on in/out
        lead = [None] * (nd - 3)
        e = _fit(shape[-3], mesh, "data")
        if _is_out(path):
            return P(*lead, e, _fit(shape[-2], mesh, "model"), None)
        return P(*lead, e, None, _fit(shape[-1], mesh, "model"))
    # generic 2D-trailing matrices (+ leading scan dims)
    lead = [None] * (nd - 2)
    if _is_out(path):
        return P(*lead, _fit(shape[-2], mesh, "model"),
                 _fit(shape[-1], mesh, "data"))
    return P(*lead, _fit(shape[-2], mesh, "data"),
             _fit(shape[-1], mesh, "model"))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_shardings(mesh: Mesh, params_tree):
    """NamedSharding pytree for a params (or shape-struct) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = [NamedSharding(mesh, param_spec(_path_str(p), l.shape, mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(mesh: Mesh, opt_tree):
    """Moments mirror params; scalar step replicated."""
    def spec(path, leaf):
        ps = _path_str(path)
        if ps == "step" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the leading "mu/" / "nu/" container name
        sub = ps.split("/", 1)[1] if "/" in ps else ps
        return NamedSharding(mesh, param_spec(sub, leaf.shape, mesh))
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def batch_spec(shape, mesh: Mesh) -> P:
    """(B, ...) data inputs: batch over ("pod","data") when divisible."""
    b = _fit(shape[0], mesh, batch_axes(mesh))
    return P(b, *([None] * (len(shape) - 1)))


def batch_shardings(mesh: Mesh, tree):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), tree)


def cache_spec(path: str, shape, mesh: Mesh, batch_dim: int = 1) -> P:
    """Decode-cache leaves.  Attention k/v: (G, B, C, KV, hd); recurrent
    state (G, B, H, K, V); shift states (G, B, d).

    Preference order: batch over DP axes; KV-heads over model; if KV does
    not divide, the cache SEQ dim takes the model axis (flash-decode style);
    with batch=1 (long_500k) the seq dim additionally takes the data axis.
    """
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    b = shape[batch_dim]
    bspec = _fit(b, mesh, batch_axes(mesh))
    lead = [None] * batch_dim
    if leaf in ("k", "v") and nd == batch_dim + 4:
        _, c, kv, hd = shape[batch_dim:]
        kvspec = _fit(kv, mesh, "model")
        seq_axes = []
        if bspec is None:
            seq_axes.append("data")
            if "pod" in mesh.axis_names:
                seq_axes.insert(0, "pod")
        if kvspec is None:
            seq_axes.append("model")
        seqspec = _fit(c, mesh, tuple(seq_axes)) if seq_axes else None
        return P(*lead, bspec, seqspec, kvspec, None)
    if leaf == "S" and nd == batch_dim + 4:          # rwkv state
        return P(*lead, bspec, _fit(shape[batch_dim + 1], mesh, "model"),
                 None, None)
    if leaf == "ssm" and nd == batch_dim + 4:        # mamba state
        return P(*lead, bspec, _fit(shape[batch_dim + 1], mesh, "model"),
                 None, None)
    rest = [None] * (nd - batch_dim - 1)
    return P(*lead, bspec, *rest)


def cache_shardings(mesh: Mesh, cache_tree, batch_dim: int = 1):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = [NamedSharding(mesh, cache_spec(_path_str(p), l.shape, mesh,
                                          batch_dim))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
