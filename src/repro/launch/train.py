"""End-to-end training driver.

Trains any registry architecture (full or --smoke reduced variant) on the
synthetic LM stream with a boundary-compression policy, on the current
device set (CPU here; the same program lowers to the production mesh via
launch/dryrun.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 200 --batch 8 --seq 128 --policy top10reuse
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke \
      --steps 50 --policy q4q8 --grad-accum 2 --ckpt /tmp/mix.npz
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 50 --policy q4q8 --transport pipeline --stages 2
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 50 --transport pipeline --stages 2 --schedule 1f1b \
      --pipeline-microbatches 16
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 50 --policy q4q8 --transport pipeline --stages 2 \
      --schedule interleaved --virtual-stages 2
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 50 --mesh data=2,tensor=2 --wire data=q8,tensor=q8+ef:0.1
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
      --steps 20 --mesh data=2,stage=2,tensor=2 --wire stage=q8,tensor=q4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.configs.registry import ARCHS, get
from repro.obs import trace as obs_trace
from repro.core.boundary import init_boundary_state
from repro.core.parallel import spec_from_cli
from repro.core.policy import (CompressionPolicy, NO_POLICY, PolicyRules,
                               aqsgd_policy, ef_policy, parse_policy_rules,
                               quant_policy, resolve_policy, topk_policy)
from repro.models import encdec, transformer
from repro.models.config import active_param_count, param_count
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.train.steps import _resolve_parallel, make_lm_train_step

POLICIES = {
    "none": lambda: NO_POLICY,
    "q4q8": lambda: CompressionPolicy(num_stages=4,
                                      boundary=quant_policy(4, 8)),
    "top10": lambda: CompressionPolicy(num_stages=4,
                                       boundary=topk_policy(0.10)),
    "top10reuse": lambda: CompressionPolicy(
        num_stages=4, boundary=topk_policy(0.10, reuse_indices=True)),
    "ef21top10": lambda: CompressionPolicy(num_stages=4,
                                           boundary=ef_policy(0.10, "ef21")),
}


def synthetic_stream(cfg, batch: int, seq: int, seed: int = 0,
                     num_samples: int = 4096, start_step: int = 0,
                     dp: int = 1):
    """Deterministic order-2 Markov token stream (see data/synthetic.py),
    vocab-clipped to the model's vocabulary.  Each step's batch is a pure
    function of (seed, step), so ``start_step`` fast-forwards the stream —
    a resumed run sees exactly the batches the interrupted run would have.

    ``dp > 1`` deals ids per replica: contiguous batch shard r cycles over
    its own id block ``[r*num_samples/dp, (r+1)*num_samples/dp)`` — the
    AQ-SGD dp routing contract (each replica owns the buffer rows of the
    examples it sees; see ``repro.core.feedback.shard_ids``)."""
    rng = np.random.RandomState(seed)
    vocab = min(cfg.vocab_size, 1024)
    succ = rng.randint(0, vocab, size=(vocab, vocab, 4))
    step = start_step
    while True:
        r = np.random.RandomState(seed + 1 + step)
        out = np.zeros((batch, seq), np.int32)
        out[:, 0] = r.randint(0, vocab, batch)
        out[:, 1] = r.randint(0, vocab, batch)
        for t in range(2, seq):
            out[:, t] = succ[out[:, t - 2], out[:, t - 1],
                             r.randint(0, 4, batch)]
        # ids cycle over a bounded "dataset" so AQ-SGD's per-example
        # buffers revisit rows (the premise of the compensation)
        if dp > 1:
            sh, per = batch // dp, num_samples // dp
            ids = np.concatenate(
                [r * per + (np.arange(sh, dtype=np.int32) + sh * step) % per
                 for r in range(dp)])
        else:
            ids = (np.arange(batch, dtype=np.int32)
                   + batch * step) % num_samples
        yield out, ids
        step += 1


def make_batch(cfg, tokens):
    b = {"tokens": jnp.asarray(tokens)}
    n = tokens.shape[0]
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.zeros((n, cfg.num_patches, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.enc_dec:
        b["enc_embeds"] = jnp.zeros((n, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)
    return b


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="none",
                    help="a named policy (%s) OR an adaptive rule spec: "
                         "';'-separated 'codec[:k_frac][@cond,...]' rules, "
                         "conds size>=N | size<N | depth>=N | depth<N | "
                         "bandwidth>=X | bandwidth<X (bytes/s; fires only "
                         "under a probe — see obs/probes.py) | "
                         "dir=fw|bw — first match wins per boundary, e.g. "
                         "'q4@size>=65536;q8@size>=16384;none' (resolved "
                         "against seq*d_model at trace time)"
                         % ", ".join(sorted(POLICIES)))
    ap.add_argument("--transport", default="simulated",
                    choices=("simulated", "pipeline"),
                    help="simulated boundary (paper) or the real "
                         "compressed shard_map/ppermute pipeline")
    ap.add_argument("--stages", type=int, default=None,
                    help="pipeline stage count (default: policy's)")
    ap.add_argument("--schedule", default="gpipe",
                    choices=("gpipe", "1f1b", "interleaved"),
                    help="pipeline schedule: gpipe (minimum-tick skew "
                         "scan), 1f1b (rematerialized ticks + fused "
                         "single-buffer hops; use with "
                         "--pipeline-microbatches >> stages), interleaved "
                         "(--virtual-stages slices per device: 1/v the "
                         "bubble, v*S-1 compressed cuts)")
    ap.add_argument("--virtual-stages", type=int, default=None,
                    help="virtual stage slices per device for "
                         "--schedule interleaved (default 2)")
    ap.add_argument("--pipeline-microbatches", type=int, default=None,
                    help="GPipe/1F1B microbatch count for the pipeline "
                         "transport (default: the stage count)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="3D mesh sizes, 'data=2,stage=2,tensor=2' (axis "
                         "aliases dp/pp/tp/model accepted; missing axes "
                         "default to 1).  stage>1 implies --transport "
                         "pipeline; tensor>1 shards the layer stack over "
                         "the compressed TP collectives "
                         "(transport/tp_collectives.py).  Replaces "
                         "--dp/--stages")
    ap.add_argument("--wire", default=None, metavar="SPEC",
                    help="per-axis wire config "
                         "'axis=codec[+feedback][:k_frac]', e.g. "
                         "'data=q8+ef:0.1,tensor=q4'.  Codecs "
                         "none|q8|q4|topk (or a quoted rule spec); "
                         "feedback ef|ef21.  Replaces --dp-codec/"
                         "--dp-feedback/--dp-k-frac")
    ap.add_argument("--dp", type=int, default=1,
                    help="DEPRECATED (use --mesh data=N): data-parallel "
                         "replicas: the global batch splits into --dp "
                         "contiguous shards and per-replica gradients are "
                         "all-reduced over the real wire "
                         "(transport/collectives.py).  With --transport "
                         "pipeline this runs the 2D (data, stages) mesh "
                         "(needs dp*stages host devices)")
    ap.add_argument("--dp-codec", default="none",
                    choices=("none", "q8", "q4", "topk"),
                    help="DEPRECATED (use --wire data=CODEC): wire codec "
                         "for the DP gradient all-reduce (paper Tables "
                         "2-3: gradients tolerate milder rates than "
                         "activations)")
    ap.add_argument("--dp-feedback", default="none",
                    choices=("none", "ef", "ef21"),
                    help="DEPRECATED (use --wire data=codec+FEEDBACK): "
                         "per-replica error feedback on the DP reduce "
                         "(residuals ride the train state and the "
                         "checkpoint)")
    ap.add_argument("--dp-k-frac", type=float, default=0.1,
                    help="DEPRECATED (use --wire data=topk:K): TopK kept "
                         "fraction for --dp-codec topk")
    ap.add_argument("--feedback", default="none",
                    choices=("none", "ef", "ef21", "efmixed", "aqsgd"),
                    help="error-feedback mode (paper Tables 3-4); replaces "
                         "the boundary with TopK(--k-frac) + this "
                         "compensation, on either transport")
    ap.add_argument("--k-frac", type=float, default=0.1,
                    help="TopK kept fraction for --feedback boundaries")
    ap.add_argument("--num-samples", type=int, default=4096,
                    help="AQ-SGD per-example buffer size; the synthetic "
                         "stream's ids cycle modulo this")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="gradient-accumulation splits of the global batch "
                         "(bounds activation memory at B/grad_accum)")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="DEPRECATED alias for --grad-accum (and, with "
                         "--transport pipeline, for "
                         "--pipeline-microbatches)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint path (npz); saves the FULL train "
                         "state: params + optimizer moments + feedback "
                         "buffers (checkpoint/io.save_train_state).  A "
                         "'{step}' placeholder keeps one file per save "
                         "instead of overwriting")
    ap.add_argument("--save-every", type=int, default=None,
                    help="checkpoint every N steps (default 100)")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="DEPRECATED alias for --save-every")
    ap.add_argument("--resume", default=None,
                    help="resume from a --ckpt train-state file: restores "
                         "params, optimizer state, feedback buffers, and "
                         "the data-stream position")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write metrics here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write the JSONL event log "
                         "here (obs/export.py schema; default: tracing "
                         "off, zero overhead)")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="also write a Chrome-trace JSON loadable at "
                         "ui.perfetto.dev / chrome://tracing")
    ap.add_argument("--metrics", type=int, default=0, metavar="N",
                    help="sample per-boundary compression error + "
                         "feedback-buffer norms every N steps (obs/"
                         "quality.py; 0 = off; implies tracing)")
    args = ap.parse_args(argv)

    tracing = bool(args.trace or args.perfetto or args.metrics)
    if tracing:
        obs_trace.enable()

    cfg = get(args.arch, smoke=args.smoke)
    seq = min(args.seq, cfg.max_seq)
    save_every = args.save_every
    if args.ckpt_every is not None:
        import warnings
        if save_every is not None:
            ap.error("--ckpt-every (deprecated) conflicts with "
                     "--save-every — drop --ckpt-every")
        warnings.warn("--ckpt-every is deprecated: use --save-every",
                      DeprecationWarning)
        save_every = args.ckpt_every
    save_every = 100 if save_every is None else save_every
    grad_accum = args.grad_accum
    pipeline_mb = args.pipeline_microbatches
    if args.microbatches is not None:
        import warnings
        if args.transport == "pipeline":
            if pipeline_mb is not None:
                ap.error("--microbatches (deprecated) conflicts with "
                         "--pipeline-microbatches — drop --microbatches")
            warnings.warn("--microbatches is deprecated: use "
                          "--pipeline-microbatches for the pipeline "
                          "microbatch count", DeprecationWarning)
            if args.microbatches > 1:
                pipeline_mb = args.microbatches
        else:
            if grad_accum != 1:
                ap.error("--microbatches (deprecated) conflicts with "
                         "--grad-accum — drop --microbatches")
            warnings.warn("--microbatches is deprecated: use --grad-accum "
                          "for gradient accumulation", DeprecationWarning)
            grad_accum = args.microbatches
    virtual_stages = (args.virtual_stages if args.virtual_stages is not None
                      else (2 if args.schedule == "interleaved" else 1))
    if args.policy in POLICIES:
        policy = POLICIES[args.policy]()
    else:
        try:
            policy = parse_policy_rules(args.policy)
        except ValueError as e:
            ap.error(f"--policy {args.policy!r} is neither a named policy "
                     f"({', '.join(sorted(POLICIES))}) nor a valid rule "
                     f"spec: {e}")
    if args.feedback != "none":
        bp = (aqsgd_policy(args.k_frac) if args.feedback == "aqsgd"
              else ef_policy(args.k_frac, args.feedback))
        stages = policy.num_stages if policy.num_boundaries else 4
        policy = CompressionPolicy(num_stages=stages, boundary=bp)
    if args.stages:
        policy = dataclasses.replace(policy, num_stages=args.stages)
    if isinstance(policy, PolicyRules):
        # static resolution: rules -> concrete per-boundary codecs, keyed
        # by the LM's uniform cut size (hashable before any jit tracing)
        policy = resolve_policy(policy, seq * cfg.d_model)
    parallel = None
    if args.mesh or args.wire:
        legacy_used = [f for f, used in
                       (("--dp", args.dp != 1),
                        ("--dp-codec", args.dp_codec != "none"),
                        ("--dp-feedback", args.dp_feedback != "none"),
                        ("--dp-k-frac", args.dp_k_frac != 0.1),
                        ("--stages", bool(args.stages))) if used]
        if legacy_used:
            ap.error(f"--mesh/--wire conflict with the deprecated "
                     f"{', '.join(legacy_used)} — configure every axis "
                     "through --mesh/--wire")
        try:
            parallel = spec_from_cli(args.mesh, args.wire)
            # rule-coded axis wires resolve statically here (no probe on
            # this driver): data carries the gradient tree, stage/tensor
            # the per-example activation cut
            parallel = parallel.resolved(
                {"data": param_count(cfg), "stage": seq * cfg.d_model,
                 "tensor": seq * cfg.d_model // max(parallel.tp, 1)})
        except ValueError as e:
            ap.error(f"--mesh/--wire: {e}")
    if parallel is not None:
        spec_eff, policy_eff, transport_eff = _resolve_parallel(
            "launch.train", parallel, policy, args.transport, {})
    else:
        spec_eff, policy_eff, transport_eff = None, policy, args.transport
    dp_n = spec_eff.dp if spec_eff is not None else args.dp
    tp_n = spec_eff.tp if spec_eff is not None else 1
    need_devices = (spec_eff.num_devices if spec_eff is not None else
                    (args.dp * policy.num_stages
                     if args.transport == "pipeline" else args.dp))
    if (need_devices > 1
            and "xla_force_host_platform_device_count"
            not in os.environ.get("XLA_FLAGS", "")):
        # Must land before first jax backend init (imports alone are fine).
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={need_devices}")
    n_params = param_count(cfg)
    print(f"# arch={cfg.arch_id} params~{n_params/1e6:.1f}M "
          f"(active {active_param_count(cfg)/1e6:.1f}M) "
          f"B={args.batch} S={seq} policy={args.policy}"
          f"{'' if args.feedback == 'none' else '+' + args.feedback} "
          f"devices={jax.device_count()}", flush=True)

    opt = OptimizerConfig(kind="adamw", lr=args.lr, weight_decay=0.01,
                          schedule="cosine", t_max=args.steps, grad_clip=1.0)
    params = (encdec if cfg.enc_dec else transformer).init_params(
        jax.random.PRNGKey(args.seed), cfg)
    opt_state = init_opt_state(opt, params)
    if transport_eff == "pipeline":
        from repro.train.loop import _pipeline_bstates
        bstates = _pipeline_bstates(
            policy_eff, (seq, cfg.d_model), batch=args.batch,
            microbatches=pipeline_mb,
            num_samples=args.num_samples, dtype=jnp.bfloat16,
            virtual_stages=virtual_stages, dp=dp_n)
    else:
        # boundaries that actually exist in the stack: segment_bounds caps
        # the stage count at the group count (a 2-group smoke model under a
        # 4-stage policy has 1 cut, not 3) — and the train step returns
        # bstates in that effective structure, which --resume restores into
        from repro.models.transformer import segment_bounds
        n_units = cfg.num_layers if cfg.enc_dec else cfg.num_groups
        eff = max(0, len(segment_bounds(n_units, policy_eff.num_stages)) - 1)
        bstates = [init_boundary_state(policy_eff.at(i), (seq, cfg.d_model),
                                       batch=args.batch,
                                       num_samples=args.num_samples,
                                       dtype=jnp.bfloat16)
                   for i in range(eff)]
    if transport_eff == "pipeline":
        from repro.transport.schedules import get_schedule
        sched = get_schedule(args.schedule, virtual_stages)
        mb_eff = pipeline_mb or policy_eff.num_stages
        print(f"# pipeline transport: schedule={args.schedule} "
              f"microbatches={mb_eff} "
              f"{sched.describe(mb_eff, policy_eff.num_stages)}", flush=True)
    pkw = {}
    if parallel is not None:
        pkw["parallel"] = parallel
    else:
        # only forward the legacy kwargs the user actually set, so a
        # plain run never trips the ParallelDeprecationWarning
        if args.dp != 1:
            pkw["dp"] = args.dp
        if args.dp_codec != "none":
            pkw["dp_codec"] = args.dp_codec
        if args.dp_feedback != "none":
            pkw["dp_feedback"] = args.dp_feedback
        if args.dp_k_frac != 0.1:
            pkw["dp_k_frac"] = args.dp_k_frac
    step_fn = make_lm_train_step(cfg, policy, opt, remat=not args.no_remat,
                                 donate=False,
                                 grad_accum=grad_accum,
                                 transport=args.transport,
                                 pipeline_microbatches=pipeline_mb,
                                 schedule=args.schedule,
                                 virtual_stages=virtual_stages, **pkw)
    dp_codec_eff = (spec_eff.data.codec if spec_eff is not None
                    else args.dp_codec)
    dp_feedback_eff = (spec_eff.data.feedback if spec_eff is not None
                       else args.dp_feedback)
    dp_state = None
    if dp_n > 1:
        from repro.train.loop import init_lm_dp_state
        dp_state = init_lm_dp_state(cfg, params, policy_eff, dp_n,
                                    dp_feedback_eff,
                                    transport=transport_eff,
                                    virtual_stages=virtual_stages, tp=tp_n)
        print(f"# dp={dp_n} gradient all-reduce: codec={dp_codec_eff} "
              f"feedback={dp_feedback_eff}", flush=True)
    tp_state = None
    if tp_n > 1:
        t_ax = spec_eff.tensor
        print(f"# tp={tp_n} tensor collectives: codec={t_ax.codec} "
              f"feedback={t_ax.feedback}", flush=True)
        if transport_eff == "simulated":
            from repro.models.transformer import tp_sites
            from repro.transport.tp_collectives import init_tp_state
            tp_state = init_tp_state((args.batch, seq, cfg.d_model),
                                     tp_sites(cfg), t_ax.feedback)

    start_step = 0
    if args.resume:
        if dp_n > 1:
            params, opt_state, bstates, dp_state, start_step = \
                ckpt_io.restore_train_state(args.resume, params, opt_state,
                                            bstates, dp_like=dp_state)
        else:
            params, opt_state, bstates, start_step = \
                ckpt_io.restore_train_state(args.resume, params, opt_state,
                                            bstates)
        print(f"# resumed step-{start_step} train state from {args.resume}",
              flush=True)
        if tp_state is not None and spec_eff.tensor.feedback != "none":
            print("# note: tensor-wire feedback residuals are not "
                  "checkpointed — resuming with zeroed tp_state", flush=True)
    stream = synthetic_stream(cfg, args.batch, seq, args.seed,
                              num_samples=args.num_samples,
                              start_step=start_step, dp=dp_n)
    tap = None
    if args.metrics:
        from repro.obs.quality import QualityTap
        tap = QualityTap((args.batch, seq, cfg.d_model),
                         every=args.metrics, dtype=jnp.bfloat16,
                         seed=args.seed)
    metrics, t0 = [], time.time()
    tokens_per_step = args.batch * seq
    for step in range(start_step + 1, args.steps + 1):
        toks, ids = next(stream)
        with obs_trace.span("train.step", cat="train", step=step) as sa:
            extra = [s for s in (dp_state, tp_state) if s is not None]
            out = step_fn(params, opt_state, bstates, make_batch(cfg, toks),
                          jnp.asarray(ids), *extra)
            params, opt_state, bstates, m = out[0], out[1], out[2], out[-1]
            rest = list(out[3:-1])
            if dp_state is not None:
                dp_state = rest.pop(0)
            if tp_state is not None:
                tp_state = rest.pop(0)
            if tracing:
                sa["loss"] = round(float(m["loss"]), 6)  # sync in span
        if tap is not None:
            tap.maybe_sample(step, policy, bstates or None)
        if step % args.log_every == 0 or step == args.steps:
            dt = time.time() - t0
            loss = float(m["loss"])
            rec = {"step": step, "loss": round(loss, 4),
                   "ppl": round(math.exp(min(loss, 20.0)), 2),
                   "tok_per_s": round((step - start_step) * tokens_per_step
                                      / dt, 1),
                   "wall_s": round(dt, 1)}
            metrics.append(rec)
            print(json.dumps(rec), flush=True)
        if args.ckpt and (step % save_every == 0 or step == args.steps):
            ckpt_io.save_train_state(
                args.ckpt.replace("{step}", str(step)), params, opt_state,
                bstates, step=step,
                extra={"arch": cfg.arch_id, "policy": args.policy,
                       "feedback": args.feedback, "dp": dp_n,
                       "dp_codec": dp_codec_eff, "tp": tp_n},
                dp_state=dp_state)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=1)
    if tracing:
        tr = obs_trace.get_tracer()
        events = tr.drain()
        if args.trace:
            from repro.obs.export import to_jsonl
            print(f"# trace: {to_jsonl(events, args.trace)} events "
                  f"-> {args.trace} (dropped {tr.dropped})", flush=True)
        if args.perfetto:
            from repro.obs.export import to_chrome_trace
            print(f"# perfetto: {to_chrome_trace(events, args.perfetto)} "
                  f"events -> {args.perfetto}", flush=True)
    print("# done: final loss "
          f"{metrics[-1]['loss'] if metrics else 'n/a (already at --steps)'}",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
