"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Production target: TPU v5e pods — 16x16 = 256 chips per pod,
2 pods = 512 chips multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "tensor") if multi_pod else ("data", "tensor")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples).

    Uses the canonical ``(data, tensor)`` names (core/parallel.py);
    sharding/specs.py accepts the historical "model" name as an alias.
    """
    return jax.make_mesh((1, 1), ("data", "tensor"))


def make_data_mesh(dp: int, *, data_axis: str = "data"):
    """1D data-parallel mesh: ``dp`` replicas for the compressed gradient
    all-reduce (transport/collectives.py) around the SIMULATED boundary."""
    if dp < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    if jax.device_count() < dp:
        raise RuntimeError(
            f"data-parallel mesh needs >= {dp} devices, have "
            f"{jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={dp} before jax init")
    return jax.make_mesh((dp,), (data_axis,))


def make_dp_pipeline_mesh(dp: int, stages: int, *, data_axis: str = "data",
                          stage_axis: str = "stage"):
    """2D ``(data, stages)`` mesh: ``dp`` replicas each running a
    ``stages``-deep compressed pipeline.  Row r of the mesh is one replica;
    ``ppermute`` over ``stage_axis`` moves activations within a row, the
    DP gradient all-reduce rings over ``data_axis`` within a column.
    """
    if dp < 1 or stages < 1:
        raise ValueError(f"dp and stages must be >= 1, got ({dp}, {stages})")
    need = dp * stages
    if jax.device_count() < need:
        raise RuntimeError(
            f"2D DPxPP mesh needs >= {need} devices (dp={dp} x "
            f"stages={stages}), have {jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax init")
    return jax.make_mesh((dp, stages), (data_axis, stage_axis))


def make_tensor_mesh(tp: int, *, tensor_axis: str = "tensor"):
    """1D tensor-parallel mesh: ``tp`` shards whose all-gather /
    reduce-scatter ring through the compressed wire
    (transport/tp_collectives.py)."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if jax.device_count() < tp:
        raise RuntimeError(
            f"tensor-parallel mesh needs >= {tp} devices, have "
            f"{jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={tp} before jax init")
    return jax.make_mesh((tp,), (tensor_axis,))


def make_3d_mesh(dp: int, stages: int, tp: int, *, data_axis: str = "data",
                 stage_axis: str = "stage", tensor_axis: str = "tensor"):
    """3D ``(data, stage, tensor)`` mesh — all three of the paper's
    communication axes in one program.  Each (data, stage) cell holds a
    ``tp``-wide tensor-parallel group; ``ppermute`` over ``stage_axis``
    moves activations between stages within a (data, tensor) column, the
    TP all-gather/reduce-scatter rings over ``tensor_axis`` within a
    stage, and the DP gradient all-reduce rings over ``data_axis``.
    Axes of size 1 are kept (shard_map binds their names for free), so
    degenerate specs lower to the 2D/1D meshes' programs.
    """
    for k, v in (("dp", dp), ("stages", stages), ("tp", tp)):
        if v < 1:
            raise ValueError(f"{k} must be >= 1, got {v}")
    need = dp * stages * tp
    if jax.device_count() < need:
        raise RuntimeError(
            f"3D mesh needs >= {need} devices (dp={dp} x stages={stages} "
            f"x tp={tp}), have {jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax init")
    return jax.make_mesh((dp, stages, tp), (data_axis, stage_axis, tensor_axis))


# Hardware constants for §Roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
