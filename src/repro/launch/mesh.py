"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Production target: TPU v5e pods — 16x16 = 256 chips per pod,
2 pods = 512 chips multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(dp: int, *, data_axis: str = "data"):
    """1D data-parallel mesh: ``dp`` replicas for the compressed gradient
    all-reduce (transport/collectives.py) around the SIMULATED boundary."""
    if dp < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    if jax.device_count() < dp:
        raise RuntimeError(
            f"data-parallel mesh needs >= {dp} devices, have "
            f"{jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={dp} before jax init")
    return jax.make_mesh((dp,), (data_axis,))


def make_dp_pipeline_mesh(dp: int, stages: int, *, data_axis: str = "data",
                          stage_axis: str = "stage"):
    """2D ``(data, stages)`` mesh: ``dp`` replicas each running a
    ``stages``-deep compressed pipeline.  Row r of the mesh is one replica;
    ``ppermute`` over ``stage_axis`` moves activations within a row, the
    DP gradient all-reduce rings over ``data_axis`` within a column.
    """
    if dp < 1 or stages < 1:
        raise ValueError(f"dp and stages must be >= 1, got ({dp}, {stages})")
    need = dp * stages
    if jax.device_count() < need:
        raise RuntimeError(
            f"2D DPxPP mesh needs >= {need} devices (dp={dp} x "
            f"stages={stages}), have {jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax init")
    return jax.make_mesh((dp, stages), (data_axis, stage_axis))


# Hardware constants for §Roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
