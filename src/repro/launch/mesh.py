"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Production target: TPU v5e pods — 16x16 = 256 chips per pod,
2 pods = 512 chips multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# Hardware constants for §Roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
