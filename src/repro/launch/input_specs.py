"""ShapeDtypeStruct stand-ins for every (architecture x input-shape) pair.

No device allocation — the dry-run lowers against these structs only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1),
}


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape):
    """The data-batch pytree for train/prefill (tokens + modality stubs)."""
    b, s = shape.batch, shape.seq
    batch = {"tokens": _sd((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = _sd((b, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.enc_dec:
        batch["enc_embeds"] = _sd((b, cfg.enc_seq, cfg.d_model),
                                  jnp.bfloat16)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(token, caches, pos) structs for one decode step with a filled cache
    of length ``shape.seq``."""
    b, s = shape.batch, shape.seq
    mod = encdec if cfg.enc_dec else transformer
    caches = jax.eval_shape(
        lambda: mod.init_caches(cfg, b, s, jnp.bfloat16))
    if cfg.enc_dec:
        memory = _sd((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        caches = (caches, memory)
    token = _sd((b,), jnp.int32)
    pos = _sd((), jnp.int32)
    return token, caches, pos


def ids_spec(shape: InputShape):
    return _sd((shape.batch,), jnp.int32)


def applicable(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only for sub-quadratic-decode archs (DESIGN.md §7)."""
    if shape_name != "long_500k":
        return True
    return cfg.supports_long_decode()
