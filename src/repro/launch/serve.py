"""Batched serving driver.

Loads (or randomly initializes) a registry architecture and serves batched
greedy-decoding requests through :class:`repro.serve.engine.ServeEngine`,
with the paper's rule applied: a model trained with boundary compression is
served with the same compression at inference (finding F3).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --policy top10 --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np
import jax

from repro.checkpoint import io as ckpt_io
from repro.configs.registry import ARCHS, get
from repro.launch.train import POLICIES
from repro.models import encdec, transformer
from repro.serve.engine import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="none", choices=sorted(POLICIES))
    ap.add_argument("--no-compress", action="store_true",
                    help="serve WITHOUT compression (finding-F3 ablation)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None, help="restore params from npz")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch, smoke=args.smoke)
    mod = encdec if cfg.enc_dec else transformer
    params = mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params, step = ckpt_io.restore(args.ckpt, params)
        print(f"# restored step-{step} params from {args.ckpt}", flush=True)
    policy = POLICIES[args.policy]()
    engine = ServeEngine(params, cfg, policy,
                         compress=not args.no_compress,
                         max_batch=args.batch, max_seq=args.max_seq)

    rng = np.random.RandomState(args.seed)
    reqs = [Request(rng.randint(0, min(cfg.vocab_size, 1024),
                                args.prompt_len).astype(np.int32),
                    args.new_tokens)
            for _ in range(args.batch)]
    # warmup compile, then measured run
    engine.generate([Request(reqs[0].prompt.copy(), 2)])
    probe = engine.throughput_probe(args.batch, args.prompt_len,
                                    args.new_tokens)
    print(json.dumps({"arch": cfg.arch_id, "policy": args.policy,
                      "compress": not args.no_compress, **probe}),
          flush=True)
    done = engine.generate(reqs)
    for i, r in enumerate(done[: min(4, len(done))]):
        print(f"# req{i}: prompt[-4:]={r.prompt[-4:].tolist()} "
              f"-> out[:8]={r.out[:8].tolist()}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
