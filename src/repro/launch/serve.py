"""Serving driver: static batch or continuous batching.

Loads (or randomly initializes) a registry architecture and serves
generation requests with the paper's rule applied: a model trained with
boundary compression is served with the same compression at inference
(finding F3), the stage cuts packing the real wire-codec payloads.

Examples:
  # continuous batching, mixed Zipf-length workload, temperature sampling
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --engine continuous --policy top10 --slots 4 --requests 16 \
      --temperature 0.8 --top-k 40
  # static-batch baseline with the prefill/decode throughput probe
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --engine static --policy top10 --batch 4 --prompt-len 32 \
      --new-tokens 32
  # finding-F3 ablation: serve an (EF-)trained model uncompressed
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --engine continuous --policy top10 --no-compress
  # paged serving: prefix-shared KV pages + chunked prefill on a
  # shared-system-prompt workload
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --engine continuous --policy top10 --prefix-cache \
      --prefill-chunk 16 --shared-prefix 48
  # speculative decoding: a draft model proposes, the target verifies
  # (output is exactly the target's greedy stream)
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small --smoke \
      --engine continuous --policy top10 --draft gpt2-small --spec-k 4
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax

from repro.checkpoint import io as ckpt_io
from repro.configs.registry import ARCHS, get
from repro.launch.train import POLICIES
from repro.models import encdec, transformer
from repro.serve.engine import (ContinuousEngine, Request, ServeEngine,
                                left_pad_unsupported)
from repro.serve.sampling import SamplingConfig


def zipf_lengths(rng, n, lo, hi, a=1.6):
    """Zipf-distributed lengths in [lo, hi] — the mixed serving workload."""
    return np.clip(lo + (rng.zipf(a, n) - 1), lo, hi).astype(int)


def _export_trace(args) -> None:
    """Drain the tracer into the requested --trace / --perfetto files."""
    from repro.obs import trace as obs_trace
    tr = obs_trace.get_tracer()
    if tr is None:
        return
    events = tr.drain()
    if args.trace:
        from repro.obs.export import to_jsonl
        print(f"# trace: {to_jsonl(events, args.trace)} events "
              f"-> {args.trace} (dropped {tr.dropped})", flush=True)
    if args.perfetto:
        from repro.obs.export import to_chrome_trace
        print(f"# perfetto: {to_chrome_trace(events, args.perfetto)} "
              f"events -> {args.perfetto}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default=None,
                    choices=("continuous", "static"),
                    help="default: continuous where the arch supports it "
                         "(maskable left-padding), else static")
    ap.add_argument("--policy", default="none", choices=sorted(POLICIES))
    ap.add_argument("--no-compress", action="store_true",
                    help="serve WITHOUT compression (finding-F3 ablation; "
                         "EF-trained models lose almost nothing here, "
                         "plain-TopK-trained models degrade)")
    ap.add_argument("--batch", type=int, default=4,
                    help="static engine batch size")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous engine decode slots")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous engine: number of requests to serve")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="static: exact prompt length; continuous: max of "
                         "the Zipf prompt-length mix")
    ap.add_argument("--new-tokens", type=int, default=32,
                    help="static: decode steps; continuous: max of the "
                         "Zipf max-new-tokens mix")
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos", type=int, default=None,
                    help="stop decoding a request at this token id")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous engine: prefix-sharing paged KV — "
                         "requests with a common prompt prefix reuse its "
                         "cached pages instead of re-prefilling")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous engine: ingest prompts in chunks of "
                         "this many tokens, one chunk per tick, "
                         "interleaved with decode (kills the prefill "
                         "stall); implies the paged KV cache")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page in paged mode")
    ap.add_argument("--draft", default=None, choices=sorted(ARCHS),
                    help="speculative decoding: draft arch proposing "
                         "--spec-k tokens per tick for the target to "
                         "verify in one forward (greedy only; a draft "
                         "trained with boundary compression must serve "
                         "compressed — finding F3 applies to the draft "
                         "too, so it shares --policy/--no-compress)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft proposals per speculative tick")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="workload: prepend a common system-prompt "
                         "prefix of this many tokens to every request "
                         "(what --prefix-cache accelerates)")
    ap.add_argument("--ckpt", default=None, help="restore params from npz")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write the JSONL event log "
                         "here (obs/export.py schema; default: tracing "
                         "off, zero overhead)")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="also write a Chrome-trace JSON loadable at "
                         "ui.perfetto.dev / chrome://tracing")
    ap.add_argument("--metrics", type=int, default=1, metavar="N",
                    help="continuous engine: emit scheduler/page-pool "
                         "counters every N ticks when tracing is on "
                         "(default 1)")
    args = ap.parse_args(argv)

    tracing = bool(args.trace or args.perfetto)
    if tracing:
        from repro.obs import trace as obs_trace
        obs_trace.enable()

    cfg = get(args.arch, smoke=args.smoke)
    unsupported = left_pad_unsupported(cfg)
    if args.engine is None:
        args.engine = "static" if unsupported else "continuous"
        if unsupported:
            print(f"# {cfg.arch_id}: {sorted(unsupported)} cannot mask "
                  "left-padding -> static engine", flush=True)
    elif args.engine == "continuous" and unsupported:
        ap.error(f"--engine continuous: {sorted(unsupported)} cannot mask "
                 "left-padding — use --engine static "
                 "(equal-length batches)")
    mod = encdec if cfg.enc_dec else transformer
    params = mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params, step = ckpt_io.restore_params(args.ckpt, params)
        print(f"# restored step-{step} params from {args.ckpt}", flush=True)
    policy = POLICIES[args.policy]()
    compress = not args.no_compress
    rng = np.random.RandomState(args.seed)

    if args.engine == "static":
        if args.temperature or args.top_k or args.top_p < 1.0 \
                or args.eos is not None:
            ap.error("--temperature/--top-k/--top-p/--eos need "
                     "--engine continuous (the static engine decodes "
                     "greedily to a fixed length)")
        if args.prefix_cache or args.prefill_chunk or args.draft \
                or args.shared_prefix:
            ap.error("--prefix-cache/--prefill-chunk/--draft/"
                     "--shared-prefix need --engine continuous")
        engine = ServeEngine(params, cfg, policy, compress=compress,
                             max_batch=args.batch, max_seq=args.max_seq)
        reqs = [Request(rng.randint(0, min(cfg.vocab_size, 1024),
                                    args.prompt_len).astype(np.int32),
                        args.new_tokens)
                for _ in range(args.batch)]
        probe = engine.throughput_probe(args.batch, args.prompt_len,
                                       args.new_tokens)
        print(json.dumps({"arch": cfg.arch_id, "engine": "static",
                          "policy": args.policy, "compress": compress,
                          **probe}), flush=True)
        done = engine.generate(reqs)
        for i, r in enumerate(done[: min(4, len(done))]):
            print(f"# req{i}: prompt[-4:]={r.prompt[-4:].tolist()} "
                  f"-> out[:8]={r.out[:8].tolist()}", flush=True)
        _export_trace(args)
        return 0

    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    draft_params = draft_cfg = None
    if args.draft:
        draft_cfg = get(args.draft, smoke=args.smoke)
        if draft_cfg.vocab_size != cfg.vocab_size:
            ap.error(f"--draft {args.draft}: draft vocab "
                     f"{draft_cfg.vocab_size} != target vocab "
                     f"{cfg.vocab_size} — proposals must share token ids")
        draft_mod = encdec if draft_cfg.enc_dec else transformer
        draft_params = draft_mod.init_params(
            jax.random.PRNGKey(args.seed + 1), draft_cfg)
    engine = ContinuousEngine(params, cfg, policy, compress=compress,
                              num_slots=args.slots, max_seq=args.max_seq,
                              sampling=sampling,
                              max_prompt=args.prompt_len
                              + args.shared_prefix,
                              prefix_cache=args.prefix_cache,
                              prefill_chunk=args.prefill_chunk,
                              page_size=args.page_size,
                              draft_params=draft_params,
                              draft_cfg=draft_cfg, draft_policy=policy,
                              spec_k=args.spec_k,
                              metrics_every=max(1, args.metrics))
    engine.warmup()
    vocab = min(cfg.vocab_size, 1024)
    shared = rng.randint(0, vocab, args.shared_prefix).astype(np.int32)
    plens = zipf_lengths(rng, args.requests, 2, args.prompt_len)
    news = zipf_lengths(rng, args.requests, 1, args.new_tokens)
    t0 = time.time()
    for i in range(args.requests):
        tail = rng.randint(0, vocab, plens[i]).astype(np.int32)
        engine.submit(np.concatenate([shared, tail]),
                      max_new_tokens=int(news[i]), eos_token=args.eos,
                      seed=args.seed + i)
    done = engine.drain()
    wall = time.time() - t0
    total_new = sum(len(r.tokens) for r in done)
    print(json.dumps({"arch": cfg.arch_id, "engine": "continuous",
                      "policy": args.policy, "compress": compress,
                      "requests": args.requests, "slots": args.slots,
                      "wall_s": round(wall, 3),
                      "tok_per_s": round(total_new / wall, 1),
                      **engine.stats()}), flush=True)
    for r in sorted(done, key=lambda r: r.req_id)[:4]:
        print(f"# req{r.req_id}: {json.dumps(r.metrics())} "
              f"out[:8]={r.out[:8].tolist()}", flush=True)
    _export_trace(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
