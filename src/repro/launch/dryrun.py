import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, print memory/cost analysis and collective-bytes terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  ... [--policy none|q4q8|top10|top10reuse] [--json out.json]

The FIRST two lines of this file force 512 host platform devices BEFORE any
jax import (jax locks the device count at first init).  Never set this
globally — smoke tests must see one device.
"""
import argparse
import json
import re
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, ASSIGNED, get
from repro.core.policy import (CompressionPolicy, NO_POLICY, quant_policy,
                               topk_policy)
from repro.launch import mesh as meshlib
from repro.launch.input_specs import (SHAPES, applicable, batch_specs,
                                      decode_specs, ids_spec)
from repro.models import encdec, scan_config, transformer
from repro.models.config import active_param_count
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.sharding import ctx
from repro.sharding.specs import (batch_shardings, cache_shardings,
                                  opt_state_shardings, param_shardings,
                                  replicated)
from repro.train.steps import make_lm_train_step

POLICIES: Dict[str, CompressionPolicy] = {
    "none": NO_POLICY,
    "q4q8": CompressionPolicy(num_stages=4, boundary=quant_policy(4, 8)),
    "top10": CompressionPolicy(num_stages=4, boundary=topk_policy(0.10)),
    "top10reuse": CompressionPolicy(
        num_stages=4, boundary=topk_policy(0.10, reuse_indices=True)),
}

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "u16": 2,
                "s16": 2}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in (optimized) HLO."""
    totals: Dict[str, int] = {}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"[%\w.-]+ = (.+?) (all-gather|all-reduce|reduce-scatter"
                     r"|all-to-all|collective-permute)", line)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in shape_re.finditer(shapes_part):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
    return totals


def collective_counts(hlo_text: str,
                      by_pairs: bool = False) -> Dict[str, int]:
    """Number of collective LAUNCHES per op kind in (optimized) HLO —
    each op instance is one collective launch on the interconnect (a
    ``lax.scan`` body appears once, so counts are per steady-state tick
    times the number of loops).  Async ``-start``/``-done`` pairs count
    once.

    ``by_pairs=True`` keys each count by the op's communication pattern —
    ``"collective-permute|{{0,2},{2,0},...}"`` (``source_target_pairs``,
    or ``replica_groups`` for reductions/gathers).  On a 2D DPxPP mesh
    this separates the DP gradient-reduce ring (pairs along the ``data``
    axis) from the pipeline's stage ring, so fused-vs-unfused launch
    claims stay auditable per axis inside one combined train-step program
    (see benchmarks/pipeline_wire.py, "dp" section).
    """
    counts: Dict[str, int] = {}
    launch_re = re.compile(
        r"= .+? (all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(")
    pairs_re = re.compile(
        r"(?:source_target_pairs|replica_groups)=(\{\{.*?\}\})")
    for line in hlo_text.splitlines():
        line = line.strip()
        m = launch_re.search(line)
        if not m:
            continue
        key = m.group(1)
        if by_pairs:
            pm = pairs_re.search(line)
            key = f"{key}|{pm.group(1) if pm else '?'}"
        counts[key] = counts.get(key, 0) + 1
    return counts


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              policy_name: str = "none", compile_: bool = True,
              remat: bool = True, unroll: bool = False,
              unrolled_costs: bool = True):
    scan_config.UNROLL = unroll
    """Lower (and optionally compile) one combination; return the report.

    ``unrolled_costs``: additionally lower (NOT compile) with layer scans
    unrolled and record exact global HLO flops — lax.scan bodies are
    counted once by cost_analysis, so the scanned program's numbers
    undercount by ~num_groups (see scan_config.py).  Cheap: lowering is
    seconds even where the unrolled compile would take tens of minutes.
    """
    cfg = get(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch: no sub-quadratic decode"}
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    policy = POLICIES[policy_name]
    mod = encdec if cfg.enc_dec else transformer
    t0 = time.time()

    with ctx.use_mesh(mesh):
        params_s = jax.eval_shape(
            lambda: mod.init_params(jax.random.PRNGKey(0), cfg))
        pshard = param_shardings(mesh, params_s)

        if shape.kind == "train":
            opt = OptimizerConfig(kind="adamw", lr=1e-4,
                                  moment_dtype=jnp.bfloat16,
                                  weight_decay=0.0, schedule="constant")
            opt_s = jax.eval_shape(lambda: init_opt_state(opt, params_s))
            oshard = opt_state_shardings(mesh, opt_s)
            bspec = batch_specs(cfg, shape)
            bshard = batch_shardings(mesh, bspec)
            ids = ids_spec(shape)
            idshard = batch_shardings(mesh, ids)

            def do_lower():
                fn = make_lm_train_step(cfg, policy, opt, remat=remat,
                                        donate=False, jit=False)
                jitted = jax.jit(
                    fn,
                    in_shardings=(pshard, oshard, [], bshard, idshard),
                    donate_argnums=(0, 1))
                return jitted.lower(params_s, opt_s, [], bspec, ids)
        elif shape.kind == "prefill":
            bspec = batch_specs(cfg, shape)
            bshard = batch_shardings(mesh, bspec)

            def do_lower():
                def prefill_fn(params, batch):
                    return mod.prefill(params, batch, cfg, policy,
                                       cache_len=shape.seq)
                jitted = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
                return jitted.lower(params_s, bspec)
        else:
            token, caches, pos = decode_specs(cfg, shape)
            cshard = cache_shardings(
                mesh, caches[0] if cfg.enc_dec else caches,
                batch_dim=1)
            if cfg.enc_dec:
                cshard = (cshard, batch_shardings(mesh, caches[1]))
            tshard = batch_shardings(mesh, token)

            def do_lower():
                def decode_fn(params, token, caches, pos):
                    return mod.decode_step(params, token, caches, pos, cfg,
                                           policy)
                jitted = jax.jit(
                    decode_fn,
                    in_shardings=(pshard, tshard, cshard,
                                  replicated(mesh, pos)),
                    donate_argnums=(2,))
                return jitted.lower(params_s, token, caches, pos)

        lowered = do_lower()
        t_lower = time.time() - t0
        report = {"arch": arch, "shape": shape_name, "policy": policy_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "devices": int(np_prod(mesh.devices.shape)),
                  "lower_s": round(t_lower, 1), "skipped": False,
                  "unroll": unroll}

        if compile_:
            t1 = time.time()
            compiled = lowered.compile()
            report["compile_s"] = round(time.time() - t1, 1)
            ca = compiled.cost_analysis() or {}
            report["flops"] = float(ca.get("flops", 0.0))
            report["bytes"] = float(ca.get("bytes accessed", 0.0))
            ma = compiled.memory_analysis()
            if ma is not None:
                report["argument_bytes"] = getattr(ma, "argument_size_in_bytes", 0)
                report["output_bytes"] = getattr(ma, "output_size_in_bytes", 0)
                report["temp_bytes"] = getattr(ma, "temp_size_in_bytes", 0)
                report["peak_bytes"] = (report["argument_bytes"]
                                        + report["temp_bytes"])
            hlo = compiled.as_text()
            report["collectives"] = collective_bytes(hlo)
            report["collective_bytes"] = sum(report["collectives"].values())

        if unrolled_costs and not unroll:
            ca_s = lowered.cost_analysis() or {}
            report["flops_scanned_global"] = float(ca_s.get("flops", 0.0))
            # exact GLOBAL flops from the unrolled lowering (no compile —
            # the unrolled SPMD compile takes tens of minutes; lowering is
            # seconds).  Pre-fusion 'bytes accessed' is inflated, so only
            # flops are trusted from this pass; the roofline corrects the
            # compiled bytes/collectives by the flop undercount factor.
            t2 = time.time()
            scan_config.UNROLL = True
            try:
                ca_u = do_lower().cost_analysis() or {}
                report["flops_unrolled_global"] = float(ca_u.get("flops", 0.0))
                report["unroll_lower_s"] = round(time.time() - t2, 1)
            finally:
                scan_config.UNROLL = False
        # model flops (6ND) for the useful-compute ratio
        n_active = active_param_count(cfg)
        tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
        mult = 6 if shape.kind == "train" else 2
        report["model_flops"] = float(mult * n_active * tokens)
        return report


def np_prod(t):
    p = 1
    for x in t:
        p *= x
    return p


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="none", choices=sorted(POLICIES))
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact cost_analysis "
                         "(roofline pass)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    reports = []
    for arch, shape in combos:
        try:
            r = lower_one(arch, shape, args.multi_pod, args.policy,
                          compile_=not args.no_compile,
                          remat=not args.no_remat, unroll=args.unroll)
        except Exception as e:  # noqa: BLE001 — report and continue
            r = {"arch": arch, "shape": shape, "error": repr(e)[:500],
                 "skipped": False}
        reports.append(r)
        print(json.dumps(r), flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(reports, f, indent=1)
    bad = [r for r in reports if r.get("error")]
    print(f"# {len(reports) - len(bad)}/{len(reports)} OK", flush=True)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
