"""Single-device simulated transport (the paper's Sec. 2.1 setup).

Implements the :class:`Transport` interface with NO collective: the
"wire" is a dense compress-decompress round-trip inside one program,
convergence-equivalent to the distributed system.  The dense C(x) equals
the registered wire codec's ``roundtrip`` on the jnp backend (tested), so
simulated training and the real packed ``ppermute`` pipeline
(transport/pipeline.py) see the SAME numbers at the boundary.

core/boundary.py wraps this class in ``jax.custom_vjp`` so the backward
direction (``bw``) runs on the activation-gradient during backprop.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Tuple

import jax.numpy as jnp

from repro.core.compressors import apply_mask, topk_mask
from repro.core.feedback import FeedbackState, feedback_message
from repro.core.policy import BoundaryPolicy
from repro.transport.base import Transport


class SimulatedTransport(Transport):
    """Feedback-wrapped compressors at one cut, no real communication.

    State is a :class:`repro.core.feedback.FeedbackState` per direction;
    this single-program boundary only uses its ``resid`` slot (the real
    packed-wire pipeline additionally maintains ``mirror`` for the
    delta-coded modes — here both ends of the wire are one array).
    """

    def __init__(self, policy: BoundaryPolicy):
        self.policy = policy

    def fw(self, x, fw_state: FeedbackState, ids=None
           ) -> Tuple[jnp.ndarray, FeedbackState, Any]:
        """Forward message + new fw state + ctx (TopK mask for reuse)."""
        p = self.policy
        if p.feedback == "aqsgd" and ids is None:
            raise ValueError("aqsgd feedback needs per-example ids")
        m, new_resid = feedback_message(p.feedback, p.fw, x,
                                        fw_state.resid, ids)
        mask = None
        if p.reuse_indices:
            # Mask of what the forward direction actually kept.  With plain
            # TopK this is the TopK mask of x itself (paper Table 5).
            src = x if p.feedback == "none" else m
            mask = topk_mask(src, p.fw.k_frac)
        return m, fw_state.replace(resid=new_resid), mask

    def bw(self, g, bw_state: FeedbackState, ctx=None
           ) -> Tuple[jnp.ndarray, FeedbackState]:
        """Backward gradient message + new bw state.

        ``ctx`` is the forward TopK mask when ``reuse_indices`` is set
        (paper Table 5: the gradient reuses the forward indices, so no
        fresh TopK — and no index bytes — in the backward direction).
        """
        p = self.policy
        if p.reuse_indices:
            return apply_mask(g, ctx), bw_state.map(jnp.zeros_like)
        m, new_resid = feedback_message(p.bw_feedback, p.bw, g,
                                        bw_state.resid)
        return m, bw_state.replace(resid=new_resid)


@lru_cache(maxsize=None)
def simulated_transport(policy: BoundaryPolicy) -> SimulatedTransport:
    """Cached per-policy instance (policies are frozen/hashable)."""
    return SimulatedTransport(policy)
