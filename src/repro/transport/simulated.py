"""Single-device simulated transport (the paper's Sec. 2.1 setup).

Implements the :class:`Transport` interface with NO collective: the
"wire" is a dense compress-decompress round-trip inside one program,
convergence-equivalent to the distributed system.  The dense C(x) equals
the registered wire codec's ``roundtrip`` on the jnp backend (tested), so
simulated training and the real packed ``ppermute`` pipeline
(transport/pipeline.py) see the SAME numbers at the boundary.

core/boundary.py wraps this class in ``jax.custom_vjp`` so the backward
direction (``bw``) runs on the activation-gradient during backprop.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Tuple

import jax.numpy as jnp

from repro.core.compressors import apply_mask, topk_mask
from repro.core.feedback import feedback_message
from repro.core.policy import BoundaryPolicy
from repro.transport.base import Transport


class SimulatedTransport(Transport):
    """Feedback-wrapped compressors at one cut, no real communication."""

    def __init__(self, policy: BoundaryPolicy):
        self.policy = policy

    def fw(self, x, fw_buf=None, ids=None) -> Tuple[jnp.ndarray, Any, Any]:
        """Forward message + new fw buffer + ctx (TopK mask for reuse).

        The single buffer here stands for BOTH ends of the wire: the real
        transport keeps a receiver-side mirror for the delta-coded modes
        (ef21/aqsgd — see core.feedback.needs_recv_mirror), which this
        single-program boundary collapses into one array.
        """
        p = self.policy
        if p.feedback == "aqsgd" and ids is None:
            raise ValueError("aqsgd feedback needs per-example ids")
        m, new_fw = feedback_message(p.feedback, p.fw, x, fw_buf, ids)
        mask = None
        if p.reuse_indices:
            # Mask of what the forward direction actually kept.  With plain
            # TopK this is the TopK mask of x itself (paper Table 5).
            src = x if p.feedback == "none" else m
            mask = topk_mask(src, p.fw.k_frac)
        return m, new_fw, mask

    def bw(self, g, bw_buf=None, ctx=None) -> Tuple[jnp.ndarray, Any]:
        """Backward gradient message + new bw buffer.

        ``ctx`` is the forward TopK mask when ``reuse_indices`` is set
        (paper Table 5: the gradient reuses the forward indices, so no
        fresh TopK — and no index bytes — in the backward direction).
        """
        p = self.policy
        if p.reuse_indices:
            return apply_mask(g, ctx), jnp.zeros_like(bw_buf)
        return feedback_message(p.bw_feedback, p.bw, g, bw_buf)


@lru_cache(maxsize=None)
def simulated_transport(policy: BoundaryPolicy) -> SimulatedTransport:
    """Cached per-policy instance (policies are frozen/hashable)."""
    return SimulatedTransport(policy)
