"""Wire codecs: the byte formats a stage boundary actually sends.

One codec = one wire scheme.  ``pack`` maps a boundary tensor ``(B, ...)``
to a payload pytree of arrays (static structure, static shapes — required
inside ``lax.scan`` / ``ppermute``); ``unpack`` inverts it given the
original shape.  Both the simulated boundary (core/boundary.py) and the
real ``ppermute`` pipeline (transport/pipeline.py) consume THIS registry,
so bytes-on-wire accounting and compression semantics cannot drift apart.

Registered schemes:

  * ``none`` — raw bf16                            (2    bytes/elem)
  * ``q8``   — uint8 codes + per-tensor min/scale  (1    byte/elem)
  * ``q4``   — two 4-bit codes packed per uint8    (0.5  byte/elem)
  * ``topk`` — (bf16 values, uint16/int32 indices) (k*(2+idx) bytes/elem)

Quantization uses PER-TENSOR min/max scales so that
``codec.roundtrip(x) == quantize_dequantize(x, bits)`` exactly — the
simulated boundary's C(x) and the real wire round-trip are bit-identical
(tested in tests/test_transport.py).  TopK indices are ``uint16`` whenever
the flattened per-example feature dim fits in 16 bits, ``int32`` otherwise.

On TPU the codec hot path routes through the fused Pallas wire kernels
(see the README "Kernels" section): ``q8`` via kernels/quantize.py
(per-tile scales) when the flattened shape tiles into 128-lane blocks,
``q4`` via kernels/pack4.py and TopK via kernels/topk_select.py (both
per-tensor, byte- resp. set-identical to the jnp formats), and multi-leaf
payload framing via kernels/framing.py.  Everywhere else — and whenever a
shape fails a kernel's tiling/VMEM guard — the pure-jnp path is used.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import (Compressor, dequantize_kbit,
                                    quantize_kbit, topk_scatter,
                                    topk_values_indices)

# Index dtype threshold: a flattened feature dim of up to 2**16 entries has
# indices 0..65535, exactly the uint16 range.
_U16_MAX_N = 1 << 16


def _flat_n(shape) -> int:
    n = 1
    for s in shape[1:]:
        n *= s
    return n


class WireCodec:
    """Base class: a named wire format with a bytes-per-element cost model.

    ``pack(x, k_frac)``   : (B, ...) tensor -> payload dict (static shapes).
    ``unpack(payload, shape, dtype)`` : payload -> (B, ...) tensor.
    ``wire_bytes_per_elem(n, elem_bytes, k_frac)`` : cost model, excluding
    the per-tensor scale overhead (O(1) bytes).
    """

    name: str = "?"

    def payload_keysets(self) -> Tuple[Tuple[str, ...], ...]:
        """The exact key sets this codec's ``pack`` can emit — registered
        alongside the codec so ``unpack_payload`` dispatches on the full
        key SET, not on whichever single key happens to probe first."""
        raise NotImplementedError

    def pack(self, x: jnp.ndarray, k_frac: float = 1.0) -> dict:
        raise NotImplementedError

    def unpack(self, payload: dict, shape, dtype=jnp.bfloat16) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes_per_elem(self, n: int, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        raise NotImplementedError

    def roundtrip(self, x: jnp.ndarray, k_frac: float = 1.0,
                  dtype=None) -> jnp.ndarray:
        """pack -> unpack: the dense C(x) equivalent of this wire format."""
        return self.unpack(self.pack(x, k_frac), x.shape,
                           dtype or x.dtype)


class NoneCodec(WireCodec):
    """Raw bf16 — the uncompressed baseline wire format."""

    name = "none"

    def payload_keysets(self):
        return (("raw",),)

    def pack(self, x, k_frac: float = 1.0):
        return {"raw": x.astype(jnp.bfloat16)}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        return payload["raw"].astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        return float(elem_bytes)


def _pallas_tiling(flat_shape) -> Optional[Tuple[int, int]]:
    """(bm, bn) for the tiled Pallas wire kernels, or None when no tiling
    fits — the feature dim is not a 128-multiple, or the row block (largest
    power-of-two divisor of m, capped at 256) would under-fill the native
    8-sublane tile.  See kernels/tiling.py."""
    from repro.kernels.tiling import wire_tiling
    return wire_tiling(flat_shape)


def _fullrow_fits(n: int, bytes_per_elem: int = 4) -> bool:
    """Can a full-feature-dim row block (q4 / TopK kernels) stay within
    the per-instance VMEM budget at bm=1?"""
    from repro.kernels.tiling import VMEM_BUDGET
    return 0 < n * bytes_per_elem <= VMEM_BUDGET


class QuantCodec(WireCodec):
    """Uniform k-bit min-max quantization; 4-bit packs two codes per byte.

    Per-tensor scales (paper Sec. 2.2) on the jnp path; on TPU the 8-bit
    variant uses the fused Pallas wire kernels with per-tile scales
    (kernels/quantize.py — strictly more accurate at the same wire cost).
    """

    def __init__(self, bits: int):
        assert bits in (4, 8), bits
        self.bits = bits
        self.name = f"q{bits}"

    def payload_keysets(self):
        if self.bits == 4:
            return (("codes4", "min", "scale"),)
        return (("codes", "min", "scale"),      # per-tensor jnp format
                ("codes", "tile_meta"))         # per-tile Pallas format

    def pack(self, x, k_frac: float = 1.0):
        b = x.shape[0]
        flat = x.reshape(b, -1)
        if self.bits == 8 and _use_pallas_wire():
            tiling = _pallas_tiling(flat.shape)
            if tiling is not None:
                from repro.kernels.quantize import quantize_wire
                codes, meta = quantize_wire(flat.astype(jnp.float32), 8,
                                            block=tiling)
                return {"codes": codes, "tile_meta": meta}
        if (self.bits == 4 and _use_pallas_wire()
                and _fullrow_fits(flat.shape[1])):
            from repro.kernels.pack4 import pack4_wire
            packed, mn, sc = pack4_wire(flat.astype(jnp.float32))
            return {"codes4": packed, "min": mn, "scale": sc}
        codes, mn, sc = quantize_kbit(flat.astype(jnp.float32), self.bits,
                                      axis=None)
        if self.bits == 4:
            n = flat.shape[1]
            if n % 2:                       # odd feature dim: pad one code
                codes = jnp.pad(codes, ((0, 0), (0, 1)))
            even = codes[:, 0::2]
            odd = codes[:, 1::2]
            packed = (even | (odd << 4)).astype(jnp.uint8)
            return {"codes4": packed, "min": mn, "scale": sc}
        return {"codes": codes, "min": mn, "scale": sc}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        b = shape[0]
        n = _flat_n(shape)
        if "codes4" in payload:
            packed = payload["codes4"]
            if _use_pallas_wire() and _fullrow_fits(n):
                from repro.kernels.pack4 import unpack4_wire
                flat = unpack4_wire(packed, payload["min"],
                                    payload["scale"], n)
                return flat.reshape(shape).astype(dtype)
            even = packed & 0xF
            odd = packed >> 4
            codes = jnp.stack([even, odd], axis=-1).reshape(b, -1)[:, :n]
            flat = dequantize_kbit(codes, payload["min"], payload["scale"])
            return flat.reshape(shape).astype(dtype)
        if "tile_meta" in payload:
            from repro.kernels.quantize import dequantize_wire
            codes, meta = payload["codes"], payload["tile_meta"]
            gm, gn = meta.shape[0], meta.shape[1] // 2
            block = (codes.shape[0] // gm, codes.shape[1] // gn)
            flat = dequantize_wire(codes, meta, jnp.float32, block=block)
            return flat.reshape(shape).astype(dtype)
        flat = dequantize_kbit(payload["codes"], payload["min"],
                               payload["scale"])
        return flat.reshape(shape).astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        return self.bits / 8.0


class TopKCodec(WireCodec):
    """(values, indices) of the largest-|.| k_frac entries per example.

    Values ride as bf16; indices are uint16 when the flattened feature dim
    fits in 16 bits (n <= 65536), int32 otherwise — for the paper's typical
    boundary (seq x d_model bf16, 10% kept) that is 0.1*(2+2)=0.4 bytes per
    original element instead of 0.6.
    """

    name = "topk"

    def payload_keysets(self):
        return (("idx", "vals"),)

    def pack(self, x, k_frac: float = 0.1):
        b = x.shape[0]
        flat = x.reshape(b, -1)
        n = flat.shape[1]
        if _use_pallas_wire() and _fullrow_fits(n):
            from repro.kernels.topk_select import topk_select_wire
            k = max(1, int(round(k_frac * n)))   # same k as the jnp path
            vals, idx = topk_select_wire(flat, k)
        else:
            vals, idx = topk_values_indices(flat, k_frac)
        if n <= _U16_MAX_N:
            idx = idx.astype(jnp.uint16)
        return {"vals": vals.astype(jnp.bfloat16), "idx": idx}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        idx = payload["idx"].astype(jnp.int32)
        return topk_scatter(payload["vals"].astype(jnp.float32), idx,
                            shape, jnp.float32).astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 0.1) -> float:
        idx_bytes = 2 if n <= _U16_MAX_N else 4
        return k_frac * (elem_bytes + idx_bytes)


def _use_pallas_wire() -> bool:
    from repro.core.compressors import _use_pallas
    return _use_pallas()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, WireCodec] = {}

# frozenset(payload keys) -> codec name: the unpack_payload dispatch table,
# built at registration from each codec's declared payload_keysets().
_PAYLOAD_KEYSETS: Dict[frozenset, str] = {}


def register_codec(codec: WireCodec) -> WireCodec:
    """Add a codec to the registry (future schemes plug in here)."""
    _REGISTRY[codec.name] = codec
    for keys in codec.payload_keysets():
        ks = frozenset(keys)
        owner = _PAYLOAD_KEYSETS.get(ks)
        if owner is not None and owner != codec.name:
            raise ValueError(f"payload key set {sorted(ks)} already "
                             f"registered to codec {owner!r}")
        _PAYLOAD_KEYSETS[ks] = codec.name
    return codec


def get_codec(name: str) -> WireCodec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown wire scheme {name!r}; "
                         f"registered: {sorted(_REGISTRY)}") from None


def registered_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_codec(NoneCodec())
register_codec(QuantCodec(8))
register_codec(QuantCodec(4))
register_codec(TopKCodec())


def codec_for(comp: Compressor) -> WireCodec:
    """The wire codec realizing a :class:`Compressor` on the network.

    ``codec_for(c).roundtrip(x)`` equals ``c(x)`` on the jnp backend —
    the invariant that makes the simulated boundary wire-faithful.
    """
    if comp.kind == "none":
        return get_codec("none")
    if comp.kind == "quant":
        if comp.bits not in (4, 8):
            raise ValueError(f"no wire codec for {comp.bits}-bit quantization"
                             " (registered: q4, q8)")
        return get_codec(f"q{comp.bits}")
    if comp.kind == "topk":
        return get_codec("topk")
    raise ValueError(f"no wire codec for compressor kind {comp.kind!r}")


# ---------------------------------------------------------------------------
# Functional wrappers (the original core/pipeline.py API)
# ---------------------------------------------------------------------------

def pack_payload(x: jnp.ndarray, scheme: str, k_frac: float = 0.1) -> dict:
    """x: (B, ...) stage output -> wire pytree (static shapes)."""
    return get_codec(scheme).pack(x, k_frac)


def unpack_payload(payload: dict, shape, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Inverse of :func:`pack_payload`: dispatches on the payload's EXACT
    key set, registered per codec via ``payload_keysets()``."""
    name = _PAYLOAD_KEYSETS.get(frozenset(payload))
    if name is None:
        known = sorted(sorted(ks) for ks in _PAYLOAD_KEYSETS)
        raise ValueError(f"payload keys {sorted(payload)} match no "
                         f"registered codec wire format; known: {known}")
    return get_codec(name).unpack(payload, shape, dtype)


def wire_bytes(payload) -> int:
    """Actual bytes-on-wire of a packed payload."""
    return sum(p.size * p.dtype.itemsize for p in jax.tree.leaves(payload))


# ---------------------------------------------------------------------------
# Payload fusion: one contiguous byte buffer per hop
# ---------------------------------------------------------------------------
# A packed payload is a pytree (q8: codes + min + scale; EF-mixed: two full
# payloads), and ``ppermute`` lowers one collective-permute PER LEAF.  On a
# latency-bound interconnect each launch costs the collective's fixed
# overhead, so the fused schedules bitcast every leaf to uint8, concatenate,
# and send ONE buffer per direction per tick — byte-identical on the wire
# (same total payload bytes, pure bitcasts) but a single collective launch.
#
# When the Pallas wire kernels are on, the concatenate (and the slicing on
# the receive side) routes through the one-pass framing kernel
# (kernels/framing.py) — same bytes, one kernel instead of a concat chain.


def _leaf_nbytes(s) -> int:
    nb = jnp.dtype(s.dtype).itemsize
    for dim in s.shape:
        nb *= dim
    return nb


def _bytes_to_leaf(seg: jnp.ndarray, s):
    """Flat uint8 segment -> array of the leaf's shape/dtype (the inverse
    of the per-leaf bitcast in :func:`fuse_payload`)."""
    itemsize = jnp.dtype(s.dtype).itemsize
    if itemsize == 1:
        a = seg.reshape(s.shape)
        return a.astype(s.dtype) if s.dtype == jnp.bool_ else \
            jax.lax.bitcast_convert_type(a, s.dtype)
    return jax.lax.bitcast_convert_type(
        seg.reshape(*s.shape, itemsize), s.dtype)


def _use_pallas_framing(total_bytes: int, n_parts: int) -> bool:
    if n_parts < 2 or not _use_pallas_wire():
        return False
    from repro.kernels.framing import FRAME_MAX_BYTES
    return 0 < total_bytes <= FRAME_MAX_BYTES


def fuse_payload(payload) -> jnp.ndarray:
    """Flatten a packed payload pytree into one contiguous uint8 vector."""
    parts = []
    for a in jax.tree.leaves(payload):
        b = (a.astype(jnp.uint8) if a.dtype == jnp.bool_
             else jax.lax.bitcast_convert_type(a, jnp.uint8))
        parts.append(b.reshape(-1))
    if not parts:
        return jnp.zeros((0,), jnp.uint8)
    if len(parts) == 1:
        return parts[0]
    if _use_pallas_framing(sum(p.size for p in parts), len(parts)):
        from repro.kernels.framing import frame_parts
        return frame_parts(parts)
    return jnp.concatenate(parts)


def unfuse_payload(buf: jnp.ndarray, payload_struct):
    """Inverse of :func:`fuse_payload` given the payload's shape/dtype
    structure (``jax.eval_shape`` of the pack, or the payload itself)."""
    leaves, treedef = jax.tree.flatten(payload_struct)
    sizes = [_leaf_nbytes(s) for s in leaves]
    if _use_pallas_framing(sum(sizes), len(leaves)):
        from repro.kernels.framing import unframe_parts
        segs = unframe_parts(buf, sizes)
    else:
        segs, off = [], 0
        for nb in sizes:
            segs.append(buf[off:off + nb])
            off += nb
    out = [_bytes_to_leaf(seg, s) for seg, s in zip(segs, leaves)]
    return jax.tree.unflatten(treedef, out)
