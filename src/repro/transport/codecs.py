"""Wire codecs: the byte formats a stage boundary actually sends.

One codec = one wire scheme.  ``pack`` maps a boundary tensor ``(B, ...)``
to a payload pytree of arrays (static structure, static shapes — required
inside ``lax.scan`` / ``ppermute``); ``unpack`` inverts it given the
original shape.  Both the simulated boundary (core/boundary.py) and the
real ``ppermute`` pipeline (transport/pipeline.py) consume THIS registry,
so bytes-on-wire accounting and compression semantics cannot drift apart.

Registered schemes:

  * ``none`` — raw bf16                            (2    bytes/elem)
  * ``q8``   — uint8 codes + per-tensor min/scale  (1    byte/elem)
  * ``q4``   — two 4-bit codes packed per uint8    (0.5  byte/elem)
  * ``topk`` — (bf16 values, uint16/int32 indices) (k*(2+idx) bytes/elem)

Quantization uses PER-TENSOR min/max scales so that
``codec.roundtrip(x) == quantize_dequantize(x, bits)`` exactly — the
simulated boundary's C(x) and the real wire round-trip are bit-identical
(tested in tests/test_transport.py).  TopK indices are ``uint16`` whenever
the flattened per-example feature dim fits in 16 bits, ``int32`` otherwise.

On TPU the ``q8`` pack/unpack routes through the fused Pallas wire kernels
(kernels/quantize.py, per-tile scales) when the flattened shape tiles into
128-lane blocks; elsewhere the pure-jnp path is used.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import (Compressor, dequantize_kbit,
                                    quantize_kbit, topk_scatter,
                                    topk_values_indices)

# Index dtype threshold: a flattened feature dim of up to 2**16 entries has
# indices 0..65535, exactly the uint16 range.
_U16_MAX_N = 1 << 16


def _flat_n(shape) -> int:
    n = 1
    for s in shape[1:]:
        n *= s
    return n


class WireCodec:
    """Base class: a named wire format with a bytes-per-element cost model.

    ``pack(x, k_frac)``   : (B, ...) tensor -> payload dict (static shapes).
    ``unpack(payload, shape, dtype)`` : payload -> (B, ...) tensor.
    ``wire_bytes_per_elem(n, elem_bytes, k_frac)`` : cost model, excluding
    the per-tensor scale overhead (O(1) bytes).
    """

    name: str = "?"

    def pack(self, x: jnp.ndarray, k_frac: float = 1.0) -> dict:
        raise NotImplementedError

    def unpack(self, payload: dict, shape, dtype=jnp.bfloat16) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes_per_elem(self, n: int, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        raise NotImplementedError

    def roundtrip(self, x: jnp.ndarray, k_frac: float = 1.0,
                  dtype=None) -> jnp.ndarray:
        """pack -> unpack: the dense C(x) equivalent of this wire format."""
        return self.unpack(self.pack(x, k_frac), x.shape,
                           dtype or x.dtype)


class NoneCodec(WireCodec):
    """Raw bf16 — the uncompressed baseline wire format."""

    name = "none"

    def pack(self, x, k_frac: float = 1.0):
        return {"raw": x.astype(jnp.bfloat16)}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        return payload["raw"].astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        return float(elem_bytes)


def _pallas_tiling(flat_shape) -> Optional[Tuple[int, int]]:
    """(bm, bn) for the Pallas wire kernels, or None when no tiling fits."""
    m, n = flat_shape
    bn = next((c for c in (2048, 1024, 512, 256, 128) if n % c == 0), None)
    if bn is None:
        return None
    bm = max(1, min(256, m))
    while m % bm:
        bm -= 1
    return bm, bn


class QuantCodec(WireCodec):
    """Uniform k-bit min-max quantization; 4-bit packs two codes per byte.

    Per-tensor scales (paper Sec. 2.2) on the jnp path; on TPU the 8-bit
    variant uses the fused Pallas wire kernels with per-tile scales
    (kernels/quantize.py — strictly more accurate at the same wire cost).
    """

    def __init__(self, bits: int):
        assert bits in (4, 8), bits
        self.bits = bits
        self.name = f"q{bits}"

    def pack(self, x, k_frac: float = 1.0):
        b = x.shape[0]
        flat = x.reshape(b, -1)
        if self.bits == 8 and _use_pallas_wire():
            tiling = _pallas_tiling(flat.shape)
            if tiling is not None:
                from repro.kernels.quantize import quantize_wire
                codes, meta = quantize_wire(flat.astype(jnp.float32), 8,
                                            block=tiling)
                return {"codes": codes, "tile_meta": meta}
        codes, mn, sc = quantize_kbit(flat.astype(jnp.float32), self.bits,
                                      axis=None)
        if self.bits == 4:
            n = flat.shape[1]
            if n % 2:                       # odd feature dim: pad one code
                codes = jnp.pad(codes, ((0, 0), (0, 1)))
            even = codes[:, 0::2]
            odd = codes[:, 1::2]
            packed = (even | (odd << 4)).astype(jnp.uint8)
            return {"codes4": packed, "min": mn, "scale": sc}
        return {"codes": codes, "min": mn, "scale": sc}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        b = shape[0]
        n = _flat_n(shape)
        if "codes4" in payload:
            packed = payload["codes4"]
            even = packed & 0xF
            odd = packed >> 4
            codes = jnp.stack([even, odd], axis=-1).reshape(b, -1)[:, :n]
            flat = dequantize_kbit(codes, payload["min"], payload["scale"])
            return flat.reshape(shape).astype(dtype)
        if "tile_meta" in payload:
            from repro.kernels.quantize import dequantize_wire
            codes, meta = payload["codes"], payload["tile_meta"]
            gm, gn = meta.shape[0], meta.shape[1] // 2
            block = (codes.shape[0] // gm, codes.shape[1] // gn)
            flat = dequantize_wire(codes, meta, jnp.float32, block=block)
            return flat.reshape(shape).astype(dtype)
        flat = dequantize_kbit(payload["codes"], payload["min"],
                               payload["scale"])
        return flat.reshape(shape).astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 1.0) -> float:
        return self.bits / 8.0


class TopKCodec(WireCodec):
    """(values, indices) of the largest-|.| k_frac entries per example.

    Values ride as bf16; indices are uint16 when the flattened feature dim
    fits in 16 bits (n <= 65536), int32 otherwise — for the paper's typical
    boundary (seq x d_model bf16, 10% kept) that is 0.1*(2+2)=0.4 bytes per
    original element instead of 0.6.
    """

    name = "topk"

    def pack(self, x, k_frac: float = 0.1):
        b = x.shape[0]
        flat = x.reshape(b, -1)
        vals, idx = topk_values_indices(flat, k_frac)
        if flat.shape[1] <= _U16_MAX_N:
            idx = idx.astype(jnp.uint16)
        return {"vals": vals.astype(jnp.bfloat16), "idx": idx}

    def unpack(self, payload, shape, dtype=jnp.bfloat16):
        idx = payload["idx"].astype(jnp.int32)
        return topk_scatter(payload["vals"].astype(jnp.float32), idx,
                            shape, jnp.float32).astype(dtype)

    def wire_bytes_per_elem(self, n, elem_bytes: int = 2,
                            k_frac: float = 0.1) -> float:
        idx_bytes = 2 if n <= _U16_MAX_N else 4
        return k_frac * (elem_bytes + idx_bytes)


def _use_pallas_wire() -> bool:
    from repro.core.compressors import _use_pallas
    return _use_pallas()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, WireCodec] = {}


def register_codec(codec: WireCodec) -> WireCodec:
    """Add a codec to the registry (future schemes plug in here)."""
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> WireCodec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown wire scheme {name!r}; "
                         f"registered: {sorted(_REGISTRY)}") from None


def registered_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_codec(NoneCodec())
register_codec(QuantCodec(8))
register_codec(QuantCodec(4))
register_codec(TopKCodec())


def codec_for(comp: Compressor) -> WireCodec:
    """The wire codec realizing a :class:`Compressor` on the network.

    ``codec_for(c).roundtrip(x)`` equals ``c(x)`` on the jnp backend —
    the invariant that makes the simulated boundary wire-faithful.
    """
    if comp.kind == "none":
        return get_codec("none")
    if comp.kind == "quant":
        if comp.bits not in (4, 8):
            raise ValueError(f"no wire codec for {comp.bits}-bit quantization"
                             " (registered: q4, q8)")
        return get_codec(f"q{comp.bits}")
    if comp.kind == "topk":
        return get_codec("topk")
    raise ValueError(f"no wire codec for compressor kind {comp.kind!r}")


# ---------------------------------------------------------------------------
# Functional wrappers (the original core/pipeline.py API)
# ---------------------------------------------------------------------------

def pack_payload(x: jnp.ndarray, scheme: str, k_frac: float = 0.1) -> dict:
    """x: (B, ...) stage output -> wire pytree (static shapes)."""
    return get_codec(scheme).pack(x, k_frac)


def unpack_payload(payload: dict, shape, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Inverse of :func:`pack_payload` (dispatches on payload keys)."""
    for key, name in (("raw", "none"), ("codes4", "q4"), ("vals", "topk"),
                      ("codes", "q8"), ("tile_meta", "q8")):
        if key in payload:
            return get_codec(name).unpack(payload, shape, dtype)
    raise ValueError(list(payload))


def wire_bytes(payload) -> int:
    """Actual bytes-on-wire of a packed payload."""
    return sum(p.size * p.dtype.itemsize for p in jax.tree.leaves(payload))


# ---------------------------------------------------------------------------
# Payload fusion: one contiguous byte buffer per hop
# ---------------------------------------------------------------------------
# A packed payload is a pytree (q8: codes + min + scale; EF-mixed: two full
# payloads), and ``ppermute`` lowers one collective-permute PER LEAF.  On a
# latency-bound interconnect each launch costs the collective's fixed
# overhead, so the fused schedules bitcast every leaf to uint8, concatenate,
# and send ONE buffer per direction per tick — byte-identical on the wire
# (same total payload bytes, pure bitcasts) but a single collective launch.

def fuse_payload(payload) -> jnp.ndarray:
    """Flatten a packed payload pytree into one contiguous uint8 vector."""
    parts = []
    for a in jax.tree.leaves(payload):
        b = (a.astype(jnp.uint8) if a.dtype == jnp.bool_
             else jax.lax.bitcast_convert_type(a, jnp.uint8))
        parts.append(b.reshape(-1))
    if not parts:
        return jnp.zeros((0,), jnp.uint8)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unfuse_payload(buf: jnp.ndarray, payload_struct):
    """Inverse of :func:`fuse_payload` given the payload's shape/dtype
    structure (``jax.eval_shape`` of the pack, or the payload itself)."""
    leaves, treedef = jax.tree.flatten(payload_struct)
    out, off = [], 0
    for s in leaves:
        itemsize = jnp.dtype(s.dtype).itemsize
        size = 1
        for dim in s.shape:
            size *= dim
        nbytes = size * itemsize
        seg = buf[off:off + nbytes]
        off += nbytes
        if itemsize == 1:
            a = seg.reshape(s.shape)
            a = a.astype(s.dtype) if s.dtype == jnp.bool_ else \
                jax.lax.bitcast_convert_type(a, s.dtype)
        else:
            a = jax.lax.bitcast_convert_type(
                seg.reshape(*s.shape, itemsize), s.dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)
