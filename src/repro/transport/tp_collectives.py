"""Compressed tensor-parallel collectives over the wire codecs.

The third communication axis: on a ``(data, stage, tensor)`` mesh the
attention/MLP weights shard over ``tensor`` (Megatron-style column/row
parallelism with the residual stream SEQUENCE-sharded, the Megatron-SP
layout), and what crosses the tensor ring is a PACKED payload from the
same wire-codec registry the stage boundaries and the DP gradient
all-reduce use (transport/codecs.py, fused uint8 framing via
kernels/framing.py):

  * activation path: an ALL-GATHER of the sequence-sharded residual
    before each sharded matmul group — every rank packs its ``(B, S/tp,
    d)`` shard, the payloads ride a ``ppermute`` ring (``tp - 1`` hops),
    and every rank decodes all ``tp`` payloads in source-rank order, so
    the gathered activation is bitwise identical on every rank;
  * gradient path: a REDUCE-SCATTER of the partial outputs / incoming
    activation-gradients — rank ``r`` packs the slice destined for each
    peer and sends it at ring distance ``h`` (``tp - 1`` single-hop
    permutes), then sums the ``tp`` decoded contributions for its own
    slice in source-rank order (fixed association).

Both primitives are differentiable with the straight-through convention
the pipeline transport uses: the VJP of the compressed all-gather is the
compressed reduce-scatter of the incoming cotangent, and vice versa — so
activations compress forward and activation-gradients compress backward,
the paper's asymmetry, now on the tensor axis.

Error feedback (``FeedbackState(scope="tp")``, see
:func:`init_tp_state`) compensates the FORWARD all-gather (the
activation side, where the paper shows compensation matters most):

  * ``ef``   — send C(x + e);  e' = x + e - C(x + e)   (resid is
               sequence-sharded like x);
  * ``ef21`` — send the delta C(x - M_r) against a model M of every
               rank's shard; all ranks apply all decoded deltas, so M
               stays REPLICATED across the ring and the gathered
               activation IS the updated model (no separate resid).

``codec="none"`` is a RAW passthrough (dtype-preserving), so an
uncompressed TP program is bit-exact against a single-device reference
that applies the same rank-ordered partial-sum association.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.feedback import FEEDBACK_REGISTRY, FeedbackState
from repro.transport.base import shard_map_compat
from repro.transport.codecs import (
    fuse_payload,
    get_codec,
    unfuse_payload,
    wire_bytes,
)
from repro.transport.collectives import (
    _leaf_n,
    _ring_gather,
    pack_grad_leaf,
    unpack_grad_leaf,
)

# The modes whose registry entry admits the "tp" scope (core/feedback.py).
TP_FEEDBACK_MODES = tuple(m.name for m in FEEDBACK_REGISTRY.values()
                          if "tp" in m.scopes)


def tp_payload_struct(shard_shape, codec_name: str, *, k_frac: float = 0.1,
                      dtype=jnp.bfloat16):
    """``eval_shape`` of one packed activation shard — the exact
    bytes-on-wire source for the benchmark's "tp" section."""
    codec = get_codec(codec_name)
    return jax.eval_shape(
        lambda a: pack_grad_leaf(codec, a, k_frac),
        jax.ShapeDtypeStruct(shard_shape, dtype))


def tp_wire_report(feat_shape, tp: int, codec_name: str, *,
                   k_frac: float = 0.1, dtype=jnp.bfloat16,
                   seq_dim: int = 1, sites: int = 1) -> dict:
    """Exact and modeled wire bytes of the TP collectives for one FULL
    activation of shape ``feat_shape`` (the sequence dim ``seq_dim``
    shards over the ring).

    Per collective (all-gather OR reduce-scatter) each rank sends
    ``tp - 1`` payloads of one packed ``(.., S/tp, ..)`` shard;
    ``payload_bytes_per_hop`` is exact (from the packed shapes),
    ``model_bytes`` is the codec's per-element cost model.  ``sites`` is
    the number of gather+scatter cut points a forward pass crosses (2 per
    sharded-matmul group: in-gather + out-scatter).
    """
    if feat_shape[seq_dim] % tp:
        raise ValueError(f"feat dim {seq_dim} ({feat_shape[seq_dim]}) "
                         f"not divisible by tp={tp}")
    codec = get_codec(codec_name)
    shard = list(feat_shape)
    shard[seq_dim] //= tp
    struct = tp_payload_struct(tuple(shard), codec_name, k_frac=k_frac,
                               dtype=dtype)
    exact = wire_bytes(struct)
    n = _leaf_n(shard)
    elem = jnp.dtype(dtype).itemsize if codec.name == "none" else 2
    model = codec.wire_bytes_per_elem(n, elem, k_frac) * n
    return {
        "tp_codec": codec_name, "k_frac": k_frac, "tp": tp,
        "shard_elems": n,
        "n_payload_leaves": len(jax.tree.leaves(struct)),
        "payload_bytes_per_hop": exact,
        "model_bytes": round(model),
        "hops_per_collective": tp - 1,
        "wire_bytes_per_collective": (tp - 1) * exact,
        "sites_per_forward": sites,
        "wire_bytes_per_forward": sites * 2 * (tp - 1) * exact,
    }


def init_tp_state(feat_shape, sites: int, feedback: str = "none",
                  dtype=jnp.float32) -> FeedbackState:
    """Per-site TP feedback state, carried in the train state.

    ``feat_shape`` is the FULL activation entering the layer stack
    (global batch — the batch dim shards over ``data``, the sequence dim
    over ``tensor``; activations are naturally batch-sharded so no
    replica stacking is needed).  ``sites`` counts the all-gather cut
    points per forward (2 per transformer block: attention + MLP
    in-gathers).  ``resid`` (EF) is sharded like the activations;
    ``mirror`` (EF21's model M) is replicated over the ring.
    """
    if feedback not in TP_FEEDBACK_MODES:
        raise ValueError(f"unknown tp feedback {feedback!r}; "
                         f"known: {TP_FEEDBACK_MODES}")
    z = jnp.zeros((0,), dtype)
    if feedback == "none":
        return FeedbackState(resid=z, mirror=z, agg=z, scope="tp",
                             direction="act", mode=feedback)
    buf = jnp.zeros((sites, *feat_shape), dtype)
    if feedback == "ef":
        return FeedbackState(resid=buf, mirror=z, agg=z, scope="tp",
                             direction="act", mode=feedback)
    return FeedbackState(resid=z, mirror=buf, agg=z, scope="tp",
                         direction="act", mode=feedback)


@dataclasses.dataclass
class TPCollectives:
    """The compressed TP wire for one mesh axis.

    Built once per train step (static config: codec, feedback, fusion);
    the differentiable :meth:`gather` / :meth:`scatter` close over the
    ring and are called from inside a ``shard_map`` body that binds
    ``axis`` (models/transformer.py's TP stage fn, via :func:`tp_apply`
    or the pipeline).  ``seq_dim`` is the activation dim sharded over the
    ring (1 for ``(B, S, d)``).
    """

    mesh: Mesh
    axis: str
    codec: str = "none"
    k_frac: float = 0.1
    feedback: str = "none"
    fused: bool = True
    seq_dim: int = 1

    def __post_init__(self):
        if self.feedback not in TP_FEEDBACK_MODES:
            raise ValueError(f"unknown tp feedback {self.feedback!r}; "
                             f"known: {TP_FEEDBACK_MODES}")
        if self.feedback != "none" and self.codec == "none":
            raise ValueError(
                "tp feedback compensates a LOSSY tp codec; with "
                "codec='none' there is nothing to compensate")
        self.tp = self.mesh.shape[self.axis]
        self._codec = get_codec(self.codec)
        self._gather_p = self._make_gather_p()
        self._scatter_p = self._make_scatter_p()

    # -- wire primitives (non-differentiable; called inside shard_map) -----

    def _pack(self, x):
        return pack_grad_leaf(self._codec, x, self.k_frac)

    def _decode(self, payload, shape, dtype):
        m = unpack_grad_leaf(self._codec, payload, shape)
        return m.astype(dtype)

    def _slot(self, slots, struct, s: int):
        if self.fused:
            return unfuse_payload(slots[s], struct)
        return jax.tree.map(lambda a: a[s], slots)

    def all_gather_wire(self, x_shard):
        """Ring all-gather of packed shards; every rank decodes all ``tp``
        payloads and concatenates in source-rank order (bitwise identical
        output on every rank)."""
        if self.tp == 1:
            return x_shard
        payload = self._pack(x_shard)
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payload)
        wire = fuse_payload(payload) if self.fused else payload
        slots = _ring_gather(wire, self.axis, self.tp)
        parts = [
            self._decode(self._slot(slots, struct, s), x_shard.shape,
                         x_shard.dtype)
            for s in range(self.tp)
        ]
        return jnp.concatenate(parts, axis=self.seq_dim)

    def reduce_scatter_wire(self, partial):
        """Packed-slice exchange + source-rank-ordered sum: rank ``r``
        keeps ``sum_s C(partial_s[slice r])``.  Every contribution —
        including the rank's own — goes through the codec, so the sum is
        uniformly compressed (same convention as the DP reduce)."""
        tp, dim = self.tp, self.seq_dim
        if tp == 1:
            return partial
        if partial.shape[dim] % tp:
            raise ValueError(f"reduce-scatter dim {dim} "
                             f"({partial.shape[dim]}) not divisible by "
                             f"tp={tp}")
        sl = partial.shape[dim] // tp
        r = jax.lax.axis_index(self.axis)
        payloads = [
            self._pack(jax.lax.dynamic_slice_in_dim(partial, j * sl, sl,
                                                    dim))
            for j in range(tp)
        ]
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payloads[0])
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *payloads)
        own = jax.tree.map(lambda a: a[r], stacked)
        slots = jax.tree.map(
            lambda a: jnp.zeros((tp, *a.shape), a.dtype).at[r].set(a), own)
        for h in range(1, tp):
            dest = (r + h) % tp
            send = jax.tree.map(lambda a: a[dest], stacked)
            perm = [(i, (i + h) % tp) for i in range(tp)]
            if self.fused:
                buf = jax.lax.ppermute(fuse_payload(send), self.axis, perm)
                moved = unfuse_payload(buf, struct)
            else:
                moved = jax.lax.ppermute(send, self.axis, perm)
            src = (r - h) % tp
            slots = jax.tree.map(
                lambda banked, a: banked.at[src].set(a), slots, moved)
        shard_shape = list(partial.shape)
        shard_shape[dim] = sl
        out = None
        for s in range(tp):
            m = self._decode(jax.tree.map(lambda a: a[s], slots),
                             tuple(shard_shape), partial.dtype)
            out = m if out is None else out + m
        return out

    # -- differentiable collectives (straight-through custom_vjp) ----------

    def _make_gather_p(self) -> Callable:
        """``gather_p(x_shard, add) -> full``: all-gather of
        ``C(x + add)`` (``add`` carries the stop-gradient feedback term);
        VJP = compressed reduce-scatter of the incoming cotangent."""

        @jax.custom_vjp
        def gather_p(x, add):
            return self.all_gather_wire(x + add)

        def fwd(x, add):
            return gather_p(x, add), None

        def bwd(_, dfull):
            dx = self.reduce_scatter_wire(dfull)
            return dx, jnp.zeros_like(dx)

        gather_p.defvjp(fwd, bwd)
        return gather_p

    def _make_scatter_p(self) -> Callable:
        """``scatter_p(partial) -> shard``: compressed reduce-scatter;
        VJP = compressed all-gather of the incoming cotangent."""

        @jax.custom_vjp
        def scatter_p(partial):
            return self.reduce_scatter_wire(partial)

        def fwd(partial):
            return scatter_p(partial), None

        def bwd(_, dshard):
            return (self.all_gather_wire(dshard),)

        scatter_p.defvjp(fwd, bwd)
        return scatter_p

    def _own_slice(self, full, sl: int):
        r = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(full, r * sl, sl, self.seq_dim)

    def gather(self, x_shard, resid=None, mirror=None):
        """Differentiable compressed all-gather with feedback.

        ``resid``/``mirror`` are ONE site's buffers (shape of the full /
        sharded activation, see :func:`init_tp_state`) or None.  Returns
        ``(full, new_resid, new_mirror)`` — state updates are
        stop-gradient (forward-only, like the pipeline's fw buffers).
        """
        sg = jax.lax.stop_gradient
        sl = x_shard.shape[self.seq_dim]
        if self.feedback == "none" or self.tp == 1:
            full = self._gather_p(x_shard, jnp.zeros_like(x_shard))
            return full, resid, mirror
        if self.feedback == "ef":
            e = resid.astype(x_shard.dtype)
            full = self._gather_p(x_shard, sg(e))
            own = self._own_slice(full, sl)
            new_resid = sg((x_shard + e - own).astype(resid.dtype))
            return full, new_resid, mirror
        # ef21: the wire carries the delta against the replicated model M;
        # the gathered activation IS the updated model.
        m_own = self._own_slice(mirror, sl).astype(x_shard.dtype)
        delta_full = self._gather_p(x_shard, sg(-m_own))
        full = mirror.astype(x_shard.dtype) + delta_full
        new_mirror = sg(full.astype(mirror.dtype))
        return full, resid, new_mirror

    def gather_site(self, x_shard, buf=None):
        """One cut point's :meth:`gather` with its single ACTIVE buffer
        (EF's resid / EF21's mirror / ignored for "none") — what the
        layer-stack loop threads per site."""
        if self.feedback == "ef":
            full, buf, _ = self.gather(x_shard, resid=buf)
        elif self.feedback == "ef21":
            full, _, buf = self.gather(x_shard, mirror=buf)
        else:
            full, _, _ = self.gather(x_shard)
        return full, buf

    def scatter(self, partial):
        """Differentiable compressed reduce-scatter (no feedback: the
        partial-output sum is the gradient-path twin of the DP reduce,
        which also runs codec-only)."""
        if self.tp == 1:
            return partial
        return self._scatter_p(partial)

    def wire_report(self, feat_shape, *, sites: int = 1,
                    dtype=jnp.bfloat16) -> dict:
        return tp_wire_report(feat_shape, self.tp, self.codec,
                              k_frac=self.k_frac, dtype=dtype,
                              seq_dim=self.seq_dim, sites=sites)


def _trace_wire(tpc: TPCollectives, feat_shape, dtype, sites: int) -> None:
    """Emit the TP-ring wire facts when tracing is on (trace time only —
    the body executes once per jit compilation)."""
    from repro.obs import trace
    tr = trace.get_tracer()
    if tr is None or tpc.tp == 1:
        return
    rep = tp_wire_report(feat_shape, tpc.tp, tpc.codec, k_frac=tpc.k_frac,
                         dtype=dtype, seq_dim=tpc.seq_dim, sites=sites)
    tr.instant("tp.wire", cat="wire", axis=tpc.axis, feedback=tpc.feedback,
               fused=tpc.fused,
               launches_per_hop=(1 if tpc.fused
                                 else rep["n_payload_leaves"]),
               **rep)


def tp_apply(fn: Callable, params, x, tpc: TPCollectives, *,
             param_dims, state: Optional[FeedbackState] = None,
             batch_axis: Optional[str] = None, sites: int = 0):
    """Run a TP stage function inside ``shard_map`` over the tensor ring.

    ``fn(params_local, x_local, resid_local, mirror_local) ->
    (y_local, new_resid, new_mirror)`` computes the layer stack on the
    sequence-sharded residual, calling ``tpc.gather``/``tpc.scatter`` at
    the cut points (models/transformer.py's ``tp_stage_stack_fn``).

    ``params``: the stack pytree — each leaf shards over the ring at the
    dim given by ``param_dims`` (a matching pytree of ints; -1 =
    replicated, e.g. norms, whose tiny gradients all-reduce via the
    shard_map transpose psum — the "all-reduce on the gradient path").
    When ``batch_axis`` is given (the DP x TP mesh) each leaf instead
    carries a LEADING broadcast replica dim ``(dp, ...)`` — its gradient
    comes back PER REPLICA for the compressed DP reduce — and ``x``'s
    batch dim shards over ``batch_axis``.

    ``state``: a scope-"tp" :class:`FeedbackState` (or None); returns
    ``(y, new_state)`` with ``y`` the reassembled full activation.
    """
    axis, seq_dim, tp = tpc.axis, tpc.seq_dim, tpc.tp
    if x.shape[seq_dim] % tp:
        raise ValueError(f"sequence dim {seq_dim} ({x.shape[seq_dim]}) "
                         f"not divisible by tp={tp}")
    if state is not None and state.scope != "tp":
        raise ValueError(f"tp_apply needs scope='tp' state, got "
                         f"{state.scope!r}")
    _trace_wire(tpc, x.shape, x.dtype, sites)

    lead = 1 if batch_axis is not None else 0

    def pspec(a, d):
        spec = [None] * a.ndim
        if batch_axis is not None:
            spec[0] = batch_axis
        if d >= 0:
            spec[d + lead] = axis
        return P(*spec)

    x_spec = P(*[batch_axis if i == 0 else (axis if i == seq_dim else None)
                 for i in range(x.ndim)])

    def st_spec(a, sharded: bool):
        if a.ndim != x.ndim + 1:          # size-0 placeholder
            return P(*([None] * a.ndim))
        inner = [batch_axis if i == 0 else
                 (axis if (i == seq_dim and sharded) else None)
                 for i in range(x.ndim)]
        return P(None, *inner)

    if state is None:
        state = init_tp_state(x.shape, max(sites, 1), "none")
    rspec = jax.tree.map(lambda a: st_spec(a, True), state.resid)
    mspec = jax.tree.map(lambda a: st_spec(a, False), state.mirror)

    def body(p, xs, rs, ms):
        if batch_axis is not None:
            p = jax.tree.map(lambda a: a[0], p)
        y, nr, nm = fn(p, xs, rs, ms)
        return y, nr, nm

    p_specs = jax.tree.map(pspec, params, param_dims)
    y, new_resid, new_mirror = shard_map_compat(
        body, tpc.mesh,
        (p_specs, x_spec, rspec, mspec),
        (x_spec, rspec, mspec),
    )(params, x, state.resid, state.mirror)
    return y, state.replace(resid=new_resid, mirror=new_mirror)
