"""The transport interface: what crosses a pipeline-stage cut, both ways.

A :class:`Transport` realizes ONE boundary of a :class:`CompressionPolicy`:

  ``fw(x, fw_buf, ids)  -> (message, new_fw_buf, ctx)``
      the forward activation crossing the cut (feedback-wrapped compressor);
      ``ctx`` carries whatever the backward direction needs (e.g. the
      forward TopK mask / indices for ``reuse_indices``).

  ``bw(g, bw_buf, ctx)  -> (grad_message, new_bw_buf)``
      the backward activation-gradient crossing the cut in the reverse
      direction.

Two implementations exist:

  * :class:`repro.transport.simulated.SimulatedTransport` — single-device,
    convergence-faithful (the paper's Sec. 2.1 setup); used inside the
    ``jax.custom_vjp`` boundary in core/boundary.py.
  * :class:`repro.transport.pipeline.PipelineTransport` — the real
    ``shard_map``/``ppermute`` path: packed payloads on the wire in both
    directions, with per-stage feedback buffers threaded through the
    pipeline scan (``fw_hop``/``bw_hop`` extend fw/bw with the buffer
    slice bookkeeping; delta-coded modes add receiver-side mirrors).

Both consume the same wire-codec registry (transport/codecs.py), so the
simulated C(x) and the real packed bytes round-trip identically.

Error feedback is wire-cost-free: EF packs the compensated tensor
``x + e`` (same codec, same bytes), EF-mixed packs two half-K payloads
(k/2 + k/2 = k), and EF21/AQ-SGD pack the delta ``x - buf`` (again one
codec payload) — so :meth:`Transport.wire_bytes_per_example` holds for
every feedback mode, which the pipeline_wire benchmark asserts.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import BoundaryPolicy
from repro.transport.codecs import WireCodec, codec_for


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` moved between jax versions; replication checking
    is off either way (payload pytrees confuse it).  Shared by the pipeline
    transport and the DP gradient collectives."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


class Transport:
    """One stage cut: a forward and a backward wire direction."""

    policy: BoundaryPolicy

    def fw(self, x: jnp.ndarray, fw_buf=None, ids=None
           ) -> Tuple[jnp.ndarray, Any, Any]:
        raise NotImplementedError

    def bw(self, g: jnp.ndarray, bw_buf=None, ctx=None
           ) -> Tuple[jnp.ndarray, Any]:
        raise NotImplementedError

    # -- wire accounting (shared by benchmarks) -----------------------------

    def fw_codec(self) -> Optional[WireCodec]:
        try:
            return codec_for(self.policy.fw)
        except ValueError:
            return None

    def bw_codec(self) -> Optional[WireCodec]:
        try:
            return codec_for(self.policy.bw)
        except ValueError:
            return None

    def wire_bytes_per_example(self, n: int, elem_bytes: int = 2
                               ) -> Tuple[float, float]:
        """(fw, bw) modeled bytes for one example's boundary tensor of
        ``n`` flattened elements (excl. per-tensor scale overhead)."""
        fw_c, bw_c = self.fw_codec(), self.bw_codec()
        fw = (fw_c.wire_bytes_per_elem(n, elem_bytes, self.policy.fw.k_frac)
              * n if fw_c else float("nan"))
        if self.policy.reuse_indices and bw_c is not None:
            # indices already live at both ends after the forward send: the
            # backward payload is values only, and its length is set by the
            # FORWARD pack's k (the reused indices), not the bw compressor.
            bw = self.policy.fw.k_frac * n * elem_bytes
        else:
            bw = (bw_c.wire_bytes_per_elem(n, elem_bytes,
                                           self.policy.bw.k_frac) * n
                  if bw_c else float("nan"))
        return fw, bw
