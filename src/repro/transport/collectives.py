"""Compressed data-parallel gradient all-reduce over the wire codecs.

The gradient-side twin of the pipeline's compressed activation hops: on a
``(data, stages)`` mesh every replica owns the gradient of its batch shard,
and what crosses the ``data`` axis is a PACKED payload from the same
wire-codec registry the stage boundaries use (transport/codecs.py) — the
paper's activation-compression and gradient-compression regimes finally run
simultaneously on one mesh (paper Tables 2-3: gradients tolerate milder
rates than activations; error feedback rescues aggressive ones).

Scheme (the standard compress-then-exchange all-reduce, cf. Agarwal et al.,
*On the Utility of Gradient Compression in Distributed Training Systems*):

  1. every replica packs each parameter-leaf gradient with one codec call
     (per-leaf per-tensor scales; ragged/odd-sized leaves hit the q4 pad
     path), optionally error-compensated by PER-REPLICA residual buffers;
  2. all per-leaf payloads are FUSED into one contiguous uint8 buffer (the
     1F1B fused-hop trick — one collective launch per ring hop instead of
     one per payload leaf);
  3. the buffers ride a ``ppermute`` ring over the data axis (``dp - 1``
     hops), each replica banking the in-flight buffer by SOURCE RANK;
  4. every replica decodes the ``dp`` payloads and sums them in source-rank
     order — a fixed association, so all replicas compute a bitwise
     identical reduced gradient (ring-order sums would diverge per rank).

``codec="none"`` is a RAW passthrough (native dtype, no bf16 downcast), so
an uncompressed DP reduce is bit-exact against serial gradient summation —
the acceptance baseline.  Error feedback (the gradient-axis analog of the
PR-2 boundary buffers; buffers ride the train state, see
:func:`init_dp_state`):

  * ``ef``   — send C(g + e);                 e' = g + e - C(g + e)
  * ``ef21`` — send the delta C(g - w);       w' = w + C(g - w), and the
               receivers reconstruct the sum from a REPLICATED aggregate
               G = sum_r w_r (no per-sender mirrors needed: the reduced
               gradient is G + sum_r C(g_r - w_r), which updates G).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.feedback import FEEDBACK_REGISTRY, FeedbackState
from repro.transport.base import shard_map_compat
from repro.transport.codecs import (WireCodec, _use_pallas_wire,
                                    fuse_payload, get_codec, unfuse_payload,
                                    wire_bytes)

# The modes whose registry entry admits the "dp" scope (core/feedback.py).
DP_FEEDBACK_MODES = tuple(m.name for m in FEEDBACK_REGISTRY.values()
                          if "dp" in m.scopes)


def _leaf_n(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def pack_grad_leaf(codec: WireCodec, a: jnp.ndarray, k_frac: float = 0.1):
    """One parameter leaf -> wire payload.  ``none`` passes the RAW leaf
    through (dtype-preserving: the uncompressed reduce stays bit-exact);
    lossy codecs flatten to ``(1, n)`` — one per-tensor scale per leaf, the
    q4 pad path for odd ``n``, uint16 TopK indices when ``n`` fits."""
    if codec.name == "none":
        return a
    return codec.pack(a.reshape(1, -1).astype(jnp.float32), k_frac)


def unpack_grad_leaf(codec: WireCodec, payload, shape) -> jnp.ndarray:
    """Inverse of :func:`pack_grad_leaf`; lossy codecs decode to f32."""
    if codec.name == "none":
        return payload
    n = _leaf_n(shape)
    return codec.unpack(payload, (1, n), jnp.float32).reshape(shape)


def grad_payload_structs(grads_like, codec_name: str,
                         k_frac: float = 0.1) -> List:
    """``eval_shape`` of every leaf's packed payload — the exact
    bytes-on-wire source for the benchmark's "dp" section."""
    codec = get_codec(codec_name)
    return [
        jax.eval_shape(lambda a: pack_grad_leaf(codec, a, k_frac),
                       jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
        for leaf in jax.tree.leaves(grads_like)
    ]


def dp_wire_report(grads_like, codec_name: str, *, k_frac: float = 0.1,
                   dp: int = 2) -> dict:
    """Exact and modeled wire bytes of ONE compressed DP all-reduce.

    ``payload_bytes_per_hop``: the fused uint8 buffer each replica sends
    per ring hop (exact, from the packed payload shapes).  ``model_bytes``:
    sum over leaves of ``n * wire_bytes_per_elem`` (q4/topk per-leaf
    raggedness included).  One reduce = ``dp - 1`` hops per replica.
    """
    codec = get_codec(codec_name)
    structs = grad_payload_structs(grads_like, codec_name, k_frac)
    exact = wire_bytes(structs)
    model = 0.0
    for leaf in jax.tree.leaves(grads_like):
        n = _leaf_n(leaf.shape)
        elem = (jnp.dtype(leaf.dtype).itemsize if codec.name == "none"
                else 2)
        model += codec.wire_bytes_per_elem(n, elem, k_frac) * n
    return {
        "dp_codec": codec_name, "k_frac": k_frac, "dp": dp,
        "n_param_leaves": len(structs),
        "n_payload_leaves": len(jax.tree.leaves(structs)),
        "payload_bytes_per_hop": exact,
        "model_bytes": round(model),
        "hops_per_reduce": dp - 1,
        "wire_bytes_per_reduce": (dp - 1) * exact,
    }


def init_dp_state(grads_like, dp: int, feedback: str = "none",
                  dtype=jnp.float32) -> FeedbackState:
    """Per-replica DP feedback state, carried in the train state (and the
    train-state checkpoint — exact-resume includes the residuals).

    A :class:`repro.core.feedback.FeedbackState` at scope ``"dp"``:
    ``resid`` holds ``(dp, *leaf)`` per-replica buffers (EF's error
    ``e_r`` / EF21's gradient model ``w_r``); ``agg`` is EF21's replicated
    aggregate ``G = sum_r w_r``.  ``mirror`` and unused slots are size-0
    placeholders so the pytree structure is mode-stable.
    """
    if feedback not in DP_FEEDBACK_MODES:
        raise ValueError(f"unknown dp feedback {feedback!r}; "
                         f"known: {DP_FEEDBACK_MODES}")
    z = jnp.zeros((0,), dtype)
    if feedback == "none":
        return FeedbackState(resid=jnp.zeros((dp, 0), dtype), mirror=z,
                             agg=z, scope="dp", direction="grad",
                             mode=feedback)
    resid = jax.tree.map(lambda a: jnp.zeros((dp, *a.shape), dtype),
                         grads_like)
    agg = (jax.tree.map(lambda a: jnp.zeros(a.shape, dtype), grads_like)
           if feedback == "ef21" else z)
    return FeedbackState(resid=resid, mirror=z, agg=agg, scope="dp",
                         direction="grad", mode=feedback)


def _ring_gather(payload_tree, axis: str, dp: int):
    """All-gather via a ``ppermute`` ring: ``dp - 1`` hops, banking the
    in-flight payload by SOURCE rank.  Returns the payload pytree with a
    leading ``(dp,)`` dim ordered by source rank (identical on every
    replica up to its own shard's position — the decode sums in rank
    order, so the reduction is association-fixed)."""
    r = jax.lax.axis_index(axis)
    slots = jax.tree.map(
        lambda a: jnp.zeros((dp, *a.shape), a.dtype).at[r].set(a),
        payload_tree)
    if dp == 1:
        return slots
    perm = [(i, (i + 1) % dp) for i in range(dp)]
    inflight = payload_tree
    for h in range(1, dp):
        inflight = jax.lax.ppermute(inflight, axis, perm)
        src = (r - h) % dp
        slots = jax.tree.map(lambda sl, a: sl.at[src].set(a), slots,
                             inflight)
    return slots


def make_grad_all_reduce(mesh: Mesh, axis: str, codec: str = "none", *,
                         k_frac: float = 0.1, feedback: str = "none",
                         average: bool = False, fused: bool = True,
                         shard_axis: str = None, tp_axis: str = None,
                         tp_dims=None):
    """Build ``reduce(grads_dp, dp_state) -> (reduced, new_dp_state)``.

    ``grads_dp``: a gradient pytree whose leaves carry a leading replica
    dim ``(dp, *leaf)`` (e.g. the gradient w.r.t. dp-stacked pipeline
    params, or a ``vmap``-batched per-replica gradient).  The reduced
    gradient comes back replica-free and REPLICATED — every replica decodes
    the same payloads and sums them in the same order.

    ``average=True`` scales each replica's contribution by ``1/dp`` before
    compression (per-replica mean losses); default is a plain sum
    (per-replica losses already carry the global denominator).

    ``fused=False`` rings the raw per-leaf payload pytree instead of one
    fused buffer — same bytes, one collective launch PER PAYLOAD LEAF per
    hop; exists so the benchmark can audit the fusion claim.

    ``shard_axis``: on a 2D ``(data, stages)`` mesh, additionally shard
    the reduce over this axis — a leaf whose post-replica leading dim
    divides the axis (the stage-stacked layer gradients) rings only its
    own slice within its stage column, cutting per-device wire bytes by
    the stage count and avoiding the all-gather a stage-replicated spec
    would force on the (stage-sharded) pipeline gradient.  Non-divisible
    leaves degrade to stage-replicated.  Per-tensor scales then cover the
    per-stage slice (strictly finer, never coarser).

    ``tp_axis``/``tp_dims``: on a mesh with a tensor axis, a leaf whose
    ``tp_dims`` entry is >= 1 is tensor-SHARDED at that absolute dim
    (index into the ``(dp, *leaf)`` array; -1 = replicated over tensor —
    models/transformer.tp_param_dims shifted by the replica dim).  Each
    tensor coordinate then rings only its own weight shard over ``data``
    — the three rings never mix, and per-device DP wire bytes drop by
    ``tp`` for the sharded leaves.
    """
    if (tp_axis is None) != (tp_dims is None):
        raise ValueError("tp_axis and tp_dims come together (see "
                         "models/transformer.tp_param_dims)")
    if feedback not in DP_FEEDBACK_MODES:
        raise ValueError(f"unknown dp feedback {feedback!r}; "
                         f"known: {DP_FEEDBACK_MODES}")
    if feedback != "none" and codec == "none":
        raise ValueError("dp_feedback compensates a LOSSY dp_codec; "
                         "with dp_codec='none' there is nothing to "
                         "compensate — drop dp_feedback")
    codec_obj = get_codec(codec)
    dp = mesh.shape[axis]
    s_shard = mesh.shape[shard_axis] if shard_axis is not None else 1

    def _sharded(shape, lead: int) -> bool:
        """Does this leaf take the extra ``shard_axis`` dim after its
        ``lead`` replica dims?"""
        return (shard_axis is not None and len(shape) > lead
                and shape[lead] > 0 and shape[lead] % s_shard == 0)

    def body(g_dp, resid, agg):
        gl = [a[0] for a in jax.tree.leaves(g_dp)]
        gdef = jax.tree.structure(g_dp)
        if feedback != "none":
            rl = [a[0] for a in jax.tree.leaves(resid)]
        else:
            rl = [None] * len(gl)
        if feedback == "ef21":
            al = jax.tree.leaves(agg)
        else:
            al = [None] * len(gl)

        # -- compensate + pack (per leaf, f32 for lossy codecs) -------------
        xs, payloads = [], []
        for a, e in zip(gl, rl):
            if codec_obj.name == "none":
                x = (a / dp).astype(a.dtype) if average else a
            else:
                x = a.astype(jnp.float32)
                if average:
                    x = x / dp
                if feedback == "ef":
                    x = x + e
                elif feedback == "ef21":
                    x = x - e                     # resid holds w_r
            xs.append(x)
            payloads.append(pack_grad_leaf(codec_obj, x, k_frac))

        # -- exchange: one fused buffer (or the raw payload pytree) ---------
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payloads)
        if fused:
            slots = _ring_gather(fuse_payload(payloads), axis, dp)
            slot = lambda s: unfuse_payload(slots[s], struct)
        else:
            slots = _ring_gather(payloads, axis, dp)
            slot = lambda s: jax.tree.map(lambda a: a[s], slots)

        # -- decode + sum in source-rank order ------------------------------
        # On the Pallas backend the whole receive side (unfuse -> dequant ->
        # rank-ordered accumulate) fuses into ONE kernel per hop
        # (kernels/dp_reduce.py) when every leaf rides the per-tensor
        # q8/q4 wire format.  The fold is static and source-rank ordered
        # and every replica runs the identical program, so the reduced
        # gradient stays bitwise identical across replicas — same
        # association as the reference loop below (the per-element dequant
        # may round 1 ulp tighter where the compiler emits an FMA).
        plans = None
        if fused and codec_obj.name in ("q8", "q4") and _use_pallas_wire():
            from repro.kernels.dp_reduce import (build_decode_plans,
                                                 decode_fits,
                                                 decode_sum_fused)
            plans = build_decode_plans(struct, [g.shape for g in gl])
            if plans is not None and not decode_fits(plans, dp):
                plans = None
        if plans is not None:
            dense = decode_sum_fused(slots, plans, dp)
            acc = [d.reshape(g.shape) for d, g in zip(dense, gl)]
        else:
            acc = [None] * len(gl)
            for s in range(dp):
                pls = slot(s)
                for i, g in enumerate(gl):
                    m = unpack_grad_leaf(codec_obj, pls[i], g.shape)
                    acc[i] = m if acc[i] is None else acc[i] + m

        # -- feedback state updates (own decode == own slot, same bits) ----
        new_rl, new_al, out = [], [], []
        for i, g in enumerate(gl):
            if feedback == "none":
                out.append(acc[i].astype(g.dtype))
                continue
            m_own = unpack_grad_leaf(codec_obj, payloads[i], g.shape)
            if feedback == "ef":
                new_rl.append((xs[i] - m_own)[None])
                out.append(acc[i].astype(g.dtype))
            else:                                 # ef21
                reduced = al[i] + acc[i]          # G + sum_r C(g_r - w_r)
                new_rl.append((rl[i] + m_own)[None])
                new_al.append(reduced)
                out.append(reduced.astype(g.dtype))
        reduced_tree = jax.tree.unflatten(gdef, out)
        if feedback == "none":
            new_resid = jax.tree.map(lambda a: a, resid)
            new_agg = jax.tree.map(lambda a: a, agg)
        else:
            new_resid = jax.tree.unflatten(jax.tree.structure(resid),
                                           new_rl)
            new_agg = (jax.tree.unflatten(jax.tree.structure(agg), new_al)
                       if feedback == "ef21"
                       else jax.tree.map(lambda a: a, agg))
        return reduced_tree, new_resid, new_agg

    def _trace_wire(grads_dp) -> None:
        """Emit the DP-ring wire facts when tracing is on.  Runs at TRACE
        time (the ``reduce`` body executes once per jit compilation), so
        the steady-state step pays nothing and no device ops are added."""
        from repro.obs import trace
        tr = trace.get_tracer()
        if tr is None:
            return
        g_like = [jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
                  for a in jax.tree.leaves(grads_dp)]
        rep = dp_wire_report(g_like, codec, k_frac=k_frac, dp=dp)
        tr.instant("dp.wire", cat="wire", axis=axis, feedback=feedback,
                   fused=fused, shard_axis=shard_axis or "",
                   launches_per_hop=(1 if fused
                                     else rep["n_payload_leaves"]),
                   **rep)

    def reduce(grads_dp, dp_state: FeedbackState):
        _trace_wire(grads_dp)

        def dp_spec(a, d=-1):
            e = [axis] + [None] * (a.ndim - 1)
            if _sharded(a.shape, 1):
                e[1] = shard_axis
            if tp_axis is not None and d >= 1:
                e[d] = tp_axis
            return P(*e)

        def out_spec(a, d=-1):
            e = [None] * max(a.ndim - 1, 0)
            if _sharded(a.shape, 1):
                e[0] = shard_axis
            if tp_axis is not None and d >= 1:
                e[d - 1] = tp_axis
            return P(*e)

        if tp_axis is None:
            gspec = jax.tree.map(dp_spec, grads_dp)
            ospec = jax.tree.map(out_spec, grads_dp)
        else:
            gspec = jax.tree.map(dp_spec, grads_dp, tp_dims)
            ospec = jax.tree.map(out_spec, grads_dp, tp_dims)
        if tp_axis is None or feedback == "none":
            rspec = jax.tree.map(dp_spec, dp_state.resid)
        else:
            # resid mirrors the grad tree leaf-for-leaf
            rspec = jax.tree.map(dp_spec, dp_state.resid, tp_dims)
        if tp_axis is None or feedback != "ef21":
            aspec = jax.tree.map(
                lambda a: P(shard_axis) if _sharded(a.shape, 0) else P(),
                dp_state.agg)
        else:
            aspec = jax.tree.map(
                lambda a, d: P(*[(shard_axis if (i == 0 and
                                                 _sharded(a.shape, 0))
                                  else (tp_axis if d >= 1 and i == d - 1
                                        else None))
                                 for i in range(a.ndim)]),
                dp_state.agg, tp_dims)
        reduced, new_resid, new_agg = shard_map_compat(
            body, mesh, (gspec, rspec, aspec),
            (ospec, rspec, aspec),
        )(grads_dp, dp_state.resid, dp_state.agg)
        return reduced, dp_state.replace(resid=new_resid, agg=new_agg)

    return reduce
