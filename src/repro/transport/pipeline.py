"""Real pipeline parallelism with compressed, DIFFERENTIABLE stage handoffs.

The stage boundary is an actual ``jax.lax.ppermute`` over a mesh axis inside
``shard_map`` — microbatched pipelining, each device holding one (or, with
the interleaved schedule, several virtual) stage slices.  The boundary
tensor is PACKED by a wire codec (transport/codecs.py) before the ppermute,
so the collective-permute bytes in the lowered HLO shrink by exactly the
paper's compression ratio.

Training-capable: the packed hop is wrapped in ``jax.custom_vjp`` whose
backward ppermutes a *packed gradient payload* in the REVERSE direction,
compressed by the boundary policy's ``bw`` compressor — the paper's
simultaneous activation + gradient compression, on real wire formats.
With ``reuse_indices`` (paper Table 5) the forward TopK indices ride in the
residuals on both ends of the wire: the backward payload is VALUES ONLY
(gathered with the receiver's indices, scattered with the sender's), saving
the index bytes in the gradient direction.

Scheduling is a first-class, pluggable layer (transport/schedules.py):
``gpipe`` (minimum-tick skew scan, the original semantics), ``1f1b``
(rematerialized ticks + fused single-buffer hops, for
``microbatches >> stages``), and ``interleaved`` (v virtual stage slices
per device, round-robin: the fill bubble shrinks by 1/v while every one of
the ``v*S - 1`` cuts is a compressed wire cut).  The scan body below is
entirely plan-driven — a :class:`~repro.transport.schedules.Schedule` maps
``(tick, device)`` to (virtual chunk, microbatch, validity, inject/emit
points), and the same custom_vjp hop serves every schedule.

Error feedback (paper Sec. 2.4/2.5, Tables 3-4) over the real wire:
per-stage EF / EF21 / EF-mixed / AQ-SGD buffers ride the ``lax.scan`` carry,
sharded ``P(axis)`` so each device owns the buffers of the cuts it sends
across (one per virtual chunk).  What gets packed onto the wire is the
COMPENSATED message:

  * EF        — payload = pack(x + e); the receiver's unpack IS m = C(x+e).
  * EF-mixed  — two half-K payloads, pack(x, K/2) + pack(e, K/2).
  * EF21      — payload = pack(x - g), a compressed delta; the receiver
                reconstructs m = g + unpack(payload) from a local MIRROR of
                the sender's buffer (both start at zero and apply identical
                deltas, so they never diverge — the AQ-SGD system design).
  * AQ-SGD    — per-example EF21: the ``(num_samples, *feat)`` buffer is
                gathered/scattered by the example ids of the microbatch in
                flight, on both the sender and the receiver mirror.

The backward hop symmetrically applies ``bw_feedback`` to the gradient
payload.  Backward-direction buffers are only touched during backprop, so
their updates are delivered AS THE COTANGENT of the ``bw_state`` argument —
the same functional-state trick core/boundary.py uses (take ``grad`` w.r.t.
``bw_state`` in the train step and read the new buffers out of the gradient
pytree).  Buffer rows are per-example, hence disjoint across microbatches:
each scan step contributes exactly one (masked) slice and the cotangent sum
over steps reassembles the full updated buffer.

Gradients retrace exactly the valid pipeline paths (the fill/drain garbage
paths get zero cotangent through the plan's masks; ring hops that carry
garbage — e.g. the wrap-around cut under gpipe — are explicitly ignored by
both directions, while under the interleaved schedule the wrap hop carries
the real chunk-boundary payload).
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.feedback import (FeedbackState, gather_rows, get_mode,
                                 needs_recv_mirror, scatter_rows)
from repro.core.policy import (BoundaryPolicy, quant_policy, topk_policy)
from repro.transport.base import Transport, shard_map_compat as _shard_map
from repro.transport.codecs import codec_for, fuse_payload, unfuse_payload
from repro.transport.schedules import Schedule, as_schedule


SCHEME_POLICIES = {
    "none": lambda k: BoundaryPolicy(),
    "q8": lambda k: quant_policy(8, 8),
    "q4": lambda k: quant_policy(4, 4),
    "topk": lambda k: topk_policy(k),
    "topk_reuse": lambda k: topk_policy(k, reuse_indices=True),
}


def _policy_for_scheme(scheme: str, k_frac: float) -> BoundaryPolicy:
    try:
        return SCHEME_POLICIES[scheme](k_frac)
    except KeyError:
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"known: {sorted(SCHEME_POLICIES)}") from None


def _zeros_f0(x):
    """float0 cotangent for an integer/bool primal (custom_vjp contract)."""
    return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# Feedback state
# ---------------------------------------------------------------------------

def init_feedback_state(policy: BoundaryPolicy, feat_shape, *,
                        num_stages: int, batch: int,
                        microbatches: Optional[int] = None,
                        num_samples: int = 0, dtype=jnp.float32,
                        virtual_stages: int = 1, dp: int = 1):
    """Per-stage feedback buffers for the real pipeline.

    Returns ``{"fw": FeedbackState, "bw": FeedbackState}`` whose ``resid``
    (the sender-side buffer) / ``mirror`` (the receiver-side replica of
    the delta-coded modes) arrays carry leading dim ``num_stages`` (shard
    ``P(axis)``: device d's slice holds the buffers of the cuts it owns —
    cut d for ``resid`` / the mirror of cut d-1; with ``virtual_stages=v``
    a chunk dim follows, slot k being cut ``k*S + d`` / its mirror).

    Global modes (ef/ef21/efmixed) keep ``(S, [v,] mb, B/(mb*dp), *feat)``
    — the simulated ``(B, *feat)`` buffer split by microbatch; AQ-SGD
    keeps ``(S, [v,] num_samples/dp, *feat)``.  Unused buffers are size-0
    placeholders ``(S, 0)`` so the pytree structure is policy-stable.

    ``dp > 1`` (the 2D ``(data, stages)`` mesh) prepends a replica dim —
    shard ``P(data_axis, stage_axis)``: each replica row compensates its
    own contiguous batch shard exactly as a solo run on that shard would,
    and AQ-SGD's dataset-indexed buffer shards BY EXAMPLE ID (replica r
    owns rows ``[r*num_samples/dp, (r+1)*num_samples/dp)``; see
    :func:`repro.core.feedback.shard_ids` for the data-routing contract).
    """
    mb = microbatches or num_stages
    if batch % (mb * dp):
        raise ValueError(f"batch {batch} not divisible by microbatches "
                         f"{mb} x dp {dp}")
    mbsz = batch // (mb * dp)
    v = virtual_stages
    chunk = () if v == 1 else (v,)
    rep = () if dp == 1 else (dp,)

    def buf(mode: str, mirror: bool):
        if mode == "none" or (mirror and not needs_recv_mirror(mode)):
            return jnp.zeros((*rep, num_stages, 0), dtype)
        if get_mode(mode).per_example:
            assert num_samples > 0, "aqsgd needs the dataset size"
            if num_samples % dp:
                raise ValueError(
                    f"aqsgd + dp shards the per-example buffer by id: "
                    f"num_samples {num_samples} must be divisible by "
                    f"dp {dp}")
            return jnp.zeros(
                (*rep, num_stages, *chunk, num_samples // dp, *feat_shape),
                dtype)
        return jnp.zeros((*rep, num_stages, *chunk, mb, mbsz, *feat_shape),
                         dtype)

    def fbs(mode: str, direction: str) -> FeedbackState:
        return FeedbackState(
            resid=buf(mode, False), mirror=buf(mode, True),
            agg=jnp.zeros((0,), dtype), scope="boundary",
            direction=direction, mode=mode)

    return {"fw": fbs(policy.feedback, "fw"),
            "bw": fbs(policy.bw_feedback, "bw")}


def _empty_state(num_stages: int, dtype, direction: str,
                 dp: int = 1) -> FeedbackState:
    rep = () if dp == 1 else (dp,)
    z = jnp.zeros((*rep, num_stages, 0), dtype)
    return FeedbackState(resid=z, mirror=z, agg=jnp.zeros((0,), dtype),
                         scope="boundary", direction=direction, mode="none")


class PipelineTransport(Transport):
    """The real wire at one stage cut: packed ``ppermute`` both directions.

    ``fw``/``bw`` are SPMD collectives — they must run inside a
    ``shard_map`` over ``axis``.  :func:`pipeline_apply` composes them into
    a ``custom_vjp`` so the backward hop runs during backprop, with
    feedback buffers threaded through the scan carry (fw) and through
    cotangents (bw).

    ``fused=True`` (the 1f1b/interleaved default) bitcasts each hop's
    payload pytree into ONE contiguous uint8 buffer before the ppermute —
    byte-identical on the wire, one collective launch per direction per
    tick instead of one per payload leaf.
    """

    def __init__(self, policy: BoundaryPolicy, axis: str, num_stages: int,
                 *, virtual_stages: int = 1, fused: bool = False):
        if policy.reuse_indices and (policy.feedback != "none"
                                     or policy.bw_feedback != "none"):
            raise NotImplementedError(
                f"reuse_indices=True conflicts with feedback="
                f"{policy.feedback!r} / bw_feedback={policy.bw_feedback!r} "
                "on the real pipeline: the backward payload is values-only, "
                "gathered at the forward TopK indices — but a compensated "
                "message C(x + e) keeps different coordinates than C(x), "
                "so those indices no longer address the wire message. "
                "Valid configurations: (a) reuse_indices=True with "
                "feedback='none' and bw_feedback='none' (paper Table 5), "
                "or (b) feedback/bw_feedback modes with "
                "reuse_indices=False (paper Tables 3-4).")
        for mode, comp, nm in ((policy.feedback, policy.fw, "fw"),
                               (policy.bw_feedback, policy.bw, "bw")):
            if mode == "efmixed" and comp.kind != "topk":
                raise ValueError(f"EF-mixed needs a TopK {nm} compressor")
        self.policy = policy
        self.axis = axis
        self.num_stages = num_stages
        self.virtual_stages = virtual_stages
        self.fused = fused
        self._fw_codec = codec_for(policy.fw)
        self._bw_codec = codec_for(policy.bw)
        self.perm_fw = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        self.perm_bw = [(i, (i - 1) % num_stages) for i in range(num_stages)]

    def _hop(self, payload, perm):
        """One ring hop of a packed payload: plain per-leaf ppermute, or a
        single fused byte-buffer launch."""
        if not self.fused:
            return jax.lax.ppermute(payload, self.axis, perm)
        struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), payload)
        moved = jax.lax.ppermute(fuse_payload(payload), self.axis, perm)
        return unfuse_payload(moved, struct)

    # -- wire framing (shared with benchmarks: eval_shape-able) -------------

    def pack_fw_message(self, y, buf_slice):
        """Compensated forward payload + the local decode m (what the
        receiver will see) + the new send-buffer slice."""
        p, kf = self.policy, self.policy.fw.k_frac
        pack = self._fw_codec.pack
        unpack = lambda pl: self._fw_codec.unpack(pl, y.shape, y.dtype)
        if p.feedback == "none":
            payload = pack(y, kf)
            return payload, None, buf_slice
        if p.feedback == "ef":
            xe = y + buf_slice.astype(y.dtype)
            payload = pack(xe, kf)
            m = unpack(payload)
            return payload, m, xe - m
        if p.feedback == "efmixed":
            e = buf_slice.astype(y.dtype)
            payload = {"x": pack(y, kf / 2.0), "e": pack(e, kf / 2.0)}
            m = (self._fw_codec.unpack(payload["x"], y.shape, y.dtype)
                 + self._fw_codec.unpack(payload["e"], y.shape, y.dtype))
            return payload, m, (y + e) - m
        # delta-coded: ef21 / aqsgd — wire carries C(x - buf) only
        b = buf_slice.astype(y.dtype)
        payload = pack(y - b, kf)
        return payload, None, b + unpack(payload)

    def unpack_fw_message(self, moved, shape, dtype, recv_slice):
        """Receiver-side decode of :meth:`pack_fw_message`'s payload.
        Returns (message, new recv-mirror slice or None)."""
        p = self.policy
        if p.feedback in ("none", "ef"):
            return self._fw_codec.unpack(moved, shape, dtype), None
        if p.feedback == "efmixed":
            return (self._fw_codec.unpack(moved["x"], shape, dtype)
                    + self._fw_codec.unpack(moved["e"], shape, dtype)), None
        m = recv_slice.astype(dtype) + self._fw_codec.unpack(moved, shape,
                                                             dtype)
        return m, m

    def pack_bw_message(self, g, buf_slice):
        """Compensated gradient payload + new bw send-buffer slice."""
        p, kb = self.policy, self.policy.bw.k_frac
        pack = self._bw_codec.pack
        unpack = lambda pl: self._bw_codec.unpack(pl, g.shape, g.dtype)
        if p.bw_feedback == "none":
            return pack(g, kb), buf_slice
        if p.bw_feedback == "ef":
            ge = g + buf_slice.astype(g.dtype)
            payload = pack(ge, kb)
            return payload, ge - unpack(payload)
        if p.bw_feedback == "efmixed":
            e = buf_slice.astype(g.dtype)
            payload = {"g": pack(g, kb / 2.0), "e": pack(e, kb / 2.0)}
            m = (self._bw_codec.unpack(payload["g"], g.shape, g.dtype)
                 + self._bw_codec.unpack(payload["e"], g.shape, g.dtype))
            return payload, (g + e) - m
        b = buf_slice.astype(g.dtype)                       # ef21
        payload = pack(g - b, kb)
        return payload, b + unpack(payload)

    def unpack_bw_message(self, moved, shape, dtype, recv_slice):
        p = self.policy
        if p.bw_feedback in ("none", "ef"):
            return self._bw_codec.unpack(moved, shape, dtype), None
        if p.bw_feedback == "efmixed":
            return (self._bw_codec.unpack(moved["g"], shape, dtype)
                    + self._bw_codec.unpack(moved["e"], shape, dtype)), None
        m = recv_slice.astype(dtype) + self._bw_codec.unpack(moved, shape,
                                                             dtype)
        return m, m

    def fw_payload_struct(self, x_struct, buf_struct=None):
        """eval_shape of the forward wire payload (feedback framing incl.)
        — the benchmark's exact bytes-on-wire source."""
        buf = buf_struct or jax.ShapeDtypeStruct(x_struct.shape,
                                                 x_struct.dtype)
        return jax.eval_shape(lambda y, b: self.pack_fw_message(y, b)[0],
                              x_struct, buf)

    def bw_payload_struct(self, g_struct, buf_struct=None):
        buf = buf_struct or jax.ShapeDtypeStruct(g_struct.shape,
                                                 g_struct.dtype)
        return jax.eval_shape(lambda g, b: self.pack_bw_message(g, b)[0],
                              g_struct, buf)

    # -- SPMD hops ----------------------------------------------------------

    def fw(self, x, fw_buf=None, ids=None):
        """Plain (feedback-free) hop: pack x, ppermute to the next stage,
        unpack.  ``ctx`` carries the (sent, received) TopK indices when
        ``reuse_indices`` is set."""
        payload = self._fw_codec.pack(x, self.policy.fw.k_frac)
        moved = self._hop(payload, self.perm_fw)
        out = self._fw_codec.unpack(moved, x.shape, x.dtype)
        ctx = None
        if self.policy.reuse_indices:
            ctx = (payload["idx"], moved["idx"])
        return out, fw_buf, ctx

    def fw_hop(self, y, fw_st, meta):
        """Feedback-compensated forward hop inside the pipeline scan.

        ``fw_st``: this device's local {"resid","mirror"} buffers (one
        :class:`~repro.core.feedback.FeedbackState` slice); ``meta``: the
        tick's bookkeeping pytree — clipped microbatch indices
        (``jc_s``/``jc_r``: send / receive side), virtual chunk indices
        (``ks``/``kr``), AQ-SGD example ids (``ids_s``/``ids_r``) and
        validity masks (``vs``/``vr``) from the schedule's plan.
        """
        mode = self.policy.feedback
        if mode == "none":
            out, _, ctx = self.fw(y)
            return out, fw_st, ctx
        v = self.virtual_stages
        send_sl = gather_rows(fw_st["resid"], meta["ks"], meta["jc_s"],
                              meta["ids_s"], mode, v)
        payload, _, new_send = self.pack_fw_message(y, send_sl)
        moved = self._hop(payload, self.perm_fw)
        recv_sl = (gather_rows(fw_st["mirror"], meta["kr"], meta["jc_r"],
                               meta["ids_r"], mode, v)
                   if needs_recv_mirror(mode) else None)
        out, new_recv = self.unpack_fw_message(moved, y.shape, y.dtype,
                                               recv_sl)
        new_st = {
            "resid": scatter_rows(fw_st["resid"], meta["ks"], meta["jc_s"],
                                  meta["ids_s"], mode, v,
                                  new_send, send_sl, meta["vs"]),
            "mirror": (fw_st["mirror"] if new_recv is None else
                       scatter_rows(fw_st["mirror"], meta["kr"],
                                    meta["jc_r"], meta["ids_r"], mode, v,
                                    new_recv, recv_sl, meta["vr"])),
        }
        return out, new_st, None

    def bw(self, g, bw_buf=None, ctx=None):
        """Plain backward hop: pack the activation-gradient, ppermute to
        the PREVIOUS stage, unpack.  With ``reuse_indices`` the payload is
        values only."""
        if self.policy.reuse_indices:
            idx_sent, idx_recv = ctx
            b = g.shape[0]
            gflat = g.reshape(b, -1)
            vals = jnp.take_along_axis(
                gflat, idx_recv.astype(jnp.int32), axis=-1
            ).astype(jnp.bfloat16)
            vals_back = jax.lax.ppermute(vals, self.axis, self.perm_bw)
            from repro.core.compressors import topk_scatter
            g_out = topk_scatter(vals_back.astype(jnp.float32),
                                 idx_sent.astype(jnp.int32), g.shape,
                                 jnp.float32).astype(g.dtype)
            return g_out, bw_buf
        payload = self._bw_codec.pack(g, self.policy.bw.k_frac)
        moved = self._hop(payload, self.perm_bw)
        return self._bw_codec.unpack(moved, g.shape, g.dtype), bw_buf

    def bw_hop(self, g, bw_send_sl, bw_recv_sl, meta, ctx):
        """Feedback-compensated backward hop (runs inside ``send``'s VJP).

        Device d sends the gradient of its RECEIVED activation (the cut
        below the chunk it computes NEXT tick — slot ``[kr, jc_r]``,
        buffer slice ``bw_send_sl``) and receives the gradient of its SENT
        activation (cut ``[ks, jc_s]``, mirror slice ``bw_recv_sl``).
        Returns ``(g_y, new_send_sl, new_recv_sl)`` where the slice
        updates are masked cotangent CONTRIBUTIONS (zero on invalid steps
        — the per-step sum reassembles the buffer).
        """
        mode = self.policy.bw_feedback
        if mode == "none" or self.policy.reuse_indices:
            g_y, _ = self.bw(g, ctx=ctx)
            new_send = jnp.zeros_like(bw_send_sl)
            new_recv = jnp.zeros_like(bw_recv_sl)
        else:
            payload, new_send = self.pack_bw_message(g, bw_send_sl)
            moved = self._hop(payload, self.perm_bw)
            g_y, new_recv = self.unpack_bw_message(
                moved, g.shape, g.dtype,
                bw_recv_sl if needs_recv_mirror(mode) else None)
            new_send = jnp.where(meta["vr"], new_send,
                                 0.0).astype(bw_send_sl.dtype)
            new_recv = (jnp.zeros_like(bw_recv_sl) if new_recv is None else
                        jnp.where(meta["vs"], new_recv, 0.0).astype(
                            bw_recv_sl.dtype))
        # Without feedback a garbage-path payload is C(0) = 0 and dies on
        # its own; a COMPENSATED message is C(0 + e) != 0 — the buffer
        # leaks onto fill/drain paths and garbage ring hops.  Mask the
        # received gradient by this tick's own validity (``vs``: the
        # microbatch whose gradient lands here) and by not being the LAST
        # LOGICAL STAGE (whose real cotangent comes from the loss through
        # ``outs``, never from the ring).
        g_y = jnp.where(meta["vs"] & ~meta["last"], g_y, jnp.zeros_like(g_y))
        return g_y, new_send, new_recv

    def make_send(self, fw_template=None) -> Callable:
        """``send(y, fw_st, bw_send_sl, bw_recv_sl, meta)``: the
        differentiable wire hop — fw hop in the primal (returning the
        updated fw buffers for the scan carry), bw hop on the cotangent
        (returning the bw buffer updates as the cotangents of the
        ``bw_*_sl`` slice arguments).

        ``fw_template``: ShapeDtypeStructs of the local fw state (for zero
        cotangents) — default size-0 (no feedback).  ``meta`` is the
        integer/bool bookkeeping pytree from the schedule plan; its
        cotangents are float0.
        """
        transport = self
        fw_template = fw_template or {
            "resid": jax.ShapeDtypeStruct((0,), jnp.float32),
            "mirror": jax.ShapeDtypeStruct((0,), jnp.float32)}

        @jax.custom_vjp
        def send(y, fw_st, bw_send_sl, bw_recv_sl, meta):
            out, new_fw, _ = transport.fw_hop(y, fw_st, meta)
            return out, new_fw

        def send_fwd(y, fw_st, bw_send_sl, bw_recv_sl, meta):
            out, new_fw, ctx = transport.fw_hop(y, fw_st, meta)
            # residuals stay O(slice): never the full fw buffers
            return (out, new_fw), (bw_send_sl, bw_recv_sl, ctx, meta)

        def send_bwd(res, cots):
            bw_send_sl, bw_recv_sl, ctx, meta = res
            g, _g_new_fw = cots          # fw buffers are forward-only state
            g_y, new_bw_send, new_bw_recv = transport.bw_hop(
                g, bw_send_sl, bw_recv_sl, meta, ctx)
            zero_fw = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   fw_template)
            return (g_y, zero_fw, new_bw_send, new_bw_recv,
                    jax.tree.map(_zeros_f0, meta))

        send.defvjp(send_fwd, send_bwd)
        return send


# ---------------------------------------------------------------------------
# Differentiable pipelined apply over a mesh axis
# ---------------------------------------------------------------------------

def wire_telemetry(transport: "PipelineTransport", sched: Schedule,
                   feat_shape, dtype, *, microbatches: int,
                   dp: int = 1) -> dict:
    """Host-side wire facts of one pipeline configuration: the chosen
    codecs, EXACT payload bytes per hop (``eval_shape`` of the packed
    wire message — the same source benchmarks/pipeline_wire.py audits),
    and collective launches per tick.  Pure trace-time Python: no device
    ops, shared by the tracer instrumentation and the benchmark's
    telemetry-vs-cost-model assertion."""
    from repro.transport.codecs import wire_bytes
    x_s = jax.ShapeDtypeStruct(tuple(feat_shape), dtype)
    fw_pl = transport.fw_payload_struct(x_s)
    if transport.policy.reuse_indices:
        # backward hop ppermutes VALUES ONLY (bf16, forward k) — the
        # reused indices already sit at both ends of the wire
        n = int(np.prod(feat_shape[1:]))
        k = max(1, int(round(transport.policy.fw.k_frac * n)))
        bw_pl = jax.ShapeDtypeStruct((feat_shape[0], k), jnp.bfloat16)
    else:
        bw_pl = transport.bw_payload_struct(x_s)
    s = transport.num_stages
    return {
        "axis": transport.axis, "stages": s,
        "virtual_stages": transport.virtual_stages,
        "schedule": sched.name, "microbatches": microbatches, "dp": dp,
        "fw_codec": transport.policy.fw.name,
        "bw_codec": transport.policy.bw.name,
        "feedback": transport.policy.feedback,
        "fw_payload_bytes_per_hop": wire_bytes(fw_pl),
        "bw_payload_bytes_per_hop": wire_bytes(bw_pl),
        "launches_per_fw_hop": (1 if transport.fused
                                else len(jax.tree.leaves(fw_pl))),
        "launches_per_bw_hop": (1 if transport.fused
                                else len(jax.tree.leaves(bw_pl))),
        "wire_cuts": sched.wire_cuts(s),
    }


def _trace_wire(transport, sched, feat_shape, dtype, mb, dp) -> None:
    """Emit the wire-telemetry event when tracing is on.  Runs at TRACE
    time (once per compilation), so the steady-state step pays nothing."""
    from repro.obs import trace
    tr = trace.get_tracer()
    if tr is None:
        return
    tr.instant("pipeline.wire", cat="wire",
               **wire_telemetry(transport, sched, feat_shape, dtype,
                                microbatches=mb, dp=dp))


def pipeline_apply(stage_fn: Callable, params_stacked, x, mesh: Mesh,
                   axis: str, *, policy: Optional[BoundaryPolicy] = None,
                   scheme: Optional[str] = None, k_frac: float = 0.1,
                   microbatches: Optional[int] = None,
                   schedule: Union[str, Schedule] = "gpipe",
                   virtual_stages: Optional[int] = None,
                   fw_state=None, bw_state=None, ids=None,
                   dp_axis: Optional[str] = None,
                   tp_axis: Optional[str] = None, tp_param_dims=None,
                   seq_dim: int = 1):
    """Run ``stage_fn(stage_params, x) -> x`` as a pipelined stage stack
    over mesh axis ``axis``, ppermute-ing PACKED payloads between stages —
    differentiable end to end (compressed gradient payloads hop backward).

    params_stacked: pytree with leading dim ``S * v`` in LOGICAL stage
    order (one slice per stage; ``v = virtual_stages``, 1 unless the
    schedule is interleaved).  Logical stage ``l`` runs on device
    ``l % S`` (round-robin), so with ``v == 1`` slice ``s`` simply lives
    on device ``s``.  x: (B, ...) global batch.  ``policy`` (a
    :class:`BoundaryPolicy`) or ``scheme`` (a codec name) selects the wire
    format; every cut uses the same policy (SPMD: one program).

    ``schedule`` picks the pipeline schedule (``"gpipe"`` | ``"1f1b"`` |
    ``"interleaved"``, or a :class:`~repro.transport.schedules.Schedule`
    instance); ``microbatches`` defaults to the stage count and must be
    positive when given (the interleaved schedule additionally requires it
    to be a multiple of S).

    ``dp_axis``: run ``dp = mesh.shape[dp_axis]`` data-parallel replicas of
    the pipeline on a 2D ``(data, stages)`` mesh.  ``params_stacked`` then
    carries a LEADING replica dim ``(dp, S * v, ...)`` — one (usually
    broadcast) copy per replica, so its gradient comes back PER REPLICA
    with no hidden cross-replica ``psum``; the caller reduces it explicitly
    (transport/collectives.py, the compressed DP gradient all-reduce).
    The global batch splits into ``dp`` contiguous shards (replica r takes
    ``x[r*B/dp:(r+1)*B/dp]``), each pipelined with ``microbatches``
    microbatches exactly as a solo run on that shard would be.

    ``tp_axis``: run every stage tensor-parallel over a third mesh axis
    (the 3D ``(data, stage, tensor)`` mesh).  ``stage_fn`` must then be
    TP-aware (models/transformer.tp_stage_stack_fn closed over a
    :class:`~repro.transport.tp_collectives.TPCollectives` on the same
    axis): it receives the SEQUENCE-SHARDED microbatch (dim ``seq_dim``
    of the per-microbatch activation split over ``tp_axis``) plus the
    tp-local weight shards (``tp_param_dims``: pytree matching
    ``params_stacked`` of per-leaf sharded-dim indices, -1 = replicated
    — models/transformer.tp_param_dims).  The stage-boundary payload is
    then the shard, so the three rings stay separable: stage hops move
    ``1/tp`` of each cut, TP gathers ring within a stage, and the DP
    reduce rings over ``data``.  Boundary feedback buffers are not
    supported on this path (their addressing assumes full-sequence
    slots); pass a buffer-free policy.

    Feedback state: when the policy carries EF/EF21/EF-mixed/AQ-SGD
    buffers, pass ``fw_state``/``bw_state`` from
    :func:`init_feedback_state` (built with the same ``virtual_stages``,
    and ``ids``: (B,) example ids for AQ-SGD).  The return value becomes
    ``(out, new_fw_state)`` and the updated backward buffers arrive as the
    COTANGENT of ``bw_state`` (take ``grad`` w.r.t. it — see
    train/steps.py).  Passing size-0 state with ``feedback='none'`` is
    allowed (it rides the carry untouched), so the calling convention can
    be policy-independent.
    """
    if policy is None:
        policy = _policy_for_scheme(scheme or "none", k_frac)
    s_stages = mesh.shape[axis]
    dp = mesh.shape[dp_axis] if dp_axis is not None else 1
    tp = mesh.shape[tp_axis] if tp_axis is not None else 1
    if tp_axis is not None:
        if policy.needs_fw_buffer or policy.needs_bw_buffer:
            raise ValueError(
                f"policy {policy.name!r} carries boundary feedback "
                "buffers; the tensor-parallel pipeline path supports "
                "buffer-free boundary policies only")
        if tp_param_dims is None:
            raise ValueError("tp_axis needs tp_param_dims (see "
                             "models/transformer.tp_param_dims)")
        if x.shape[seq_dim] % tp:
            raise ValueError(f"sequence dim {seq_dim} ({x.shape[seq_dim]})"
                             f" not divisible by tp={tp}")
    sched = as_schedule(schedule, virtual_stages)
    v = sched.virtual_stages
    transport = PipelineTransport(policy, axis, s_stages,
                                  virtual_stages=v, fused=sched.fused_wire)

    if microbatches is None:
        mb = s_stages
    else:
        if not isinstance(microbatches, (int, np.integer)) \
                or microbatches <= 0:
            raise ValueError(
                "microbatches must be a positive int, got "
                f"{microbatches!r} — pass None (or omit it) to default to "
                "the stage count")
        mb = int(microbatches)
    sched.validate(mb, s_stages)
    b = x.shape[0]
    if b % (mb * dp):
        raise ValueError(f"batch {b} is not divisible by microbatch count "
                         f"{mb} x dp {dp} (microbatches defaults to the "
                         "stage count)")
    mbsz = b // (mb * dp)

    lead = {a.shape[0] for a in jax.tree.leaves(params_stacked)}
    slice_dim = 1 if dp_axis is not None else 0
    want_lead = dp if dp_axis is not None else s_stages * v
    slices = ({a.shape[1] for a in jax.tree.leaves(params_stacked)}
              if dp_axis is not None else lead)
    if lead != {want_lead} or slices != {s_stages * v}:
        got = (f"got leading dims {sorted(lead)}" if dp_axis is None else
               f"got replica dims {sorted(lead)} (want {dp}) x slice dims "
               f"{sorted(slices)}")
        raise ValueError(
            "params_stacked must have leading dim"
            f"{(' (dp=' + str(dp) + ',') if dp_axis else ''} num_stages * "
            f"virtual_stages = {s_stages}*{v} = {s_stages * v}"
            f"{')' if dp_axis else ''} (logical stage slices); {got}")
    if v > 1:
        # logical order -> device-major order: device d's contiguous block
        # (rows d*v .. d*v+v-1 under the P(axis) shard) holds its chunks
        # k = 0..v-1, i.e. logical stages d, d+S, ..., d+(v-1)S.
        order = np.array([k * s_stages + d
                          for d in range(s_stages) for k in range(v)])
        params_dev = jax.tree.map(
            lambda a: jnp.take(a, order, axis=slice_dim), params_stacked)
    else:
        params_dev = params_stacked

    with_state = fw_state is not None or bw_state is not None
    if (policy.needs_fw_buffer or policy.needs_bw_buffer) and not with_state:
        raise ValueError(
            f"policy {policy.name!r} carries feedback buffers: pass "
            "fw_state/bw_state from init_feedback_state()")
    state_dp = dp if dp_axis is not None else 1
    if fw_state is None:
        fw_state = _empty_state(s_stages, x.dtype, "fw", dp=state_dp)
    if bw_state is None:
        bw_state = _empty_state(s_stages, x.dtype, "bw", dp=state_dp)
    for st, nm in ((fw_state, "fw_state"), (bw_state, "bw_state")):
        if st.resid.size and st.resid.shape[0] != \
                (state_dp if dp_axis is not None else s_stages):
            raise ValueError(
                f"{nm} was built for a different mesh: expected leading "
                f"{'(dp, stages)' if dp_axis is not None else '(stages,)'} "
                f"dims {(state_dp, s_stages) if dp_axis is not None else (s_stages,)}, "
                f"got shape {st.resid.shape} — rebuild with "
                f"init_feedback_state(..., dp={state_dp})")
    if ids is None:
        ids = jnp.zeros((b,), jnp.int32)
    rep = (dp,) if dp_axis is not None else ()
    ids_mb = ids.reshape(*rep, mb, mbsz).astype(jnp.int32)

    x_mb = x.reshape(*rep, mb, mbsz, *x.shape[1:])
    feat_shape = x_mb.shape[len(rep) + 1:]
    if tp_axis is not None:
        # the stage boundary carries the sequence SHARD: every ring's
        # payload (and the scan buffer) is 1/tp of the full cut
        local = list(feat_shape)
        local[seq_dim] //= tp
        feat_shape = tuple(local)
    _trace_wire(transport, sched, feat_shape, x.dtype, mb, dp)

    # the scan carry / shard_map threading works on plain {resid, mirror}
    # dicts (the per-direction slices of the FeedbackState; ``agg`` is
    # dp-scope-only and stays outside the pipeline)
    fw_c = {"resid": fw_state.resid, "mirror": fw_state.mirror}
    bw_c = {"resid": bw_state.resid, "mirror": bw_state.mirror}
    strip = 2 if dp_axis is not None else 1
    # AQ-SGD + dp: the (num_samples/dp, *feat) id-shard is addressed with
    # LOCAL rows — each replica row subtracts its shard offset from the
    # global example ids (core.feedback.shard_ids routing contract)
    per_example = (policy.needs_fw_buffer
                   and get_mode(policy.feedback).per_example)
    ns_shard = (fw_state.resid.shape[strip + (1 if v > 1 else 0)]
                if per_example else 0)

    local_fw = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[strip:], a.dtype), fw_c)
    send = transport.make_send(local_fw)
    bw_mode = policy.bw_feedback
    stage = jax.checkpoint(stage_fn) if sched.remat_ticks else stage_fn
    n_steps = sched.num_ticks(mb, s_stages)

    def body(params_local, x_local, fw_st, bw_st, ids_all):
        # params_local: this device's chunk stack (leading dim v);
        # x_local: (mb, ...).  Under dp_axis each carries one extra
        # leading replica dim of size 1 (this device's replica shard).
        if dp_axis is not None:
            params_local = jax.tree.map(lambda a: a[0], params_local)
            x_local = x_local[0]
            ids_all = ids_all[0]
            fw_st = jax.tree.map(lambda a: a[0], fw_st)
            bw_st = jax.tree.map(lambda a: a[0], bw_st)
            if per_example:
                replica = jax.lax.axis_index(dp_axis)
                ids_all = (ids_all
                           - (replica * ns_shard).astype(ids_all.dtype))
        if v == 1:
            params_local = jax.tree.map(lambda a: a[0], params_local)
        fw_st = jax.tree.map(lambda a: a[0], fw_st)
        bw_st = jax.tree.map(lambda a: a[0], bw_st)
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros(feat_shape, x_local.dtype)
        outs = jnp.zeros_like(x_local)

        def step(carry, t):
            buf, outs, fw_st = carry
            pl = sched.plan(t, idx, mb, s_stages)       # compute/send side
            pn = sched.plan(t + 1, idx, mb, s_stages)   # next tick's input
            # logical stage 0 injects from the host batch; everyone else
            # consumes the payload that arrived on the ring last tick
            x_in = jnp.where(pl.inject, x_local[pl.jc], buf)
            p_t = (params_local if v == 1 else
                   jax.tree.map(lambda a: a[pl.k], params_local))
            y = stage(p_t, x_in)
            meta = {"jc_s": pl.jc, "jc_r": pn.jc, "ks": pl.k, "kr": pn.k,
                    "ids_s": ids_all[pl.jc], "ids_r": ids_all[pn.jc],
                    "vs": pl.valid, "vr": pn.valid, "last": pl.last}
            # bw buffer slices gather OUTSIDE send: their cotangents
            # scatter-add the per-step updates back into the full buffers
            bss = (bw_st["resid"] if bw_mode == "none"
                   else gather_rows(bw_st["resid"], pn.k, pn.jc,
                                    meta["ids_r"], bw_mode, v))
            brs = (bw_st["mirror"] if not needs_recv_mirror(bw_mode)
                   else gather_rows(bw_st["mirror"], pl.k, pl.jc,
                                    meta["ids_s"], bw_mode, v))
            buf, fw_st = send(y, fw_st, bss, brs, meta)
            # the LAST LOGICAL STAGE's valid y is a pipeline output
            outs = jnp.where(pl.last & pl.valid, outs.at[pl.jc].set(y), outs)
            return (buf, outs, fw_st), None

        (_, outs, fw_st), _ = jax.lax.scan(
            step, (buf, outs, fw_st), jnp.arange(n_steps))
        # only the LAST device (of each replica row) holds the pipeline
        # output; return it stage-stacked (out_specs P(axis)) so the
        # global slice [-1] is exactly that device's buffer —
        # transposition-unambiguous (the cotangent lands on device S-1
        # alone, no psum involved).
        outs = outs[None] if dp_axis is None else outs[None, None]
        expand = ((lambda a: a[None]) if dp_axis is None
                  else (lambda a: a[None, None]))
        return outs, jax.tree.map(expand, fw_st)

    lead_axes = (axis,) if dp_axis is None else (dp_axis, axis)
    ids_spec = P() if dp_axis is None else P(dp_axis)
    st_axes = P(*lead_axes)
    if tp_axis is None:
        pspec = jax.tree.map(lambda _: st_axes, params_dev)
        x_spec = ids_spec
        out_spec = P(axis) if dp_axis is None else P(axis, dp_axis)
    else:
        def leaf_spec(a, d):
            entries = [None] * a.ndim
            for i, nm in enumerate(lead_axes):
                entries[i] = nm
            if d >= 0:
                entries[d] = tp_axis
            return P(*entries)
        pspec = jax.tree.map(leaf_spec, params_dev, tp_param_dims)
        xe = [None] * x_mb.ndim
        if dp_axis is not None:
            xe[0] = dp_axis
        xe[len(rep) + 1 + seq_dim] = tp_axis
        x_spec = P(*xe)
        oe = [None] * (x_mb.ndim + 1)
        oe[0] = axis
        if dp_axis is not None:
            oe[1] = dp_axis
        oe[2 + len(rep) + seq_dim] = tp_axis
        out_spec = P(*oe)
    st_spec = lambda st: jax.tree.map(lambda _: st_axes, st)
    out, new_fw = _shard_map(
        body, mesh,
        (pspec, x_spec, st_spec(fw_c), st_spec(bw_c), ids_spec),
        (out_spec, st_spec(fw_c)),
    )(params_dev, x_mb, fw_c, bw_c, ids_mb)
    out = out[-1].reshape(b, *x.shape[1:])
    if with_state:
        return out, fw_state.replace(resid=new_fw["resid"],
                                     mirror=new_fw["mirror"])
    return out


def pipeline_forward(stage_fn, params_stacked, x, mesh, axis, *,
                     scheme: str = "none", k_frac: float = 0.1,
                     microbatches: Optional[int] = None,
                     schedule: Union[str, Schedule] = "gpipe",
                     virtual_stages: Optional[int] = None):
    """Original forward-only entry point (now differentiable too): the
    scheme compresses BOTH directions symmetrically."""
    return pipeline_apply(stage_fn, params_stacked, x, mesh, axis,
                          scheme=scheme, k_frac=k_frac,
                          microbatches=microbatches, schedule=schedule,
                          virtual_stages=virtual_stages)
