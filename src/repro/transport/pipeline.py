"""Real pipeline parallelism with compressed, DIFFERENTIABLE stage handoffs.

The stage boundary is an actual ``jax.lax.ppermute`` over a mesh axis inside
``shard_map`` — GPipe-style microbatching, each device holding one stage.
The boundary tensor is PACKED by a wire codec (transport/codecs.py) before
the ppermute, so the collective-permute bytes in the lowered HLO shrink by
exactly the paper's compression ratio.

Training-capable: the packed hop is wrapped in ``jax.custom_vjp`` whose
backward ppermutes a *packed gradient payload* in the REVERSE direction,
compressed by the boundary policy's ``bw`` compressor — the paper's
simultaneous activation + gradient compression, on real wire formats.
With ``reuse_indices`` (paper Table 5) the forward TopK indices ride in the
residuals on both ends of the wire: the backward payload is VALUES ONLY
(gathered with the receiver's indices, scattered with the sender's), saving
the index bytes in the gradient direction.

Scheduling: at step t every device runs its stage; stage 0 injects
microbatch t, others consume the hop buffer; the last stage emits
microbatch t-(S-1).  Gradients retrace exactly the valid pipeline paths
(the fill/drain garbage paths get zero cotangent through the masks).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.policy import (BoundaryPolicy, quant_policy, topk_policy)
from repro.transport.base import Transport
from repro.transport.codecs import codec_for

def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map moved between jax versions; replication checking is
    off either way (payload pytrees confuse it)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


SCHEME_POLICIES = {
    "none": lambda k: BoundaryPolicy(),
    "q8": lambda k: quant_policy(8, 8),
    "q4": lambda k: quant_policy(4, 4),
    "topk": lambda k: topk_policy(k),
    "topk_reuse": lambda k: topk_policy(k, reuse_indices=True),
}


def _policy_for_scheme(scheme: str, k_frac: float) -> BoundaryPolicy:
    try:
        return SCHEME_POLICIES[scheme](k_frac)
    except KeyError:
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"known: {sorted(SCHEME_POLICIES)}") from None


class PipelineTransport(Transport):
    """The real wire at one stage cut: packed ``ppermute`` both directions.

    ``fw``/``bw`` are SPMD collectives — they must run inside a
    ``shard_map`` over ``axis``.  :func:`pipeline_apply` composes them into
    a ``custom_vjp`` so the backward hop runs during backprop.
    """

    def __init__(self, policy: BoundaryPolicy, axis: str, num_stages: int):
        if policy.feedback != "none" or policy.bw_feedback != "none":
            raise NotImplementedError(
                "feedback buffers are not threaded through the real "
                "pipeline yet — use the simulated transport for EF/AQ-SGD")
        self.policy = policy
        self.axis = axis
        self.num_stages = num_stages
        self._fw_codec = codec_for(policy.fw)
        self._bw_codec = codec_for(policy.bw)
        self.perm_fw = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        self.perm_bw = [(i, (i - 1) % num_stages) for i in range(num_stages)]

    def fw(self, x, fw_buf=None, ids=None):
        """Pack x, ppermute to the next stage, unpack.  ``ctx`` carries the
        (sent, received) TopK indices when ``reuse_indices`` is set."""
        payload = self._fw_codec.pack(x, self.policy.fw.k_frac)
        moved = jax.lax.ppermute(payload, self.axis, self.perm_fw)
        out = self._fw_codec.unpack(moved, x.shape, x.dtype)
        ctx = None
        if self.policy.reuse_indices:
            ctx = (payload["idx"], moved["idx"])
        return out, fw_buf, ctx

    def bw(self, g, bw_buf=None, ctx=None):
        """Pack the activation-gradient, ppermute to the PREVIOUS stage,
        unpack.  With ``reuse_indices`` the payload is values only."""
        if self.policy.reuse_indices:
            idx_sent, idx_recv = ctx
            b = g.shape[0]
            gflat = g.reshape(b, -1)
            vals = jnp.take_along_axis(
                gflat, idx_recv.astype(jnp.int32), axis=-1
            ).astype(jnp.bfloat16)
            vals_back = jax.lax.ppermute(vals, self.axis, self.perm_bw)
            from repro.core.compressors import topk_scatter
            g_out = topk_scatter(vals_back.astype(jnp.float32),
                                 idx_sent.astype(jnp.int32), g.shape,
                                 jnp.float32).astype(g.dtype)
            return g_out, bw_buf
        payload = self._bw_codec.pack(g, self.policy.bw.k_frac)
        moved = jax.lax.ppermute(payload, self.axis, self.perm_bw)
        return self._bw_codec.unpack(moved, g.shape, g.dtype), bw_buf

    def make_send(self) -> Callable:
        """``send(y)``: the differentiable wire hop (fw forward, bw on the
        cotangent), for use inside the pipeline body."""
        transport = self

        @jax.custom_vjp
        def send(y):
            out, _, _ = transport.fw(y)
            return out

        def send_fwd(y):
            out, _, ctx = transport.fw(y)
            return out, ctx

        def send_bwd(ctx, g):
            g_out, _ = transport.bw(g, ctx=ctx)
            return (g_out,)

        send.defvjp(send_fwd, send_bwd)
        return send


# ---------------------------------------------------------------------------
# Differentiable pipelined apply over a mesh axis
# ---------------------------------------------------------------------------

def pipeline_apply(stage_fn: Callable, params_stacked, x, mesh: Mesh,
                   axis: str, *, policy: Optional[BoundaryPolicy] = None,
                   scheme: Optional[str] = None, k_frac: float = 0.1,
                   microbatches: Optional[int] = None):
    """Run ``stage_fn(stage_params, x) -> x`` as an S-stage GPipe pipeline
    over mesh axis ``axis``, ppermute-ing PACKED payloads between stages —
    differentiable end to end (compressed gradient payloads hop backward).

    params_stacked: pytree with leading dim S (one slice per stage), sharded
    so stage s lives on axis index s.  x: (B, ...) global batch; microbatch
    count defaults to S (minimum-bubble GPipe).  ``policy`` (a
    :class:`BoundaryPolicy`) or ``scheme`` (a codec name) selects the wire
    format; every cut uses the same policy (SPMD: one program).
    """
    if policy is None:
        policy = _policy_for_scheme(scheme or "none", k_frac)
    s_stages = mesh.shape[axis]
    transport = PipelineTransport(policy, axis, s_stages)
    send = transport.make_send()

    mb = microbatches or s_stages
    b = x.shape[0]
    if b % mb:
        raise ValueError(f"batch {b} is not divisible by microbatch count "
                         f"{mb} (defaults to the stage count)")

    x_mb = x.reshape(mb, b // mb, *x.shape[1:])
    feat_shape = x_mb.shape[1:]

    def body(params_local, x_local):
        # params_local: this stage's slice (leading dim 1); x_local: (mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        n_steps = mb + s_stages - 1
        buf = jnp.zeros(feat_shape, x_local.dtype)
        outs = jnp.zeros_like(x_local)

        def step(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others consume the hop buffer
            inject = jnp.clip(t, 0, mb - 1)
            x_in = jnp.where(idx == 0, x_local[inject], buf)
            y = stage_fn(params_local, x_in)
            buf = send(y)
            # the LAST stage's y at step t is microbatch t - (S-1)
            emit = jnp.clip(t - (s_stages - 1), 0, mb - 1)
            outs = jnp.where(t >= s_stages - 1, outs.at[emit].set(y), outs)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(n_steps))
        # only the LAST stage holds the pipeline output; return it stage-
        # stacked (out_specs P(axis)) so the global slice [-1] is exactly
        # that stage's buffer — transposition-unambiguous (the cotangent
        # lands on stage S-1 alone, no psum involved).
        return outs[None]

    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    out = _shard_map(body, mesh, (pspec, P()), P(axis))(params_stacked, x_mb)
    return out[-1].reshape(b, *x.shape[1:])


def pipeline_forward(stage_fn, params_stacked, x, mesh, axis, *,
                     scheme: str = "none", k_frac: float = 0.1,
                     microbatches: Optional[int] = None):
    """Original forward-only entry point (now differentiable too): the
    scheme compresses BOTH directions symmetrically."""
    return pipeline_apply(stage_fn, params_stacked, x, mesh, axis,
                          scheme=scheme, k_frac=k_frac,
                          microbatches=microbatches)
