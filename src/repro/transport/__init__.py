"""Unified differentiable transport layer for stage-boundary compression.

``Transport.fw(x) / Transport.bw(g)`` is the one interface both boundary
implementations realize; ``codecs`` is the shared wire-format registry.

  codecs      — pack/unpack wire formats + registry (none/q8/q4/topk, ...)
  base        — the Transport interface + wire-cost accounting
  simulated   — single-device convergence-faithful transport (paper Sec. 2.1)
  pipeline    — real shard_map/ppermute pipeline, differentiable (beyond-paper)
  schedules   — pluggable pipeline schedules (gpipe / 1f1b / interleaved)
  collectives — compressed data-parallel gradient all-reduce (2D DPxPP mesh)
"""
from repro.transport.base import Transport, shard_map_compat
from repro.transport.codecs import (WireCodec, codec_for, fuse_payload,
                                    get_codec, pack_payload, register_codec,
                                    registered_codecs, unfuse_payload,
                                    unpack_payload, wire_bytes)
from repro.transport.collectives import (dp_wire_report, init_dp_state,
                                         make_grad_all_reduce)
from repro.transport.pipeline import (PipelineTransport, init_feedback_state,
                                      pipeline_apply, pipeline_forward)
from repro.transport.schedules import (Schedule, SCHEDULES, as_schedule,
                                       get_schedule)
from repro.transport.simulated import SimulatedTransport, simulated_transport

__all__ = [
    "Transport", "WireCodec", "codec_for", "get_codec", "pack_payload",
    "register_codec", "registered_codecs", "unpack_payload", "wire_bytes",
    "fuse_payload", "unfuse_payload", "shard_map_compat",
    "PipelineTransport", "init_feedback_state", "pipeline_apply",
    "pipeline_forward",
    "dp_wire_report", "init_dp_state", "make_grad_all_reduce",
    "Schedule", "SCHEDULES", "as_schedule", "get_schedule",
    "SimulatedTransport", "simulated_transport",
]
