"""Pipeline schedules: who computes which microbatch at which tick.

The compressed pipeline (transport/pipeline.py) runs as ONE ``lax.scan``
inside ``shard_map``: at every tick each device computes one (virtual)
stage slice for one microbatch and the packed boundary payload hops one
ring position.  A :class:`Schedule` owns exactly that bookkeeping — the
per-tick plan (which virtual chunk / microbatch each device computes,
injection/emission points, validity masks for the fill/drain garbage
paths) plus the analytic cost model (bubble fraction, in-flight stash,
wire cuts per microbatch) the benchmarks report.

Three schedules ship:

  * ``gpipe``       — the minimum-tick GPipe skew scan (PR-1 semantics,
                      bit-identical lowering to the pre-schedule code).
  * ``1f1b``        — same cut structure and microbatch order as GPipe
                      (in the scan+autodiff execution model the backward
                      ordering is fixed by scan transposition, so 1F1B's
                      fw math is GPipe's — losses match step-for-step by
                      construction), but with the two mechanics that make
                      ``microbatches >> stages`` practical: the per-tick
                      stage body is rematerialized (``jax.checkpoint``) so
                      the autodiff stash holds only the boundary tensors
                      instead of every stage-internal residual, and each
                      hop's packed payload leaves are FUSED into a single
                      contiguous byte buffer so every steady-state tick
                      costs ONE collective launch per direction instead of
                      one per payload leaf (q8: 3 -> 1; EF-mixed: 6 -> 1).
  * ``interleaved`` — Megatron-style virtual stages: each device holds
                      ``v`` round-robin stage slices (device d owns
                      logical stages d, d+S, ..., d+(v-1)S), every cut is
                      a wire cut, and the fill/drain bubble shrinks from
                      (S-1)/(mb+S-1) to (S-1)/(v*mb+S-1) — while the
                      number of compressed cuts per microbatch grows from
                      S-1 to v*S-1, the regime where the paper's codecs
                      pay for themselves.

The per-tick plan is one closed-form map.  With ``u = t - d`` (the skew
coordinate of device ``d`` at tick ``t``), ``S`` devices and ``v`` virtual
chunks, microbatches advance in groups of ``S``:

    g = u // (S*v)        # microbatch group
    k = (u % (S*v)) // S  # virtual chunk computed this tick
    r = u % S             # position within the group
    j = g*S + r           # microbatch index
    logical stage computed = k*S + d

For ``v == 1`` this degenerates to the GPipe skew ``j = t - d``.  The
invariant that makes one carry buffer suffice for every schedule: the
sender (device d-1, tick t-1) and the receiver (device d, tick t) share
the same ``u``, hence the same ``(k, j)`` — the payload arriving on the
ring is always the input for the CURRENT tick's compute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """Device-local bookkeeping for one scan tick (all fields traced).

    ``k``/``j`` are the virtual chunk / microbatch this device computes
    (``jc`` clipped into range for safe gathers); ``valid`` masks the
    fill/drain garbage paths; ``inject`` marks logical stage 0 (input
    comes from the host batch, not the wire); ``last`` marks the final
    logical stage (output is emitted, and its cotangent comes from the
    loss — never from the ring).
    """
    k: jnp.ndarray
    j: jnp.ndarray
    jc: jnp.ndarray
    valid: jnp.ndarray
    inject: jnp.ndarray
    last: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A pipeline schedule: per-tick plan + analytic cost model.

    ``virtual_stages`` — stage slices per device (v); params carry
    ``S * v`` logical slices.  ``fused_wire`` — pack each hop's payload
    pytree into one contiguous uint8 buffer (one collective launch per
    direction per tick).  ``remat_ticks`` — ``jax.checkpoint`` the
    per-tick stage body so autodiff stashes only boundary tensors.
    """
    name: str = "gpipe"
    virtual_stages: int = 1
    fused_wire: bool = False
    remat_ticks: bool = False

    # -- validation ---------------------------------------------------------

    def validate(self, microbatches: int, num_stages: int) -> None:
        v = self.virtual_stages
        if v < 1:
            raise ValueError(f"virtual_stages must be >= 1, got {v}")
        if v > 1 and microbatches % num_stages:
            raise ValueError(
                "the interleaved schedule advances microbatches in groups "
                f"of the stage count: microbatches={microbatches} must be "
                f"divisible by num_stages={num_stages}")

    # -- per-tick plan ------------------------------------------------------

    def num_ticks(self, microbatches: int, num_stages: int) -> int:
        """Scan length: every (microbatch, logical stage) pair computes
        exactly once, plus the S-1 fill skew."""
        return self.virtual_stages * microbatches + num_stages - 1

    def plan(self, t, d, microbatches: int, num_stages: int) -> TickPlan:
        """The plan for device ``d`` at tick ``t`` (``t``/``d`` traced)."""
        s, v = num_stages, self.virtual_stages
        u = t - d
        if v == 1:
            k = jnp.int32(0)
            j = u
        else:
            sv = s * v
            g = jnp.floor_divide(u, sv)
            w = u - g * sv
            k = jnp.floor_divide(w, s)
            j = g * s + (w - k * s)
        valid = (u >= 0) & (j >= 0) & (j < microbatches)
        jc = jnp.clip(j, 0, microbatches - 1)
        return TickPlan(
            k=jnp.asarray(k, jnp.int32), j=j, jc=jc, valid=valid,
            inject=(d == 0) & (k == 0),
            last=(d == s - 1) & (k == v - 1))

    # -- analytic cost model (benchmarks/pipeline_wire.py) ------------------

    def bubble_fraction(self, microbatches: int, num_stages: int) -> float:
        """Idle fraction of the fill/drain skew: (S-1)/(v*mb + S-1)."""
        return (num_stages - 1) / self.num_ticks(microbatches, num_stages)

    def wire_cuts(self, num_stages: int) -> int:
        """Compressed cuts one microbatch crosses, per direction."""
        return self.virtual_stages * num_stages - 1

    def stash_microbatches(self, microbatches: int, num_stages: int) -> int:
        """In-flight activation stash per device of the IDEALIZED schedule
        (microbatches resident between their fw and bw), the paper-model
        number the benchmark tabulates.  GPipe stashes the full batch;
        1F1B bounds it at S; interleaved at S*v.  (The scan+autodiff
        realization approaches the GPipe bound unless ``remat_ticks``
        shrinks each stashed tick to its boundary tensors.)"""
        return microbatches

    def describe(self, microbatches: int, num_stages: int) -> dict:
        return {
            "schedule": self.name,
            "virtual_stages": self.virtual_stages,
            "fused_wire": self.fused_wire,
            "remat_ticks": self.remat_ticks,
            "ticks": self.num_ticks(microbatches, num_stages),
            "bubble_fraction": round(
                self.bubble_fraction(microbatches, num_stages), 4),
            "wire_cuts_per_microbatch": self.wire_cuts(num_stages),
            # the IDEALIZED schedule's bound (see stash_microbatches) —
            # the scan+autodiff realization stashes all mb boundary
            # tensors, remat_ticks only shrinks what each tick stashes
            "idealized_stash_microbatches": self.stash_microbatches(
                microbatches, num_stages),
        }


@dataclasses.dataclass(frozen=True)
class GPipeSchedule(Schedule):
    name: str = "gpipe"

    def validate(self, microbatches: int, num_stages: int) -> None:
        if self.virtual_stages != 1:
            raise ValueError("gpipe runs one stage slice per device; use "
                             "schedule='interleaved' for virtual stages")


@dataclasses.dataclass(frozen=True)
class OneFOneBSchedule(Schedule):
    name: str = "1f1b"
    fused_wire: bool = True
    remat_ticks: bool = True

    def validate(self, microbatches: int, num_stages: int) -> None:
        if self.virtual_stages != 1:
            raise ValueError("1f1b runs one stage slice per device; use "
                             "schedule='interleaved' for virtual stages")

    def stash_microbatches(self, microbatches: int, num_stages: int) -> int:
        # warmup fills S microbatches; steady state retires one per
        # injection, so the stash never exceeds the stage count.
        return min(microbatches, num_stages)


@dataclasses.dataclass(frozen=True)
class InterleavedSchedule(Schedule):
    name: str = "interleaved"
    virtual_stages: int = 2
    fused_wire: bool = True
    remat_ticks: bool = True

    def stash_microbatches(self, microbatches: int, num_stages: int) -> int:
        return min(microbatches, num_stages) * self.virtual_stages


SCHEDULES = {
    "gpipe": GPipeSchedule,
    "1f1b": OneFOneBSchedule,
    "interleaved": InterleavedSchedule,
}


def get_schedule(name: str, virtual_stages: Optional[int] = None) -> Schedule:
    """Look up a schedule by name, optionally overriding ``virtual_stages``
    (only meaningful for ``interleaved``; the others reject v > 1)."""
    try:
        cls = SCHEDULES[name]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; "
                         f"known: {sorted(SCHEDULES)}") from None
    if virtual_stages is None:
        return cls()
    return cls(virtual_stages=virtual_stages)


def as_schedule(schedule: Union[str, Schedule],
                virtual_stages: Optional[int] = None) -> Schedule:
    """Normalize a ``schedule=`` argument (name or instance)."""
    if isinstance(schedule, Schedule):
        if virtual_stages is not None and \
                virtual_stages != schedule.virtual_stages:
            raise ValueError(
                f"virtual_stages={virtual_stages} conflicts with the "
                f"schedule instance's {schedule.virtual_stages}")
        return schedule
    return get_schedule(schedule, virtual_stages)
