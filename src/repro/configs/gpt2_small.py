"""GPT-2-small [Radford et al. 2019] — the paper's own LM fine-tuning
architecture (Table 5): 12L d=768 12H MHA, GeLU, LayerNorm, abs pos.
We use RoPE-free learned-position-free causal stack with abs pos via
the dense path (pos_embed='none' + tied embeddings) at paper scale."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gpt2-small", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=50257,
    pos_embed="rope", norm="layernorm", mlp="gelu", tie_embeddings=True,
    max_seq=1024, source="Radford et al. 2019 (paper Sec. 3.2)",
)
