"""Granite-8B-Code [arXiv:2405.04324] — llama-architecture code model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=49152,
    pos_embed="rope", rope_theta=10_000_000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
    max_seq=131072, source="arXiv:2405.04324",
)
