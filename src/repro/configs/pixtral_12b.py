"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — mistral-nemo decoder
consuming interleaved text tokens + ViT patch embeddings; the vision
encoder + projector is the allowed STUB (input_specs provides
(B, 256, d) patch embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    pos_embed="rope", rope_theta=1_000_000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    frontend="vision", num_patches=256,
    max_seq=131072, source="hf:mistralai/Pixtral-12B-2409",
)
