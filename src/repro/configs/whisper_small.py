"""Whisper-small [arXiv:2212.04356] — enc-dec; conv/mel frontend STUBBED
(input_specs provides (B, 1500, d) frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51865,
    pos_embed="abs", norm="layernorm", mlp="gelu", tie_embeddings=True,
    enc_dec=True, enc_layers=12, enc_seq=1500, frontend="audio",
    max_seq=32768, source="arXiv:2212.04356",
)
