"""StarCoder2-7B [arXiv:2402.19173] — GQA kv=4, RoPE, GeLU MLP, LayerNorm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    head_dim=128, d_ff=18432, vocab_size=49152,
    pos_embed="rope", rope_theta=1_000_000.0,
    norm="layernorm", mlp="gelu", tie_embeddings=True,
    max_seq=16384, source="arXiv:2402.19173",
)
