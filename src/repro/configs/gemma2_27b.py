"""Gemma2-27B [arXiv:2408.00118] — alternating local(4096)/global attention,
attn-logit softcap 50, final-logit softcap 30, sandwich norms, GeGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    head_dim=128, d_ff=36864, vocab_size=256000,
    pos_embed="rope", rope_theta=10_000.0,
    window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0,
    norm="rmsnorm", mlp="swiglu", post_norm=True, tie_embeddings=True,
    max_seq=1_048_576, source="arXiv:2408.00118",
)
