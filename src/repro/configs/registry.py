"""Architecture registry: the 10 assigned configs + the paper's own models.

Every entry cites its source.  ``get(arch_id)`` returns the exact config;
``get(arch_id, smoke=True)`` returns the reduced smoke variant (2 layer
groups, d_model<=256, <=4 experts) used by per-arch CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

from repro.configs.glm4_9b import CONFIG as glm4_9b
from repro.configs.granite_8b import CONFIG as granite_8b
from repro.configs.llama4_maverick_400b_a17b import CONFIG as llama4_maverick
from repro.configs.whisper_small import CONFIG as whisper_small
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.hymba_1_5b import CONFIG as hymba_1_5b
from repro.configs.gemma2_27b import CONFIG as gemma2_27b
from repro.configs.pixtral_12b import CONFIG as pixtral_12b
from repro.configs.rwkv6_3b import CONFIG as rwkv6_3b
from repro.configs.gpt2_small import CONFIG as gpt2_small

ARCHS: Dict[str, ModelConfig] = {
    c.arch_id: c for c in [
        glm4_9b, granite_8b, llama4_maverick, whisper_small, starcoder2_7b,
        mixtral_8x7b, hymba_1_5b, gemma2_27b, pixtral_12b, rwkv6_3b,
        gpt2_small,
    ]
}

ASSIGNED = [a for a in ARCHS if a != "gpt2-small"]


def get(arch_id: str, smoke: bool = False) -> ModelConfig:
    cfg = ARCHS[arch_id]
    return cfg.reduced() if smoke else cfg
