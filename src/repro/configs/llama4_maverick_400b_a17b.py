"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E] —
MoE 128 experts top-1 + shared expert, interleaved every 2nd layer
(dense/MoE pairs), early-fusion text backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    pos_embed="rope", rope_theta=500_000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    num_experts=128, top_k=1, moe_every_n=2, num_shared_experts=1,
    max_seq=1_048_576, source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
