"""Hymba-1.5B [arXiv:2411.13676] — hybrid: parallel attention + Mamba heads
per block (SSM heads implemented as Mamba2/SSD scalar-decay variant — see
DESIGN.md hardware-adaptation notes), SWA on attention heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    pos_embed="rope", rope_theta=10_000.0, window=1024,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
    ssm_state=16, ssm_heads=25,
    max_seq=1_048_576, source="arXiv:2411.13676",
)
