"""GLM-4-9B [hf:THUDM/glm-4-9b] — dense, RoPE, aggressive GQA (kv=2)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    head_dim=128, d_ff=13696, vocab_size=151552,
    pos_embed="rope", rope_theta=10_000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    max_seq=131072, source="hf:THUDM/glm-4-9b",
)
