"""Mixtral-8x7B [arXiv:2401.04088] — 8 experts top-2 MoE, GQA kv=8,
sliding-window attention (4096) => eligible for long_500k decode."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    pos_embed="rope", rope_theta=1_000_000.0, window=4096,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    num_experts=8, top_k=2,
    max_seq=1_048_576, source="arXiv:2401.04088",
)
