"""RWKV6-3B (Finch) [arXiv:2404.05892] — attention-free, data-dependent
per-channel decay; O(1) decode state => long_500k eligible.
head_size=64 => 40 heads (ssm_state field holds the head size)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    head_dim=64, d_ff=8960, vocab_size=65536,
    pos_embed="none", norm="layernorm", mlp="gelu", tie_embeddings=True,
    ssm_state=64,
    max_seq=1_048_576, source="arXiv:2404.05892",
)
