"""Model configuration.

One frozen dataclass describes every architecture family in the pool
(dense / moe / ssm / hybrid / audio / vlm).  ``src/repro/configs/<id>.py``
instantiates the exact assigned configs; ``reduced()`` derives the smoke-test
variant (<=2 layer-groups, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads

    # -- attention behaviour -------------------------------------------------
    pos_embed: str = "rope"           # rope | abs
    rope_theta: float = 10_000.0
    window: Optional[int] = None      # sliding-window size (SWA)
    local_global_period: int = 0      # gemma2: 2 => alternate local/global
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None

    # -- block flavour -------------------------------------------------------
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    mlp: str = "swiglu"               # swiglu | gelu
    post_norm: bool = False           # gemma2 sandwich norms
    tie_embeddings: bool = True

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_every_n: int = 1              # llama4: 2 => dense/MoE interleave
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_quant: bool = False  # BEYOND-PAPER: int8 EP all-to-all

    # -- SSM / linear attention ----------------------------------------------
    ssm_state: int = 0                # rwkv: head_size; mamba: state N
    ssm_heads: int = 0                # hymba: number of mamba heads

    # -- encoder-decoder (audio) ---------------------------------------------
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0                  # stub frontend frame count

    # -- modality stubs ------------------------------------------------------
    frontend: str = "none"            # none | audio | vision
    num_patches: int = 0              # vlm: patch embeddings per example

    max_seq: int = 8192
    source: str = ""                  # citation

    # ------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def group_size(self) -> int:
        """Layers per scan group (local/global or dense/moe interleave)."""
        if self.local_global_period:
            return self.local_global_period
        if self.num_experts and self.moe_every_n > 1:
            return self.moe_every_n
        return 1

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, \
            f"{self.arch_id}: num_layers {self.num_layers} % group {self.group_size}"
        return self.num_layers // self.group_size

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kinds inside one group, in order."""
        if self.family == "ssm":
            return ("rwkv",)
        if self.family == "hybrid":
            return ("hymba",)
        if self.local_global_period == 2:
            return ("attn_local", "attn_global")
        if self.num_experts and self.moe_every_n == 2:
            return ("dense", "moe")
        if self.num_experts:
            return ("moe",)
        return ("dense",)

    def supports_long_decode(self) -> bool:
        """True if decode memory is sub-quadratic in context (SSM/hybrid/SWA/
        local-global).  Pure full-attention archs skip long_500k."""
        return (self.family in ("ssm", "hybrid") or self.window is not None
                or self.local_global_period == 2)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 groups, d<=512,
        <=4 experts, small vocab."""
        group = self.group_size
        d = min(self.d_model, 256)
        heads = 4
        kv = max(1, min(self.num_kv_heads, 2))
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-smoke",
            num_layers=2 * group,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=min(self.enc_seq, 32) if self.enc_seq else 0,
            num_patches=min(self.num_patches, 8) if self.num_patches else 0,
            window=min(self.window, 16) if self.window else None,
            max_seq=512,
        )


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6ND model-FLOPs in §Roofline)."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    attn = d * hd * h + 2 * d * hd * kv + hd * h * d          # q,k,v,o
    mlp_mult = 3 if cfg.mlp == "swiglu" else 2
    dense_mlp = mlp_mult * d * ff
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    kinds = cfg.layer_kinds() * cfg.num_groups
    for kind in kinds[:cfg.num_layers]:
        if kind in ("dense", "attn_local", "attn_global"):
            total += attn + dense_mlp
        elif kind == "moe":
            total += attn + cfg.num_experts * dense_mlp
            total += cfg.num_shared_experts * dense_mlp
            total += d * cfg.num_experts                       # router
        elif kind == "rwkv":
            # r,k,v,g,w projections + output + channel mix
            total += 6 * d * d + mlp_mult * d * ff
        elif kind == "hymba":
            ssm_d = cfg.ssm_heads * hd
            total += attn + dense_mlp
            total += 2 * d * ssm_d + ssm_d * (2 * cfg.ssm_state + 2) + ssm_d * d
    if cfg.enc_dec:
        total += cfg.enc_layers * (2 * attn + dense_mlp)       # enc + cross-attn
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) params — MoE counts only top_k experts."""
    if not cfg.num_experts:
        return param_count(cfg)
    dense_like = dataclasses.replace(cfg, num_experts=cfg.top_k + cfg.num_shared_experts,
                                     top_k=cfg.top_k)
    return param_count(dense_like)
