"""GQA attention: full / sliding-window / logit-softcap variants.

Three entry points per layer:
  * ``attn_train``   — full-sequence causal self-attention (training/prefill)
  * ``attn_prefill`` — attn_train + returns the filled KV cache
  * ``attn_decode``  — one new token against a KV cache (full or ring buffer)

Cache layout: ``{"k": (B, C, KV, hd), "v": (B, C, KV, hd)}`` where C is the
full context for global layers and ``window`` for SWA layers (ring buffer —
this is what makes mixtral/gemma2 long_500k decode sub-quadratic in memory).
RoPE is applied at *write* time so ring slots never need re-rotation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import DTYPE, apply_rope, dense_init, softcap
from repro.sharding.ctx import constrain


def attn_init(key, d: int, num_heads: int, num_kv_heads: int, head_dim: int,
              dtype=DTYPE):
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, num_heads * head_dim, dtype),
            "wk": dense_init(ks[1], d, num_kv_heads * head_dim, dtype),
            "wv": dense_init(ks[2], d, num_kv_heads * head_dim, dtype),
            "wo": dense_init(ks[3], num_heads * head_dim, d, dtype)}


def _project_qkv(params, x, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, num_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(b, s, num_kv_heads, head_dim)
    return q, k, v


def _sdpa_block(q, k, v, mask, cap: Optional[float]):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask broadcast to (B,H,S,T)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    logits = softcap(logits, cap)
    logits = jnp.where(mask[:, None, None, :, :] if mask.ndim == 3
                       else mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def _qchunk(s: int) -> int:
    """Query-chunk size: bounds the materialized (S_chunk x T) logits so
    long-sequence training/prefill never holds an S x S tensor (flash-style;
    the python loop keeps HLO cost analysis exact, unlike a scan)."""
    if s <= 2048:
        return s
    return max(2048, s // 4)


def _sdpa(q, k, v, mask, cap: Optional[float]):
    s = q.shape[1]
    qc = _qchunk(s)
    if qc >= s:
        return _sdpa_block(q, k, v, mask, cap)
    outs = []
    for i in range(0, s, qc):
        mi = mask[:, i:i + qc] if mask.ndim == 3 else mask
        outs.append(_sdpa_block(q[:, i:i + qc], k, v, mi, cap))
    return jnp.concatenate(outs, axis=1)


def _causal_mask(s: int, window: Optional[int], positions) -> jnp.ndarray:
    """(1, S, S) bool mask; window==None => plain causal."""
    qp = positions[:, None]          # (S,1)
    kp = positions[None, :]          # (1,S)
    m = kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m[None]


def attn_train(params, x, *, num_heads, num_kv_heads, head_dim,
               pos_embed="rope", rope_theta=10_000.0, window=None,
               attn_softcap=None, positions=None, pad_mask=None):
    """``pad_mask``: optional (B, S) bool, True = real token.  Pad keys are
    masked out of every query's context (left-padded serving batches —
    RoPE logits depend only on position differences, so masking alone
    makes a padded prompt exactly equal to the same prompt unpadded)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    if pos_embed == "rope":
        q = apply_rope(q, positions[None], rope_theta)
        k = apply_rope(k, positions[None], rope_theta)
    mask = _causal_mask(s, window, positions)
    if pad_mask is not None:
        mask = mask & pad_mask[:, None, :]          # (B, S, S)
    out = _sdpa(q, k, v, mask, attn_softcap)
    out = out.reshape(b, s, num_heads * head_dim)
    return out @ params["wo"]


def tp_local_heads(num_heads, num_kv_heads, tp):
    """Per-rank head counts for tp-way head-sharded attention."""
    if num_heads % tp or num_kv_heads % tp:
        raise ValueError(
            f"tensor parallelism shards attention heads: num_heads "
            f"{num_heads} and num_kv_heads {num_kv_heads} must both be "
            f"divisible by tp={tp}")
    return num_heads // tp, num_kv_heads // tp


def attn_train_tp(params, x_shard, tpc, *, num_heads, num_kv_heads,
                  head_dim, pos_embed="rope", rope_theta=10_000.0,
                  window=None, attn_softcap=None, buf=None):
    """Column/row-parallel :func:`attn_train` over a compressed tensor
    ring (transport/tp_collectives.py).

    ``params`` are the LOCAL shards — wq/wk/wv split on the head out-dim,
    wo on its head in-dim — and ``x_shard`` the sequence-sharded (normed)
    residual.  The in-gather crosses the compressed wire (``buf`` is this
    site's feedback buffer), attention runs on local heads over the FULL
    sequence (RoPE/causality are exact), and the partial ``wo`` output
    reduce-scatters back to the sequence shard.
    """
    lh, lkv = tp_local_heads(num_heads, num_kv_heads, tpc.tp)
    full, buf = tpc.gather_site(x_shard, buf)
    partial = attn_train(params, full, num_heads=lh, num_kv_heads=lkv,
                         head_dim=head_dim, pos_embed=pos_embed,
                         rope_theta=rope_theta, window=window,
                         attn_softcap=attn_softcap)
    return tpc.scatter(partial), buf


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(batch: int, cache_len: int, num_kv_heads: int, head_dim: int,
               dtype=DTYPE):
    shape = (batch, cache_len, num_kv_heads, head_dim)
    k = constrain(jnp.zeros(shape, dtype), "batch", "seq", "model", None)
    v = constrain(jnp.zeros(shape, dtype), "batch", "seq", "model", None)
    return {"k": k, "v": v}


def attn_decode(params, x1, cache, pos, *, num_heads, num_kv_heads, head_dim,
                pos_embed="rope", rope_theta=10_000.0, window=None,
                attn_softcap=None, pad_len=None):
    """One-token decode.  x1: (B, 1, d); pos: scalar int32 (current index)
    or (B,) int32 per-slot indices (continuous-batching serve: each batch
    slot decodes its own request at its own position).

    ``window`` set => the cache is a ring buffer of length ``cache["k"].shape[1]
    == window`` and slots hold RoPE-rotated keys at their absolute positions.
    ``pad_len``: optional (B,) int32 — cache slots holding absolute
    positions < pad_len[b] are left-padding and masked out.
    """
    b = x1.shape[0]
    c = cache["k"].shape[1]
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    q, k, v = _project_qkv(params, x1, num_heads, num_kv_heads, head_dim)
    if pos_embed == "rope":
        posb = pos[:, None] if per_slot else jnp.full((1, 1), pos)
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    slot = pos % c if window is not None else pos
    if per_slot:
        # batch-dependent slot index: scatter one row per example
        batch_ix = jnp.arange(b)
        ck = cache["k"].at[batch_ix, slot].set(
            k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[batch_ix, slot].set(
            v[:, 0].astype(cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    idx = jnp.arange(c)
    posc = pos[:, None] if per_slot else pos            # (B,1) | scalar
    slotc = slot[:, None] if per_slot else slot
    if window is None:
        valid = idx <= posc                             # absolute layout
        abs_pos = jnp.broadcast_to(idx, valid.shape) if per_slot else idx
    else:
        # ring layout: slot i holds absolute position p_i where
        # p_i = pos - ((slot - i) mod c); valid iff p_i > pos - window
        age = (slotc - idx) % c
        valid = age < jnp.minimum(posc + 1, c)
        abs_pos = posc - age
    if per_slot:
        mask = valid                                    # (B, C)
        if pad_len is not None:
            mask = mask & (abs_pos >= pad_len[:, None])
        mask = mask[:, None, None, None, :]             # (B,1,1,1,C)
    elif pad_len is None:
        mask = valid[None, None, None, :]               # (1,1,1,C) -> bcast
    else:
        # (B,1,1,1,C): batch must align with dim 0 of the (b,kv,g,s,t)
        # logits, not broadcast against kv heads
        mask = (valid[None] & (abs_pos[None] >= pad_len[:, None])
                )[:, None, None, None, :]
    out = _sdpa(q, ck, cv, mask, attn_softcap)
    out = out.reshape(b, 1, num_heads * head_dim)
    return out @ params["wo"], {"k": ck, "v": cv}


def attn_decode_span(params, x, cache, pos, *, num_heads, num_kv_heads,
                     head_dim, pos_embed="rope", rope_theta=10_000.0,
                     window=None, attn_softcap=None, pad_len=None,
                     page_map=None, valid_len=None):
    """Multi-token decode: ``x`` is (B, T, d) new tokens occupying absolute
    positions ``pos[b] + arange(T)``.  One program shape covers chunked
    prefill (B=1, T=chunk) and speculative verification (T=k+1); T=1
    reproduces :func:`attn_decode` bit-for-bit on the same cache contents.

    Cache forms:
      * slab  — ``cache["k"]: (B, C, KV, hd)`` (page_map None), the PR-4
        slot-indexed layout; ``pad_len`` masks left-padding as usual.
      * paged — ``cache["k"]: (N, P, KV, hd)`` (a page POOL) read/written
        through ``page_map: (B, n_pages) int32`` per-slot page indices;
        logical position t lives in physical page ``page_map[b, t // P]``
        at offset ``t % P``.  Unallocated logical pages map to the trash
        page 0 — never valid under the position mask.

    ``valid_len``: optional (B,) int32 — only the first valid_len[b] of the
    T tokens are real (a padded final prefill chunk).  Invalid positions'
    K/V are routed to the trash page (paged; the slab path requires full
    validity) and their queries produce garbage logits the caller ignores.

    Ring (sliding-window) caches are not supported: pages need absolute
    positions.
    """
    if window is not None:
        raise ValueError("attn_decode_span: sliding-window ring caches "
                         "are unsupported (absolute positions only)")
    b, t, _ = x.shape
    pos = jnp.asarray(pos)
    wpos = pos[:, None] + jnp.arange(t)                 # (B, T) abs positions
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    if pos_embed == "rope":
        q = apply_rope(q, wpos, rope_theta)
        k = apply_rope(k, wpos, rope_theta)
    if page_map is not None:
        p = cache["k"].shape[1]                         # page size
        phys = jnp.take_along_axis(page_map, wpos // p, axis=1)  # (B, T)
        if valid_len is not None:
            phys = jnp.where(jnp.arange(t)[None] < valid_len[:, None],
                             phys, 0)                   # pad -> trash page
        off = wpos % p
        ck = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype))
        vk = ck[page_map].reshape(b, -1, num_kv_heads, head_dim)
        vv = cv[page_map].reshape(b, -1, num_kv_heads, head_dim)
    else:
        batch_ix = jnp.arange(b)[:, None]
        ck = cache["k"].at[batch_ix, wpos].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[batch_ix, wpos].set(v.astype(cache["v"].dtype))
        vk, vv = ck, cv
    c = vk.shape[1]
    idx = jnp.arange(c)
    mask = idx[None, None, :] <= wpos[:, :, None]       # (B, T, C) causal
    if pad_len is not None:
        mask &= idx[None, None, :] >= pad_len[:, None, None]
    out = _sdpa(q, vk, vv, mask, attn_softcap)
    out = out.reshape(b, t, num_heads * head_dim)
    return out @ params["wo"], {"k": ck, "v": cv}


def attn_prefill(params, x, *, cache_len, num_heads, num_kv_heads, head_dim,
                 pos_embed="rope", rope_theta=10_000.0, window=None,
                 attn_softcap=None, pad_mask=None):
    """Full-sequence forward that also fills the cache (inference prefill).
    ``pad_mask``: optional (B, S) bool, True = real token (see attn_train)."""
    b, s, d = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    if pos_embed == "rope":
        q = apply_rope(q, positions[None], rope_theta)
        k = apply_rope(k, positions[None], rope_theta)
    mask = _causal_mask(s, window, positions)
    if pad_mask is not None:
        mask = mask & pad_mask[:, None, :]              # (B, S, S)
    out = _sdpa(q, k, v, mask, attn_softcap)
    out = out.reshape(b, s, num_heads * head_dim)
    ring = window is not None
    csize = cache_len if not ring else min(window, cache_len)
    cache = init_cache(b, csize, num_kv_heads, head_dim, k.dtype)
    c = min(csize, s)
    klast = k[:, s - c:].astype(cache["k"].dtype)
    vlast = v[:, s - c:].astype(cache["v"].dtype)
    if ring and c == csize and s % c:
        # ring semantics: abs position p lives at slot p % c
        klast = jnp.roll(klast, s % c, axis=1)
        vlast = jnp.roll(vlast, s % c, axis=1)
    ck = jax.lax.dynamic_update_slice(cache["k"], klast, (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], vlast, (0, 0, 0, 0))
    return out @ params["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, d: int, num_heads: int, head_dim: int, dtype=DTYPE):
    return attn_init(key, d, num_heads, num_heads, head_dim, dtype)


def cross_attn(params, x, memory, *, num_heads, head_dim):
    """x: (B,S,d) queries; memory: (B,T,d) encoder output (non-causal)."""
    b, s, _ = x.shape
    t = memory.shape[1]
    q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
    k = (memory @ params["wk"]).reshape(b, t, num_heads, head_dim)
    v = (memory @ params["wv"]).reshape(b, t, num_heads, head_dim)
    mask = jnp.ones((1, 1, 1, t), bool)
    out = _sdpa(q, k, v, mask, None).reshape(b, s, num_heads * head_dim)
    return out @ params["wo"]
