"""Decoder-only transformer stack with pipeline-stage compression boundaries.

The stack is organized as ``num_groups`` layer groups (a group is 1 layer for
uniform archs, 2 for gemma2 local/global or llama4 dense/moe interleave).
Groups are evenly split into ``policy.num_stages`` stages; between stages sits
a :mod:`repro.core.boundary` compression boundary — the paper's technique.
Within a stage we ``lax.scan`` over stacked layer params (keeps HLO small and
compile time bounded at 40+ layers), with ``jax.checkpoint`` per group.

Entry points:
  init_params(key, cfg)
  forward_train(params, batch, cfg, policy, bstates, ids) -> (logits, aux, new_fw)
  forward_eval(params, batch, cfg, policy, compress)      -> logits
  init_caches(cfg, batch, cache_len, dtype)
  prefill(params, batch, cfg, policy, cache_len, compress) -> (logits, caches)
  decode_step(params, token, caches, pos, cfg, policy, compress)
                                                           -> (logits, caches)
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.boundary import (boundary_apply, boundary_eval,
                                 empty_boundary_state,
                                 boundary_wire_eval,
                                 boundary_wire_eval_tokens)
from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.models import blocks as B
from repro.models.common import DTYPE, embed_init, norm_apply, norm_init, softcap
from repro.models.config import ModelConfig
from repro.models.scan_config import scan_unroll
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=DTYPE):
    kinds = cfg.layer_kinds()
    g = cfg.num_groups
    ks = jax.random.split(key, len(kinds) + 3)
    layers = {}
    for i, kind in enumerate(kinds):
        gkeys = jax.random.split(ks[i], g)
        layers[f"b{i}"] = jax.vmap(
            lambda k: B.block_init(k, cfg, kind))(gkeys)
    params = {"embed": embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype),
              "layers": layers,
              "final_norm": norm_init(cfg.d_model, cfg.norm)}
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[-2], cfg.vocab_size, cfg.d_model,
                                       dtype)
    return params


def segment_bounds(num_groups: int, num_stages: int) -> List[Tuple[int, int]]:
    """Even split of groups into stages: [(g0, g1), ...]."""
    stages = min(num_stages, num_groups)
    per = num_groups / stages
    cuts = [int(round(per * s)) for s in range(stages + 1)]
    return [(cuts[i], cuts[i + 1]) for i in range(stages)
            if cuts[i + 1] > cuts[i]]


def _embed_lookup(embed, tokens):
    """Token embedding lookup.

    Under a mesh: one-hot matmul instead of gather — the gather's backward
    is a scatter-add that GSPMD can only partition by replicating the full
    fp32 (V, d) gradient (4.7 GB/device at vocab 256k); the one-hot dot and
    its transpose stay V-sharded and reduce with one psum (MaxText-style).
    """
    from repro.sharding.ctx import get_mesh
    if get_mesh() is None:
        return embed[tokens].astype(DTYPE)
    onehot = jax.nn.one_hot(tokens, embed.shape[0], dtype=DTYPE)
    # V over model here; activations re-shard to the S-over-model layout
    # at the caller.  S and V cannot both take the model axis in one einsum.
    onehot = constrain(onehot, "batch", None, "model")
    out = jnp.einsum("bsv,vd->bsd", onehot, embed.astype(DTYPE),
                     preferred_element_type=jnp.float32).astype(DTYPE)
    return constrain(out, "batch", None, None)


def _embed_input(params, batch, cfg: ModelConfig):
    """batch: {"tokens": (B,S)} (+ "patch_embeds": (B,P,d) for vlm)."""
    tokens = batch["tokens"]
    x = _embed_lookup(params["embed"], tokens)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        p = batch["patch_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x[:, p:]], axis=1)
    x = constrain(x, "batch", "model", None)
    return x


def _lm_logits(params, x, cfg: ModelConfig):
    """Logits in bf16 (fp32 MXU accumulation, downcast fused into the
    matmul) — materializing fp32 (B,S,V) costs 4x the HBM of the weights
    at vocab 256k; the loss upcasts per-reduction instead (see lm_loss)."""
    x = constrain(x, "batch", None, None)     # release S from the model axis
    x = norm_apply(params["final_norm"], x, cfg.norm)
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(DTYPE), head.astype(DTYPE),
                        preferred_element_type=jnp.float32).astype(DTYPE)
    logits = softcap(logits, cfg.final_softcap)
    return constrain(logits, "batch", None, "model")


def _slice_groups(tree, g0: int, g1: int):
    return jax.tree.map(lambda a: a[g0:g1], tree)


# ---------------------------------------------------------------------------
# Training forward (with boundary compression + feedback state threading)
# ---------------------------------------------------------------------------

def forward_hidden(params, batch, cfg: ModelConfig,
                   policy: CompressionPolicy = NO_POLICY,
                   bstates: Optional[list] = None,
                   ids: Optional[jnp.ndarray] = None,
                   remat: bool = True):
    """Returns (hidden_x, aux_loss, new_fw_buffers).

    ``bstates``: list of {"fw","bw"} per boundary (see core.boundary).  The
    bw buffers' updates come back as their cotangents — the train step takes
    grad w.r.t. them (see train/steps.py).
    """
    kinds = cfg.layer_kinds()
    x = _embed_input(params, batch, cfg)
    if ids is None:
        ids = jnp.zeros((x.shape[0],), jnp.int32)
    aux = jnp.float32(0.0)
    segs = segment_bounds(cfg.num_groups, policy.num_stages)
    new_fw = []

    def group_fn(x, gp):
        a = jnp.float32(0.0)
        for i, kind in enumerate(kinds):
            x, ai = B.block_train(gp[f"b{i}"], x, cfg, kind)
            a = a + ai
        # keep the scan carry (and the remat-saved residual) fully sharded:
        # batch over DP, SEQUENCE over TP (Megatron-SP layout: norms stay
        # collective-free; attention/mlp all-gather bf16 k/v as needed)
        x = constrain(x, "batch", "model", None)
        return x, a

    if remat:
        group_fn = jax.checkpoint(group_fn)

    for si, (g0, g1) in enumerate(segs):
        def scan_fn(carry, gp):
            x, a = carry
            x, ai = group_fn(x, gp)
            return (x, a + ai), None
        (x, aux), _ = jax.lax.scan(scan_fn, (x, aux),
                                   _slice_groups(params["layers"], g0, g1), unroll=scan_unroll())
        if si < len(segs) - 1:
            bp = policy.at(si)
            st = (bstates[si] if bstates is not None
                  else empty_boundary_state(x.dtype))
            x, nf = boundary_apply(bp, x, st["fw"], st["bw"], ids)
            new_fw.append(nf)
    return x, aux, new_fw


def forward_train(params, batch, cfg: ModelConfig,
                  policy: CompressionPolicy = NO_POLICY,
                  bstates: Optional[list] = None,
                  ids: Optional[jnp.ndarray] = None,
                  remat: bool = True):
    x, aux, new_fw = forward_hidden(params, batch, cfg, policy, bstates,
                                    ids, remat)
    return _lm_logits(params, x, cfg), aux, new_fw


def stage_stack_fn(cfg: ModelConfig):
    """``stage_fn(gp_stack, x) -> x`` applying a stacked slice of layer
    groups — the per-stage body for the REAL pipeline transport
    (transport/pipeline.py).  MoE aux losses are dropped on this path."""
    kinds = cfg.layer_kinds()

    def stage_fn(gp_stack, x):
        def scan_fn(x, gp):
            for i, kind in enumerate(kinds):
                x, _ = B.block_train(gp[f"b{i}"], x, cfg, kind)
            return x, None
        x, _ = jax.lax.scan(scan_fn, x, gp_stack, unroll=scan_unroll())
        return x

    return stage_fn


def stack_layer_stages(params, num_stages: int):
    """Reshape the (num_groups, ...) layer stack to (S, groups/S, ...) for
    the pipeline's stage-stacked params."""
    def reshape(a):
        g = a.shape[0]
        if g % num_stages:
            raise ValueError(
                f"num_groups={g} is not divisible by num_stages="
                f"{num_stages}; pick a stage count that divides the "
                "layer-group count (--stages for launch/train)")
        return a.reshape(num_stages, g // num_stages, *a.shape[1:])
    return jax.tree.map(reshape, params["layers"])


_TP_LAST_DIM = ("wq", "wk", "wv", "wi", "wg")


def tp_param_dims(stack):
    """The tensor-sharded dim per leaf of a layer stack (any number of
    leading scan/stage dims): wq/wk/wv and the MLP in-projections split
    on their OUT dim (column parallel), every ``wo`` on its IN dim (row
    parallel), and -1 (replicated) for the rest — the norm scales, whose
    tiny gradients all-reduce exactly via the shard_map transpose psum.
    Feeds ``tp_apply``'s ``param_dims`` / the pipeline's tp specs.
    """
    def dim(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in _TP_LAST_DIM:
            return leaf.ndim - 1
        if name == "wo":
            return leaf.ndim - 2
        return -1
    flat, treedef = jax.tree_util.tree_flatten_with_path(stack)
    return jax.tree_util.tree_unflatten(treedef,
                                        [dim(p, l) for p, l in flat])


def tp_sites(cfg: ModelConfig, groups: Optional[int] = None) -> int:
    """All-gather cut points per forward pass: 2 per block (attention +
    MLP in-gathers) — the ``sites`` count for ``init_tp_state``."""
    g = cfg.num_groups if groups is None else groups
    return 2 * len(cfg.layer_kinds()) * g


def tp_stage_stack_fn(cfg: ModelConfig, tpc):
    """``stage_fn(gp_stack, x, resid, mirror) -> (x, resid, mirror)`` —
    the tensor-parallel twin of :func:`stage_stack_fn`, run INSIDE the
    tensor ``shard_map`` (transport.tp_collectives.tp_apply or the 3D
    pipeline): ``x`` is the sequence-sharded residual, ``gp_stack`` the
    tp-local weight shards, and ``resid``/``mirror`` the site-stacked
    feedback buffers (or size-0 placeholders for feedback "none")."""
    kinds = cfg.layer_kinds()
    for kind in kinds:
        if kind not in B.TP_BLOCK_KINDS:
            raise ValueError(
                f"tensor parallelism covers the dense family "
                f"{B.TP_BLOCK_KINDS}; layer kind {kind!r} shards "
                f"differently (expert/state parallel) — run it with tp=1")
    nb = len(kinds)

    def stage_fn(gp_stack, x, resid, mirror):
        if tpc.feedback == "none":
            def scan_fn(x, gp):
                for i, kind in enumerate(kinds):
                    x, _ = B.attn_block_train_tp(gp[f"b{i}"], x, cfg, kind,
                                                 tpc)
                return x, None
            x, _ = jax.lax.scan(scan_fn, x, gp_stack, unroll=scan_unroll())
            return x, resid, mirror

        st = resid if tpc.feedback == "ef" else mirror
        g = jax.tree.leaves(gp_stack)[0].shape[0]
        st_g = st.reshape(g, 2 * nb, *st.shape[1:])

        def scan_fn(x, inp):
            gp, stb = inp
            outs = []
            for i, kind in enumerate(kinds):
                x, (b1, b2) = B.attn_block_train_tp(
                    gp[f"b{i}"], x, cfg, kind, tpc,
                    bufs=(stb[2 * i], stb[2 * i + 1]))
                outs += [b1, b2]
            return x, jnp.stack(outs)

        x, st_out = jax.lax.scan(scan_fn, x, (gp_stack, st_g),
                                 unroll=scan_unroll())
        st_out = st_out.reshape(st.shape)
        if tpc.feedback == "ef":
            return x, st_out, mirror
        return x, resid, st_out

    return stage_fn


def hidden_lm_loss(params, x, labels, cfg: ModelConfig,
                   mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Chunked cross-entropy straight from hidden states: the (B,S,V)
    logits are never materialized — each sequence chunk's logits are
    computed, reduced, and REMATERIALIZED in backward (jax.checkpoint).
    Standard large-vocab technique; keeps loss-path peak memory at one
    chunk regardless of vocab size."""
    b, s, d = x.shape
    chunk = s if s <= 512 else max(512, s // 16)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    @jax.checkpoint
    def chunk_nll(xc, lc, mc):
        logits = _lm_logits(params, xc, cfg)
        return (_fused_xent(logits, lc) * mc).sum()

    total = jnp.float32(0.0)
    for i in range(0, s, chunk):
        total = total + chunk_nll(x[:, i:i + chunk], labels[:, i:i + chunk],
                                  mask[:, i:i + chunk])
    return total / jnp.maximum(mask.sum(), 1.0)


def forward_eval(params, batch, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, wire: bool = False):
    """``wire=True`` routes stage cuts through the wire-codec registry
    (pack -> unpack per request) instead of the in-process ``boundary_eval``
    — what the serve engines do (see core/boundary.boundary_wire_eval)."""
    kinds = cfg.layer_kinds()
    beval = boundary_wire_eval if wire else boundary_eval
    x = _embed_input(params, batch, cfg)
    segs = segment_bounds(cfg.num_groups, policy.num_stages)
    for si, (g0, g1) in enumerate(segs):
        def scan_fn(x, gp):
            for i, kind in enumerate(kinds):
                x, _ = B.block_train(gp[f"b{i}"], x, cfg, kind)
            return constrain(x, "batch", "model", None), None
        x, _ = jax.lax.scan(scan_fn, x,
                            _slice_groups(params["layers"], g0, g1), unroll=scan_unroll())
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    return _lm_logits(params, x, cfg)


# ---------------------------------------------------------------------------
# Inference: prefill + decode with per-group caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=DTYPE):
    kinds = cfg.layer_kinds()
    caches = {}
    for i, kind in enumerate(kinds):
        def one(_):
            return B.block_cache(cfg, kind, batch, cache_len, dtype)
        caches[f"b{i}"] = jax.vmap(one)(jnp.arange(cfg.num_groups))
    return caches


def prefill(params, batch, cfg: ModelConfig,
            policy: CompressionPolicy = NO_POLICY, cache_len: int = 0,
            compress: bool = True, pad_len=None, wire: bool = False):
    """``pad_len``: optional (B,) int32 — the first pad_len[b] positions
    are left-padding (mixed-length serving batches) and are masked out of
    attention in every layer.  ``wire=True``: stage cuts pack/unpack the
    real codec payloads (see forward_eval)."""
    kinds = cfg.layer_kinds()
    beval = boundary_wire_eval if wire else boundary_eval
    x = _embed_input(params, batch, cfg)
    cache_len = cache_len or x.shape[1]
    segs = segment_bounds(cfg.num_groups, policy.num_stages)
    cache_segs = []
    pad_mask = None
    if pad_len is not None:
        pad_mask = jnp.arange(x.shape[1])[None, :] >= pad_len[:, None]

    for si, (g0, g1) in enumerate(segs):
        def scan_fn(x, gp):
            cs = {}
            for i, kind in enumerate(kinds):
                x, c, _ = B.block_prefill(gp[f"b{i}"], x, cfg, kind,
                                          cache_len, pad_mask=pad_mask)
                cs[f"b{i}"] = c
            return constrain(x, "batch", "model", None), cs
        x, cseg = jax.lax.scan(scan_fn, x,
                               _slice_groups(params["layers"], g0, g1), unroll=scan_unroll())
        cache_segs.append(cseg)
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *cache_segs)
    return _lm_logits(params, x[:, -1:], cfg), caches


def decode_step(params, token, caches, pos, cfg: ModelConfig,
                policy: CompressionPolicy = NO_POLICY, compress: bool = True,
                pad_len=None, wire: bool = False):
    """token: (B,) int32; pos: scalar int32 OR (B,) int32 per-slot decode
    positions (continuous batching).  Returns (logits, new_caches).
    ``pad_len``: optional (B,) int32 left-padding lengths (see prefill);
    ``wire=True``: stage cuts pack/unpack the real codec payloads."""
    kinds = cfg.layer_kinds()
    beval = boundary_wire_eval if wire else boundary_eval
    x = params["embed"][token][:, None].astype(DTYPE)
    x = constrain(x, "batch", None, "model")
    segs = segment_bounds(cfg.num_groups, policy.num_stages)
    new_segs = []
    for si, (g0, g1) in enumerate(segs):
        def scan_fn(x, gp_cache):
            gp, cache = gp_cache
            new_c = {}
            for i, kind in enumerate(kinds):
                x, c = B.block_decode(gp[f"b{i}"], x, cache[f"b{i}"], pos,
                                      cfg, kind, pad_len=pad_len)
                new_c[f"b{i}"] = c
            return constrain(x, "batch", "model", None), new_c
        x, nseg = jax.lax.scan(scan_fn, x, (_slice_groups(params["layers"], g0, g1),
                         _slice_groups(caches, g0, g1)), unroll=scan_unroll())
        new_segs.append(nseg)
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    new_caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *new_segs)
    return _lm_logits(params, x, cfg)[:, 0], new_caches


def decode_span(params, tokens, caches, pos, cfg: ModelConfig,
                policy: CompressionPolicy = NO_POLICY, compress: bool = True,
                pad_len=None, page_map=None, valid_len=None,
                wire: bool = True):
    """Multi-token decode: ``tokens`` (B, T) occupy absolute positions
    ``pos[b] + arange(T)``; K/V for all T tokens are written into the cache
    and logits are returned for EVERY position — (B, T, V).

    One program shape serves both halves of the serving stack:
      * chunked prefill — B=1, T=chunk, ``valid_len`` masking the padded
        tail of the final chunk (the last valid logit seeds generation);
      * speculative verification — B=slots, T=k+1, the target scoring the
        draft's k proposals plus the bonus position in ONE forward.

    ``caches``: the slab layout (leaves (G, B, C, ...)) or — with
    ``page_map`` (B, n_pages) — a page pool (leaves (G, N, P, ...)), see
    attention.attn_decode_span.

    Stage cuts pack per (request, token) when ``wire`` is set
    (boundary_wire_eval_tokens) — the same payload granularity as a T=1
    decode tick, so span logits match per-token decode bit-for-bit.
    """
    if compress and not wire:
        raise NotImplementedError(
            "decode_span compresses through the wire codecs only "
            "(wire=True) — the serve engines never use the in-process "
            "boundary at decode time")
    kinds = cfg.layer_kinds()
    x = params["embed"][tokens].astype(DTYPE)             # (B, T, d)
    x = constrain(x, "batch", None, "model")
    segs = segment_bounds(cfg.num_groups, policy.num_stages)
    new_segs = []
    for si, (g0, g1) in enumerate(segs):
        def scan_fn(x, gp_cache):
            gp, cache = gp_cache
            new_c = {}
            for i, kind in enumerate(kinds):
                x, c = B.block_decode_span(
                    gp[f"b{i}"], x, cache[f"b{i}"], pos, cfg, kind,
                    pad_len=pad_len, page_map=page_map, valid_len=valid_len)
                new_c[f"b{i}"] = c
            return constrain(x, "batch", "model", None), new_c
        x, nseg = jax.lax.scan(
            scan_fn, x, (_slice_groups(params["layers"], g0, g1),
                         _slice_groups(caches, g0, g1)),
            unroll=scan_unroll())
        new_segs.append(nseg)
        if si < len(segs) - 1:
            x = boundary_wire_eval_tokens(policy.at(si), x, compress)
    new_caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *new_segs)
    return _lm_logits(params, x, cfg), new_caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _pick_label_logit(logits, labels):
    """logits[..., labels] via a masked reduction instead of
    take_along_axis: gathers along a vocab dim the SPMD partitioner has
    sharded (lm head tied to a tensor-sharded embed) miscompile on some
    backends, while select+sum partitions as plain elementwise+reduce.
    Bitwise identical — every non-label slot contributes an exact 0."""
    v = logits.shape[-1]
    hit = labels[..., None] == jnp.arange(v, dtype=labels.dtype)
    return jnp.where(hit, logits, jnp.zeros((), logits.dtype)) \
        .sum(-1).astype(jnp.float32)


@jax.custom_vjp
def _fused_xent(logits, labels):
    """Per-token -log p[label] without materializing fp32 (B,S,V).

    Forward: logsumexp + masked label pick (reduce-fused upcasts only).
    Backward: dlogits = (softmax - onehot) * g, recomputed from the saved
    bf16 logits + fp32 lse — ONE (B,S,V) temp in logits dtype.
    """
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    return lse - _pick_label_logit(logits, labels)


def _fx_fwd(logits, labels):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    return lse - _pick_label_logit(logits, labels), (logits, labels, lse)


def _fx_bwd(res, g):
    logits, labels, lse = res
    probs = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    dlogits = ((probs - onehot) * g[..., None]).astype(logits.dtype)
    return dlogits, None


_fused_xent.defvjp(_fx_fwd, _fx_bwd)


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Next-token cross entropy.  logits: (B,S,V); labels: (B,S).

    Processed in sequence chunks so the fp32 elementwise intermediates over
    (B, S_chunk, V) stay bounded even on backends with weak elementwise
    fusion (the host CPU used for dry-run memory accounting)."""
    s = labels.shape[1]
    chunk = s if s <= 512 else max(512, s // 8)
    nlls = [_fused_xent(logits[:, i:i + chunk], labels[:, i:i + chunk])
            for i in range(0, s, chunk)]
    nll = jnp.concatenate(nlls, axis=1) if len(nlls) > 1 else nlls[0]
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
