"""Chunked linear-attention core — shared by RWKV6 (Finch) and Mamba2/SSD.

Recurrence (per head, state S in R^{K x V}):
    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T          (w_t <= 0, log decay)
    y_t = q_t (S_{t-1} + diag(u) k_t v_t^T)           [bonus mode, RWKV]
    y_t = q_t S_t                                      [include-current, SSD]

TPU adaptation: instead of a sequential scan over T steps we scan over
chunks of L tokens; inside a chunk everything is matmuls (MXU-friendly)
with *non-positive* exponents only — numerically safe without rescaling:

    y_t  = (q_t . exp(cx_t)) S_0                      (inter-chunk)
         + sum_j q_t k_j exp(cx_t - c_j) v_j          (intra-chunk, cx>=c_j)
    S_L  = exp(c_L) . S_0 + sum_j (k_j exp(c_L - c_j)) v_j^T

where c_t = cumsum(w)_t, cx_t = c_{t-1} (bonus) or c_t (include-current).
This is the layout the Pallas kernel (kernels/linattn.py) mirrors.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
MIN_LOG_DECAY = -8.0     # clamp: exp(-8) ~ 3e-4 per step, effectively zero


def _chunk(x, l):
    b, h, t, f = x.shape
    return x.reshape(b, h, t // l, l, f)


def chunked_linear_attention(q, k, v, log_w, *, chunk: int = 32,
                             bonus: Optional[jnp.ndarray] = None,
                             initial_state: Optional[jnp.ndarray] = None
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,log_w: (B,H,T,K); v: (B,H,T,V); bonus u: (H,K) or None.

    bonus given  => RWKV semantics (y_t reads S_{t-1} + u-weighted current).
    bonus None   => SSD semantics  (y_t reads S_t).
    Returns (y: (B,H,T,V), final_state: (B,H,K,V)).  Computation in fp32.
    """
    b, h, t, kd = q.shape
    vd = v.shape[-1]
    dt = v.dtype
    q, k, v = (a.astype(jnp.float32) for a in (q, k, v))
    log_w = jnp.clip(log_w.astype(jnp.float32), MIN_LOG_DECAY, 0.0)

    l = min(chunk, t)
    pad = (-t) % l
    if pad:
        zq = jnp.zeros((b, h, pad, kd), jnp.float32)
        q = jnp.concatenate([q, zq], axis=2)
        k = jnp.concatenate([k, zq], axis=2)
        v = jnp.concatenate([v, jnp.zeros((b, h, pad, vd), jnp.float32)], axis=2)
        log_w = jnp.concatenate([log_w, jnp.zeros((b, h, pad, kd), jnp.float32)],
                                axis=2)

    qc, kc, vc, wc = (_chunk(a, l) for a in (q, k, v, log_w))
    nc = qc.shape[2]
    include_current = bonus is None
    # intra-chunk pair mask: j < t (bonus) or j <= t (include-current)
    ti = jnp.arange(l)
    pair_mask = (ti[None, :] < ti[:, None]) if not include_current \
        else (ti[None, :] <= ti[:, None])                       # (L, L)

    if initial_state is None:
        s0 = jnp.zeros((b, h, kd, vd), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def body(s, xs):
        qi, ki, vi, wi = xs                       # (B,H,L,*)
        c = jnp.cumsum(wi, axis=2)                # (B,H,L,K)
        cx = c if include_current else c - wi     # c_{t} or c_{t-1}
        # inter-chunk
        y = jnp.einsum("bhlk,bhkv->bhlv", qi * jnp.exp(cx), s)
        # intra-chunk: exponent cx[t] - c[j]  (<= 0 wherever masked valid)
        expo = cx[:, :, :, None, :] - c[:, :, None, :, :]       # (B,H,L,L,K)
        expo = jnp.where(pair_mask[None, None, :, :, None], expo, NEG_INF)
        att = jnp.einsum("bhtk,bhjk,bhtjk->bhtj", qi, ki, jnp.exp(expo))
        y = y + jnp.einsum("bhtj,bhjv->bhtv", att, vi)
        if bonus is not None:
            ub = jnp.einsum("bhtk,hk,bhtk->bht", qi,
                            bonus.astype(jnp.float32), ki)
            y = y + ub[..., None] * vi
        # state to end of chunk
        c_last = c[:, :, -1:, :]                                # (B,H,1,K)
        s_new = jnp.exp(c_last[:, :, 0, :])[..., None] * s
        decayed_k = ki * jnp.exp(c_last - c)                    # (B,H,L,K)
        s_new = s_new + jnp.einsum("bhlk,bhlv->bhkv", decayed_k, vi)
        return s_new, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (qc, kc, vc, wc))
    s_final, ys = jax.lax.scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, nc * l, vd)[:, :, :t]
    return y.astype(dt), s_final


def linear_attention_decode(q1, k1, v1, log_w1, state, *,
                            bonus: Optional[jnp.ndarray] = None
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-step recurrence.  q1,k1,log_w1: (B,H,K); v1: (B,H,V);
    state: (B,H,K,V)."""
    f32 = jnp.float32
    q1, k1, v1 = (a.astype(f32) for a in (q1, k1, v1))
    log_w1 = jnp.clip(log_w1.astype(f32), MIN_LOG_DECAY, 0.0)
    kv = k1[..., :, None] * v1[..., None, :]                   # (B,H,K,V)
    if bonus is not None:
        read = state + bonus.astype(f32)[None, :, :, None] * kv
        new_state = jnp.exp(log_w1)[..., None] * state + kv
    else:
        new_state = jnp.exp(log_w1)[..., None] * state + kv
        read = new_state
    y = jnp.einsum("bhk,bhkv->bhv", q1, read)
    return y, new_state


def reference_linear_attention(q, k, v, log_w, *, bonus=None,
                               initial_state=None):
    """O(T) sequential oracle for tests (same signature, fp32)."""
    b, h, t, kd = q.shape
    vd = v.shape[-1]
    s = (jnp.zeros((b, h, kd, vd), jnp.float32) if initial_state is None
         else initial_state.astype(jnp.float32))
    ys = []
    for i in range(t):
        y, s = linear_attention_decode(q[:, :, i], k[:, :, i], v[:, :, i],
                                       log_w[:, :, i], s, bonus=bonus)
        ys.append(y)
    return jnp.stack(ys, axis=2).astype(v.dtype), s
