"""Mixture-of-Experts FFN: GShard-style grouped capacity-based dispatch.

TPU-native formulation: tokens are split into groups of ``group_size``;
within a group, routing is materialized as dispatch/combine one-hot tensors
``(g, E, C)`` applied with einsums.  Under expert-parallel sharding the
group axis is data-sharded and the expert axis is expert-sharded, so the
``(G,E,C,d)`` expert-input tensor changes sharding between the dispatch
einsum and the expert matmuls — XLA lowers exactly that re-sharding to an
all-to-all.  Capacity C = ceil(cf * g * top_k / E) bounds expert work and
keeps the dispatch tensor O(T * g * k * cf) instead of O(T^2).

Supports mixtral (8e top-2) and llama4-maverick (128e top-1 + shared
expert, interleaved every 2nd layer).  Router in fp32 with Switch-style
load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import DTYPE, dense_init, mlp_apply, mlp_init
from repro.sharding.ctx import constrain

GROUP_SIZE = 4096        # tokens per routing group (MaxText-like)


def moe_init(key, d: int, ff: int, num_experts: int, mlp_kind: str,
             num_shared: int = 0, dtype=DTYPE):
    ks = jax.random.split(key, num_experts + 2)
    expert = jax.vmap(lambda k: mlp_init(k, d, ff, mlp_kind, dtype))(
        jnp.stack(ks[:num_experts]))
    params = {"router": dense_init(ks[-1], d, num_experts, jnp.float32),
              "experts": expert}
    if num_shared:
        params["shared"] = mlp_init(ks[-2], d, ff * num_shared, mlp_kind, dtype)
    return params


def _route(logits: jnp.ndarray, top_k: int, cap: int, num_experts: int):
    """logits: (G, g, E) fp32 -> dispatch (G,g,E,C) token dtype-agnostic,
    combine (G,g,E,C) fp32, aux loss scalar."""
    gg, g, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance loss on top-1 assignment
    me = probs.mean(axis=1)                                      # (G,E)
    top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    ce = top1.mean(axis=1)                                       # (G,E)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # slot position of each (token, choice) within its expert, per group.
    # choices flattened in priority order: all top-1 first, then top-2 ...
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)        # (G,g,k,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(gg, g * top_k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G,g*k,E)
    pos = (pos * flat).sum(-1).reshape(gg, top_k, g).transpose(0, 2, 1)
    keep = pos < cap                                             # (G,g,k)
    gate_vals = gate_vals * keep

    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=jnp.float32)[..., :cap]       # (G,g,k,C)
    exp_oh = onehot.astype(jnp.float32)                          # (G,g,k,E)
    dispatch = jnp.einsum("Ggke,Ggkc->Ggec", exp_oh,
                          slot_oh * keep[..., None].astype(jnp.float32))
    combine = jnp.einsum("Ggke,Ggkc->Ggec", exp_oh,
                         slot_oh * gate_vals[..., None])
    return dispatch, combine, aux


def _quant_dispatch(t: jnp.ndarray, spec) -> jnp.ndarray:
    """BEYOND-PAPER: int8-quantize the expert-dispatch payload across the
    EP all-to-all (the paper compresses only pipeline-stage handoffs; the
    same insight applies to the (E,G,C,d) dispatch tensor, which §Roofline
    shows dominates MoE collective bytes).  Per-(expert,group,slot) scales
    ride along as fp32 — 1/513 of the payload.  Straight-through estimator
    in backward (the quantization is on the wire, not in the math).
    """
    from repro.core.compressors import quantize_kbit, dequantize_kbit
    from repro.sharding.ctx import constrain as _c

    @jax.custom_vjp
    def qdq(t):
        codes, mn, sc = quantize_kbit(t.astype(jnp.float32), 8, axis=(3,))
        codes = _c(codes.astype(jnp.int8), *spec)       # int8 on the wire
        mn = _c(mn, *spec)
        sc = _c(sc, *spec)
        return dequantize_kbit(codes.astype(jnp.uint8), mn, sc,
                               jnp.float32).astype(t.dtype)

    def fwd(t):
        return qdq(t), None

    def bwd(_, g):
        # paper-symmetric: the backward all-to-all payload (the gradient
        # w.r.t. the dispatched tokens) is quantized the same way
        codes, mn, sc = quantize_kbit(g.astype(jnp.float32), 8, axis=(3,))
        codes = _c(codes.astype(jnp.int8), *spec)
        gq = dequantize_kbit(codes.astype(jnp.uint8), _c(mn, *spec),
                             _c(sc, *spec), jnp.float32)
        return (gq.astype(g.dtype),)

    qdq.defvjp(fwd, bwd)
    return qdq(t)


def _moe_apply_dense(params, x: jnp.ndarray, *, num_experts: int,
                     top_k: int, mlp_kind: str, dispatch_quant: bool = False
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless dense routing (inference paths).

    The grouped capacity heuristic is LENGTH-DEPENDENT: C = ceil(cf * g *
    k / E) and the group composition both change with the total token
    count, so the same token can be dropped in one forward and routed in
    another — decode (t = B tokens per group, C collapses to 1) drifted
    from prefill, and a 30-token prefill drops different tokens than a
    32-token one.  Inference therefore routes densely: every expert runs
    on every token, combined with the (renormalized) top-k gates —
    identical expert math to the capacity path for kept tokens, and
    nothing is ever dropped.  (A production server would realize the same
    dropless semantics with grouped GEMMs instead of the dense E-way
    fan-out; capacity routing stays on the training path, where bounded
    expert work is the point.)
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"]           # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    combine = jnp.sum(
        jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.float32)
        * gate_vals[..., None], axis=1)                          # (T,E)
    top1 = jax.nn.one_hot(gate_idx[..., 0], num_experts, dtype=jnp.float32)
    aux = num_experts * jnp.sum(probs.mean(0) * top1.mean(0))
    ex_in = xt
    if dispatch_quant:
        # same wire semantics as _quant_dispatch: the token vectors the
        # experts receive are int8-quantized along d (straight-through)
        from repro.core.compressors import quantize_dequantize
        qdq = quantize_dequantize(ex_in.astype(jnp.float32), 8,
                                  axis=(1,)).astype(ex_in.dtype)
        ex_in = ex_in + jax.lax.stop_gradient(qdq - ex_in)
    ex_out = jax.vmap(lambda p: mlp_apply(p, ex_in, mlp_kind))(
        params["experts"])                                       # (E,T,d)
    y = jnp.einsum("etd,te->td", ex_out, combine.astype(ex_out.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, mlp_kind)
    return y.reshape(b, s, d), aux


def moe_apply(params, x: jnp.ndarray, *, num_experts: int, top_k: int,
              mlp_kind: str, capacity_factor: float = 1.25,
              group_size: int = GROUP_SIZE, dispatch_quant: bool = False,
              dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d).  Returns (y, aux_loss).  ``dropless`` (inference)
    switches to dense routing — see :func:`_moe_apply_dense`; single-token
    decode always routes densely (capacity degenerates to C=1 there)."""
    b, s, d = x.shape
    if dropless or s == 1:
        return _moe_apply_dense(params, x, num_experts=num_experts,
                                top_k=top_k, mlp_kind=mlp_kind,
                                dispatch_quant=dispatch_quant)
    t = b * s
    g = min(group_size, t)
    while t % g:
        g //= 2
    gg = t // g
    cap = max(top_k, int(math.ceil(capacity_factor * g * top_k / num_experts)))

    xt = x.reshape(gg, g, d)
    xt = constrain(xt, "batch", None, None)
    logits = xt.astype(jnp.float32) @ params["router"]           # (G,g,E)
    dispatch, combine, aux = _route(logits, top_k, cap, num_experts)
    # §Perf (EXPERIMENTS.md, mixtral hillclimb 1): the routing one-hots are
    # the LARGEST tensors in the layer (G*g*E*C).  Pin them group-sharded so
    # the partitioner never all-gathers them over the data axis — the
    # inter-device traffic must be the small (E,G,C,d) expert-input tensor.
    dispatch = constrain(dispatch, "batch", None, None, None)
    combine = constrain(combine, "batch", None, None, None)

    # dispatch einsum contracts g (group-local): compute with G sharded,
    # THEN reshard to expert-parallel — exactly one all-to-all on ex_in.
    # (§Perf iteration 3 tried d replicated here — all-gather bytes grew
    # 4.5x because the dispatch einsum's transpose then re-gathered the
    # full (G,E,C,d) tensor over data; d-over-model is the right layout.)
    ex_in = jnp.einsum("Ggd,Ggec->eGcd", xt, dispatch.astype(xt.dtype))
    if dispatch_quant:
        ex_in = _quant_dispatch(ex_in, ("expert", None, None, "model"))
    else:
        ex_in = constrain(ex_in, "expert", None, None, "model")
    # (§Perf iteration 4 tried an explicit bf16 d-gather here — the
    # constraint's transpose re-gathered the tensor over data in backward,
    # +4.6x all-gather.  The partitioner's implicit gather wins; its
    # f32-before-gather ordering is a CPU-backend artifact only.)
    ex_out = jax.vmap(lambda p, h: mlp_apply(p, h.reshape(-1, d), mlp_kind
                                             ).reshape(gg, cap, d),
                      in_axes=(0, 0))(params["experts"], ex_in)  # (E,G,C,d)
    # reshard BACK to group-sharded before the combine einsum so the
    # combine contraction (over e, c) is local in G — the reverse
    # all-to-all happens on ex_out, not by gathering `combine`.
    ex_out = constrain(ex_out, None, "batch", None, "model")
    # §Perf iteration 2: keep the big (E,G,C,d) tensor bf16 on the wire —
    # fp32 accumulation happens in the MXU (preferred_element_type), not by
    # materializing an fp32 copy that doubles the all-gather bytes.
    y = jnp.einsum("eGcd,Ggec->Ggd", ex_out,
                   combine.astype(ex_out.dtype),
                   preferred_element_type=jnp.float32)
    y = constrain(y.astype(x.dtype), "batch", None, None)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, mlp_kind)
    return y.reshape(b, s, d), aux
