"""ResNet-style CNN for the paper's ResNet18/CIFAR-10 experiments.

Four stages of residual blocks — exactly the paper's model-parallel degree 4
with 3 compression boundaries between stages (Fig. 1).  GroupNorm replaces
BatchNorm so the model is purely functional (no running stats to thread
through custom_vjp boundaries); this does not affect the paper's qualitative
compression findings.  NHWC, ``jax.lax.conv_general_dilated``.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.boundary import (boundary_apply, boundary_eval,
                                 empty_boundary_state)
from repro.core.policy import CompressionPolicy, NO_POLICY


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _gn(params, x, groups=8):
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(b, h, w, c) * params["scale"] + params["bias"]


def _block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {"conv1": _conv_init(ks[0], 3, 3, cin, cout), "gn1": _gn_init(cout),
         "conv2": _conv_init(ks[1], 3, 3, cout, cout), "gn2": _gn_init(cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(_gn(p["gn1"], _conv(x, p["conv1"], stride)))
    h = _gn(p["gn2"], _conv(h, p["conv2"]))
    sc = _conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def _stage_strides(num_stages, blocks_per_stage):
    return [[2 if (b == 0 and s > 0) else 1 for b in range(blocks_per_stage)]
            for s in range(num_stages)]


def init_params(key, num_classes: int = 10, width: int = 64,
                blocks_per_stage: int = 2):
    """ResNet18 when width=64, blocks_per_stage=2."""
    widths = [width, width * 2, width * 4, width * 8]
    ks = jax.random.split(key, 2 + 4 * blocks_per_stage)
    params = {"stem": _conv_init(ks[0], 3, 3, 3, width),
              "stem_gn": _gn_init(width), "stages": []}
    cin = width
    ki = 1
    for s, cout in enumerate(widths):
        stage = []
        for b in range(blocks_per_stage):
            stride = 2 if (b == 0 and s > 0) else 1
            stage.append(_block_init(ks[ki], cin, cout, stride))
            cin = cout
            ki += 1
        params["stages"].append(stage)
    params["fc"] = (jax.random.normal(ks[-1], (cin, num_classes)) *
                    (1.0 / cin) ** 0.5)
    params["fc_b"] = jnp.zeros((num_classes,))
    return params


def _head(params, x):
    x = x.mean(axis=(1, 2))
    return x @ params["fc"] + params["fc_b"]


def forward_train(params, images, policy: CompressionPolicy = NO_POLICY,
                  bstates: Optional[list] = None,
                  ids: Optional[jnp.ndarray] = None):
    """Returns (logits, new_fw_buffers).  Boundaries between the 4 stages."""
    if ids is None:
        ids = jnp.zeros((images.shape[0],), jnp.int32)
    x = jax.nn.relu(_gn(params["stem_gn"], _conv(images, params["stem"])))
    new_fw = []
    n = len(params["stages"])
    strides = _stage_strides(n, len(params["stages"][0]))
    for s, stage in enumerate(params["stages"]):
        for p, st_ in zip(stage, strides[s]):
            x = _block_apply(p, x, st_)
        if s < n - 1 and policy.num_boundaries > s:
            bp = policy.at(s)
            st = (bstates[s] if bstates is not None
                  else empty_boundary_state(x.dtype))
            x, nf = boundary_apply(bp, x, st["fw"], st["bw"], ids)
            new_fw.append(nf)
    return _head(params, x), new_fw


def forward_eval(params, images, policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True):
    x = jax.nn.relu(_gn(params["stem_gn"], _conv(images, params["stem"])))
    n = len(params["stages"])
    strides = _stage_strides(n, len(params["stages"][0]))
    for s, stage in enumerate(params["stages"]):
        for p, st_ in zip(stage, strides[s]):
            x = _block_apply(p, x, st_)
        if s < n - 1 and policy.num_boundaries > s:
            x = boundary_eval(policy.at(s), x, compress)
    return _head(params, x)


def boundary_shapes(width: int = 64, image: int = 32,
                    ) -> List[Tuple[int, ...]]:
    """Feature shapes at the 3 boundaries (for feedback buffer init)."""
    return [(image, image, width),
            (image // 2, image // 2, width * 2),
            (image // 4, image // 4, width * 4)]


# ---------------------------------------------------------------------------
# Homogeneous-stage variant for the REAL pipeline (transport/pipeline.py)
# ---------------------------------------------------------------------------
# SPMD ppermute pipelining runs ONE program on every device, so the boundary
# tensor (and the stage params pytree) must be identical across stages —
# unlike the width-doubling ResNet above.  This variant keeps a constant
# width/resolution through S stages of residual blocks; stem and head run
# outside the pipeline (replicated, single-device-cheap).

def init_pipeline_params(key, num_stages: int, num_classes: int = 10,
                         width: int = 16, blocks_per_stage: int = 2):
    """Stage params STACKED with leading dim ``num_stages``."""
    ks = jax.random.split(key, 2 + num_stages)
    params = {"stem": _conv_init(ks[0], 3, 3, 3, width),
              "stem_gn": _gn_init(width)}
    stages = []
    for s in range(num_stages):
        bks = jax.random.split(ks[1 + s], blocks_per_stage)
        stages.append({f"b{i}": _block_init(bks[i], width, width, 1)
                       for i in range(blocks_per_stage)})
    params["stages"] = jax.tree.map(lambda *a: jnp.stack(a), *stages)
    params["fc"] = (jax.random.normal(ks[-1], (width, num_classes)) *
                    (1.0 / width) ** 0.5)
    params["fc_b"] = jnp.zeros((num_classes,))
    return params


def pipeline_stage_apply(stage_params, x):
    """One homogeneous stage: ``blocks_per_stage`` width-preserving
    residual blocks.  Shape-preserving — the pipeline's ``stage_fn``."""
    for i in range(len(stage_params)):
        x = _block_apply(stage_params[f"b{i}"], x, 1)
    return x


def pipeline_stem(params, images):
    return jax.nn.relu(_gn(params["stem_gn"], _conv(images, params["stem"])))


def pipeline_head(params, x):
    return _head(params, x)


def pipeline_forward_eval(params, images, policy: CompressionPolicy = NO_POLICY,
                          compress: bool = True):
    """Single-device sequential eval of the pipeline model, applying the
    fw compressor between stages when ``compress`` (wire-equivalent: the
    codec round-trip equals C(x) — see transport/codecs.py).  With more
    stacked slices than the policy's boundary count (interleaved virtual
    stages), every cut still compresses — matching the SPMD wire, which
    runs the same uniform policy at all ``S*v - 1`` cuts."""
    x = pipeline_stem(params, images)
    n = params["stages"]["b0"]["conv1"].shape[0]
    for s in range(n):
        x = pipeline_stage_apply(
            jax.tree.map(lambda a: a[s], params["stages"]), x)
        if s < n - 1 and policy.num_boundaries > 0:
            x = boundary_eval(policy.at(min(s, policy.num_boundaries - 1)),
                              x, compress)
    return pipeline_head(params, x)
