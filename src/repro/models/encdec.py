"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The mel-spectrogram + conv feature extractor is the allowed stub:
``batch["enc_embeds"]`` carries precomputed frame embeddings
``(B, enc_seq, d)``.  Everything after that — bidirectional encoder, causal
decoder with cross-attention, compression boundaries between decoder stages —
is fully implemented.

Boundaries: the decoder stack is cut into ``policy.num_stages`` stages like
the decoder-only models; additionally the encoder->decoder memory handoff is
a real network crossing in MP deployments, so the fw compressor is applied to
the encoder output once (no feedback state — it is sent once per sequence).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.boundary import (boundary_apply, boundary_eval,
                                 empty_boundary_state,
                                 boundary_wire_eval)
from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.models import attention as A
from repro.models.common import (DTYPE, embed_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init, sinusoidal_pos)
from repro.models.config import ModelConfig
from repro.models.scan_config import scan_unroll
from repro.models.transformer import _lm_logits, segment_bounds
from repro.sharding.ctx import constrain


def _enc_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln1": norm_init(cfg.d_model, cfg.norm),
            "ln2": norm_init(cfg.d_model, cfg.norm),
            # encoder is bidirectional MHA (applied via cross_attn on itself)
            "attn": A.cross_attn_init(ks[0], cfg.d_model, cfg.num_heads,
                                      cfg.resolved_head_dim),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp)}


def _dec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p = _enc_block_init(ks[0], cfg)
    p["lnx"] = norm_init(cfg.d_model, cfg.norm)
    p["xattn"] = A.cross_attn_init(ks[1], cfg.d_model, cfg.num_heads,
                                   cfg.resolved_head_dim)
    return p


def init_params(key, cfg: ModelConfig, dtype=DTYPE):
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "dec_pos": (jax.random.normal(ks[3], (cfg.max_seq, cfg.d_model),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "enc_norm": norm_init(cfg.d_model, cfg.norm),
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }


def _attn_kw(cfg):
    return dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, pos_embed="none")


def encode(params, enc_embeds, cfg: ModelConfig):
    """enc_embeds: (B, T_enc, d) stub frontend output."""
    t = enc_embeds.shape[1]
    x = enc_embeds.astype(DTYPE) + sinusoidal_pos(t, cfg.d_model).astype(DTYPE)
    x = constrain(x, "batch", None, "model")

    def scan_fn(x, lp):
        xn = norm_apply(lp["ln1"], x, cfg.norm)
        # bidirectional: non-causal self attention via cross_attn on itself
        h = A.cross_attn(lp["attn"], xn, xn, num_heads=cfg.num_heads,
                         head_dim=cfg.resolved_head_dim)
        x = x + h
        x = x + mlp_apply(lp["mlp"], norm_apply(lp["ln2"], x, cfg.norm),
                          cfg.mlp)
        return x, None

    x, _ = jax.lax.scan(scan_fn, x, params["enc_layers"], unroll=scan_unroll())
    return norm_apply(params["enc_norm"], x, cfg.norm).astype(DTYPE)


def _dec_block(lp, x, memory, cfg, cache=None, pos=None, cache_len=0,
               mode="train"):
    # whisper is MHA throughout (kv == heads in the full config)
    kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
              head_dim=cfg.resolved_head_dim, pos_embed="abs")
    xn = norm_apply(lp["ln1"], x, cfg.norm)
    new_cache = cache
    if mode == "train":
        h = A.attn_train(lp["attn"], xn, **kw)
    elif mode == "prefill":
        h, new_cache = A.attn_prefill(lp["attn"], xn, cache_len=cache_len, **kw)
    else:
        h, new_cache = A.attn_decode(lp["attn"], xn, cache, pos, **kw)
    x = x + h
    x = x + A.cross_attn(lp["xattn"], norm_apply(lp["lnx"], x, cfg.norm),
                         memory, num_heads=cfg.num_heads,
                         head_dim=cfg.resolved_head_dim)
    x = x + mlp_apply(lp["mlp"], norm_apply(lp["ln2"], x, cfg.norm), cfg.mlp)
    return x, new_cache


def _embed_tokens(params, tokens, pos0: int = 0):
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(DTYPE)
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos0, s, 0)
    return x + pos.astype(x.dtype)


def forward_hidden(params, batch, cfg: ModelConfig,
                   policy: CompressionPolicy = NO_POLICY,
                   bstates: Optional[list] = None,
                   ids: Optional[jnp.ndarray] = None, remat: bool = True):
    """batch: {"enc_embeds": (B,T,d), "tokens": (B,S)}.
    Returns (hidden, aux, new_fw) — the train step computes the loss with
    the chunked hidden_lm_loss so the (B,S,V) logits never materialize
    (same large-vocab treatment as the decoder-only stack)."""
    memory = encode(params, batch["enc_embeds"], cfg)
    x = _embed_tokens(params, batch["tokens"])
    if ids is None:
        ids = jnp.zeros((x.shape[0],), jnp.int32)
    # enc->dec memory crossing: compress once (plain, no feedback)
    if policy.num_boundaries:
        memory = policy.at(0).fw(memory)
    segs = segment_bounds(cfg.num_layers, policy.num_stages)
    new_fw = []

    def block(x, lp):
        y, _ = _dec_block(lp, x, memory, cfg, mode="train")
        return constrain(y, "batch", "model", None), None
    if remat:
        block = jax.checkpoint(block)

    for si, (g0, g1) in enumerate(segs):
        seg = jax.tree.map(lambda a: a[g0:g1], params["dec_layers"])
        x, _ = jax.lax.scan(block, x, seg, unroll=scan_unroll())
        if si < len(segs) - 1:
            bp = policy.at(si)
            st = (bstates[si] if bstates is not None
                  else empty_boundary_state(x.dtype))
            x, nf = boundary_apply(bp, x, st["fw"], st["bw"], ids)
            new_fw.append(nf)
    return x, jnp.float32(0.0), new_fw


def forward_train(params, batch, cfg: ModelConfig,
                  policy: CompressionPolicy = NO_POLICY,
                  bstates: Optional[list] = None,
                  ids: Optional[jnp.ndarray] = None, remat: bool = True):
    x, aux, new_fw = forward_hidden(params, batch, cfg, policy, bstates,
                                    ids, remat)
    return _lm_logits(params, x, cfg), aux, new_fw


def forward_eval(params, batch, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY, compress: bool = True,
                 wire: bool = False):
    """``wire=True`` routes stage cuts (incl. the encoder-memory hop)
    through the wire-codec registry, as in transformer.forward_eval."""
    beval = boundary_wire_eval if wire else boundary_eval
    memory = encode(params, batch["enc_embeds"], cfg)
    if policy.num_boundaries:
        memory = beval(policy.at(0), memory, compress)
    x = _embed_tokens(params, batch["tokens"])
    segs = segment_bounds(cfg.num_layers, policy.num_stages)
    for si, (g0, g1) in enumerate(segs):
        seg = jax.tree.map(lambda a: a[g0:g1], params["dec_layers"])
        x, _ = jax.lax.scan(
            lambda x, lp: (constrain(_dec_block(lp, x, memory, cfg,
                                                mode="train")[0],
                           "batch", "model", None), None),
            x, seg, unroll=scan_unroll())
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    return _lm_logits(params, x, cfg)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=DTYPE):
    def one(_):
        return A.init_cache(batch, cache_len, cfg.num_heads,
                            cfg.resolved_head_dim, dtype)
    return jax.vmap(one)(jnp.arange(cfg.num_layers))


def prefill(params, batch, cfg: ModelConfig,
            policy: CompressionPolicy = NO_POLICY, cache_len: int = 0,
            compress: bool = True, pad_len=None, wire: bool = False):
    """Returns (last-token logits, (self_caches, memory)).

    ``pad_len`` is accepted for engine-API uniformity but must be zeros:
    the whisper decoder uses ABSOLUTE learned positions, so left-padding
    shifts real tokens to wrong position embeddings — a mask cannot fix
    that.  Serve enc-dec prompts start-aligned (equal decoder lengths).
    """
    beval = boundary_wire_eval if wire else boundary_eval
    memory = encode(params, batch["enc_embeds"], cfg)
    if policy.num_boundaries:
        memory = beval(policy.at(0), memory, compress)
    x = _embed_tokens(params, batch["tokens"])
    cache_len = cache_len or x.shape[1]
    segs = segment_bounds(cfg.num_layers, policy.num_stages)
    cache_segs = []
    for si, (g0, g1) in enumerate(segs):
        seg = jax.tree.map(lambda a: a[g0:g1], params["dec_layers"])

        def scan_fn(x, lp):
            y, c = _dec_block(lp, x, memory, cfg, cache_len=cache_len,
                              mode="prefill")
            # §Perf (whisper hillclimb): 12 heads / d=768 do not divide the
            # 16-way model axis, so without an explicit constraint the
            # partitioner REPLICATES the (B,S,S) attention work; sequence-
            # over-model keeps every q-chunk row-parallel (Megatron-SP).
            return constrain(y, "batch", "model", None), c
        x, cs = jax.lax.scan(scan_fn, x, seg, unroll=scan_unroll())
        cache_segs.append(cs)
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *cache_segs)
    return _lm_logits(params, x[:, -1:], cfg), (caches, memory)


def decode_step(params, token, state, pos, cfg: ModelConfig,
                policy: CompressionPolicy = NO_POLICY, compress: bool = True,
                pad_len=None, wire: bool = False):
    beval = boundary_wire_eval if wire else boundary_eval
    caches, memory = state
    x = params["embed"][token][:, None].astype(DTYPE) + \
        jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(DTYPE)
    segs = segment_bounds(cfg.num_layers, policy.num_stages)
    new_segs = []
    for si, (g0, g1) in enumerate(segs):
        seg = jax.tree.map(lambda a: a[g0:g1], params["dec_layers"])
        cseg = jax.tree.map(lambda a: a[g0:g1], caches)

        def scan_fn(x, lp_c):
            lp, c = lp_c
            y, nc = _dec_block(lp, x, memory, cfg, cache=c, pos=pos,
                               mode="decode")
            return y, nc
        x, nseg = jax.lax.scan(scan_fn, x, (seg, cseg), unroll=scan_unroll())
        new_segs.append(nseg)
        if si < len(segs) - 1:
            x = beval(policy.at(si), x, compress)
    new_caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *new_segs)
    return _lm_logits(params, x, cfg)[:, 0], (new_caches, memory)
