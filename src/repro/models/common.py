"""Shared model building blocks: norms, activations, RoPE, initializers.

Pure-JAX (no flax): params are nested dicts of jnp arrays; every module is an
``init(key, ...) -> params`` plus an ``apply(params, x, ...) -> y`` pair.
bf16 weights/activations by default, fp32 for norm statistics and softmax.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=DTYPE) -> jnp.ndarray:
    """Truncated-normal fan-in init (MaxText-style)."""
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim),
                                        jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def norm_apply(params, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, kind: str = "swiglu", dtype=DTYPE):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wi": dense_init(ks[0], d, ff, dtype),
                "wg": dense_init(ks[1], d, ff, dtype),
                "wo": dense_init(ks[2], ff, d, dtype)}
    return {"wi": dense_init(ks[0], d, ff, dtype),
            "wo": dense_init(ks[2], ff, d, dtype)}


def mlp_apply(params, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                               # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
