"""Global switch: unroll layer scans during lowering.

``lax.scan`` lowers to a while loop, and XLA's ``cost_analysis`` counts the
loop body ONCE (not x trip count), which would corrupt the roofline FLOP /
collective-byte terms.  The dry-run's roofline pass sets ``UNROLL = True``
so layer stacks unroll into straight-line HLO with exact costs; everything
else (training, smoke tests, multi-pod lowering-proof) keeps the compact
scanned form.

The inner chunk scan of linear-attention layers stays a loop either way;
its recurrence einsums are <10% of those layers' FLOPs (projections happen
outside the chunk loop) — noted in EXPERIMENTS.md §Roofline caveats.
"""
UNROLL = False


def scan_unroll():
    """Value for lax.scan(..., unroll=...)."""
    return True if UNROLL else 1
