"""Per-layer blocks for every architecture family.

Kinds:
  dense        — GQA attention + MLP (llama/glm/granite/starcoder2/pixtral)
  attn_local   — dense with sliding-window attention   (gemma2 even layers)
  attn_global  — dense with full attention             (gemma2 odd layers)
  moe          — GQA attention + MoE FFN               (mixtral, llama4)
  rwkv         — RWKV6 time-mix + channel-mix          (attention-free)
  hymba        — parallel GQA + Mamba2/SSD heads, then MLP

Uniform interface so the stack can `lax.scan` over layer groups:
  block_init(key, cfg, kind)                      -> params
  block_train(params, x, cfg, kind)               -> (y, aux)
  block_prefill(params, x, cfg, kind, cache_len)  -> (y, cache, aux)
  block_decode(params, x1, cache, pos, cfg, kind) -> (y, new_cache)
  block_cache(cfg, kind, batch, cache_len, dtype) -> cache pytree
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.common import (DTYPE, dense_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init)
from repro.models.config import ModelConfig
from repro.models.linattn import (chunked_linear_attention,
                                  linear_attention_decode)
from repro.models.moe import moe_apply, moe_init

RWKV_LORA = 32
RWKV_DECAY_LORA = 64
RWKV_HEAD = 64          # rwkv6 head size (K == V == 64)


# ===========================================================================
# Attention-family blocks (dense / attn_local / attn_global / moe)
# ===========================================================================

def _attn_kwargs(cfg: ModelConfig, kind: str):
    window = cfg.window
    if kind == "attn_local":
        window = cfg.window or 4096
    elif kind == "attn_global":
        window = None
    return dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, pos_embed=cfg.pos_embed,
                rope_theta=cfg.rope_theta, window=window,
                attn_softcap=cfg.attn_softcap)


def _attn_block_init(key, cfg: ModelConfig, moe: bool):
    ks = jax.random.split(key, 6)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm),
         "ln2": norm_init(cfg.d_model, cfg.norm),
         "attn": A.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                             cfg.num_kv_heads, cfg.resolved_head_dim)}
    if cfg.post_norm:
        p["pn1"] = norm_init(cfg.d_model, cfg.norm)
        p["pn2"] = norm_init(cfg.d_model, cfg.norm)
    if moe:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts,
                            cfg.mlp, cfg.num_shared_experts)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def _maybe_post(p, name, h, cfg):
    return norm_apply(p[name], h, cfg.norm) if cfg.post_norm else h


def _ffn(p, h, cfg: ModelConfig, moe: bool, dropless: bool = False):
    if moe:
        return moe_apply(p["moe"], h, num_experts=cfg.num_experts,
                         top_k=cfg.top_k, mlp_kind=cfg.mlp,
                         capacity_factor=cfg.capacity_factor,
                         dispatch_quant=cfg.moe_dispatch_quant,
                         dropless=dropless)
    return mlp_apply(p["mlp"], h, cfg.mlp), jnp.float32(0.0)


def _attn_block_train(p, x, cfg: ModelConfig, kind: str):
    moe = kind == "moe"
    h = A.attn_train(p["attn"], norm_apply(p["ln1"], x, cfg.norm),
                     **_attn_kwargs(cfg, kind))
    x = x + _maybe_post(p, "pn1", h, cfg)
    h, aux = _ffn(p, norm_apply(p["ln2"], x, cfg.norm), cfg, moe)
    x = x + _maybe_post(p, "pn2", h, cfg)
    return x, aux


# Block kinds whose weights shard over the tensor ring (dense family:
# heads over tp for attention, d_ff over tp for the MLP).  moe/rwkv/hymba
# route their parallelism differently (expert / head-state sharding) and
# stay off the compressed TP path.
TP_BLOCK_KINDS = ("dense", "attn_local", "attn_global")


def attn_block_train_tp(p, x, cfg: ModelConfig, kind: str, tpc,
                        bufs=(None, None)):
    """The dense-family block on a SEQUENCE-SHARDED residual ``x``
    (Megatron-SP layout): norms and residual adds run on the shard; the
    attention and MLP in-gathers cross the compressed tensor wire and
    the partial outputs reduce-scatter back (transport/tp_collectives.py).

    ``p`` holds the tp-local weight shards (see
    transformer.tp_param_dims).  ``bufs`` are this block's two per-site
    feedback buffers (attn gather, mlp gather) or Nones.
    """
    if kind not in TP_BLOCK_KINDS:
        raise ValueError(
            f"tensor parallelism covers the dense family "
            f"{TP_BLOCK_KINDS}, got kind={kind!r}")
    b1, b2 = bufs
    h, b1 = A.attn_train_tp(p["attn"], norm_apply(p["ln1"], x, cfg.norm),
                            tpc, buf=b1, **_attn_kwargs(cfg, kind))
    x = x + _maybe_post(p, "pn1", h, cfg)
    full, b2 = tpc.gather_site(norm_apply(p["ln2"], x, cfg.norm), b2)
    h = tpc.scatter(mlp_apply(p["mlp"], full, cfg.mlp))
    x = x + _maybe_post(p, "pn2", h, cfg)
    return x, (b1, b2)


def _attn_block_prefill(p, x, cfg: ModelConfig, kind: str, cache_len: int,
                        pad_mask=None):
    moe = kind == "moe"
    h, cache = A.attn_prefill(p["attn"], norm_apply(p["ln1"], x, cfg.norm),
                              cache_len=cache_len, pad_mask=pad_mask,
                              **_attn_kwargs(cfg, kind))
    x = x + _maybe_post(p, "pn1", h, cfg)
    # inference: dropless routing so decode continuations match prefill
    h, aux = _ffn(p, norm_apply(p["ln2"], x, cfg.norm), cfg, moe,
                  dropless=True)
    x = x + _maybe_post(p, "pn2", h, cfg)
    return x, cache, aux


def _attn_block_decode(p, x1, cache, pos, cfg: ModelConfig, kind: str,
                       pad_len=None):
    moe = kind == "moe"
    h, cache = A.attn_decode(p["attn"], norm_apply(p["ln1"], x1, cfg.norm),
                             cache, pos, pad_len=pad_len,
                             **_attn_kwargs(cfg, kind))
    x1 = x1 + _maybe_post(p, "pn1", h, cfg)
    h, _ = _ffn(p, norm_apply(p["ln2"], x1, cfg.norm), cfg, moe)
    x1 = x1 + _maybe_post(p, "pn2", h, cfg)
    return x1, cache


def _attn_block_decode_span(p, x, cache, pos, cfg: ModelConfig, kind: str,
                            pad_len=None, page_map=None, valid_len=None):
    """Multi-token decode (chunked prefill / speculative verify): x is
    (B, T, d) at positions ``pos[b]+arange(T)``.  MoE routes densely
    (``dropless``), exactly like the T=1 decode path (``s==1`` in
    moe_apply) — span and per-token decode see the same expert math."""
    moe = kind == "moe"
    h, cache = A.attn_decode_span(
        p["attn"], norm_apply(p["ln1"], x, cfg.norm), cache, pos,
        pad_len=pad_len, page_map=page_map, valid_len=valid_len,
        **_attn_kwargs(cfg, kind))
    x = x + _maybe_post(p, "pn1", h, cfg)
    h, _ = _ffn(p, norm_apply(p["ln2"], x, cfg.norm), cfg, moe,
                dropless=True)
    x = x + _maybe_post(p, "pn2", h, cfg)
    return x, cache


def _attn_block_cache(cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int, dtype):
    kw = _attn_kwargs(cfg, kind)
    c = cache_len if kw["window"] is None else min(kw["window"], cache_len)
    return A.init_cache(batch, c, cfg.num_kv_heads, cfg.resolved_head_dim,
                        dtype)


# ===========================================================================
# RWKV6 (Finch) block
# ===========================================================================

def _rwkv_heads(cfg: ModelConfig) -> Tuple[int, int]:
    hs = cfg.ssm_state or RWKV_HEAD
    assert cfg.d_model % hs == 0
    return cfg.d_model // hs, hs            # (H, head_size)


def _rwkv_block_init(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    h, hs = _rwkv_heads(cfg)
    ks = jax.random.split(key, 12)
    f32 = jnp.float32
    # decay base: spread in [-6, -0.3] across channels (rwkv init)
    dec = -6.0 + 5.7 * (jnp.arange(d, dtype=f32) / max(d - 1, 1)) ** 1.3
    return {
        "ln1": norm_init(d, cfg.norm), "ln2": norm_init(d, cfg.norm),
        "tm": {
            "mu_x": jnp.full((d,), 0.5, f32),
            "mu": jnp.full((5, d), 0.5, f32),                  # r,k,v,g,w
            "lora_A": dense_init(ks[0], d, 5 * RWKV_LORA, f32),
            "lora_B": (jax.random.normal(ks[1], (5, RWKV_LORA, d), f32)
                       * 0.01),
            "wr": dense_init(ks[2], d, d), "wk": dense_init(ks[3], d, d),
            "wv": dense_init(ks[4], d, d), "wg": dense_init(ks[5], d, d),
            "wo": dense_init(ks[6], d, d),
            "w0": dec,
            "w_lora_A": dense_init(ks[7], d, RWKV_DECAY_LORA, f32),
            "w_lora_B": (jax.random.normal(ks[8], (RWKV_DECAY_LORA, d), f32)
                         * 0.01),
            "u": jax.random.normal(ks[9], (h, hs), f32) * 0.1,
            "gn_scale": jnp.ones((d,), f32),
            "gn_bias": jnp.zeros((d,), f32),
        },
        "cm": {
            "mu_k": jnp.full((d,), 0.5, f32),
            "mu_r": jnp.full((d,), 0.5, f32),
            "wk": dense_init(ks[10], d, ff),
            "wv": dense_init(ks[11], ff, d),
            "wr": dense_init(ks[0], d, d),
        },
    }


def _shift(x, state):
    """x: (B,S,d); state: (B,d) previous token (zeros at start)."""
    return jnp.concatenate([state[:, None], x[:, :-1]], axis=1)


def _rwkv_time_mix(tm, x, sx, cfg: ModelConfig, state, decode: bool):
    """x: (B,S,d); sx: shifted x; state: (B,H,K,V)."""
    b, s, d = x.shape
    h, hs = _rwkv_heads(cfg)
    xf = x.astype(jnp.float32)
    dx = sx.astype(jnp.float32) - xf
    xx = xf + dx * tm["mu_x"]
    lora = jnp.tanh(xx @ tm["lora_A"]).reshape(b, s, 5, RWKV_LORA)
    delta = jnp.einsum("bsfr,frd->bsfd", lora, tm["lora_B"])    # (B,S,5,d)
    mixed = xf[:, :, None] + dx[:, :, None] * (tm["mu"] + delta)
    xr, xk, xv, xg, xw = (mixed[:, :, i].astype(x.dtype) for i in range(5))

    r = (xr @ tm["wr"]).reshape(b, s, h, hs).transpose(0, 2, 1, 3)
    k = (xk @ tm["wk"]).reshape(b, s, h, hs).transpose(0, 2, 1, 3)
    v = (xv @ tm["wv"]).reshape(b, s, h, hs).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ tm["wg"])
    log_w = -jnp.exp(tm["w0"]
                     + jnp.tanh(xw.astype(jnp.float32) @ tm["w_lora_A"])
                     @ tm["w_lora_B"])                          # (B,S,d) <= 0
    log_w = log_w.reshape(b, s, h, hs).transpose(0, 2, 1, 3)

    if decode:
        y, new_state = linear_attention_decode(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], log_w[:, :, 0], state,
            bonus=tm["u"])
        y = y[:, :, None].transpose(0, 2, 1, 3)                 # (B,1,H,V)
    else:
        y, new_state = chunked_linear_attention(
            r, k, v, log_w, bonus=tm["u"], initial_state=state)
        y = y.transpose(0, 2, 1, 3)                             # (B,S,H,V)

    # per-head group norm
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf.reshape(b, -1, d) * tm["gn_scale"] + tm["gn_bias"]
    out = (yf.astype(x.dtype) * g) @ tm["wo"]
    return out, new_state


def _rwkv_channel_mix(cm, x, sx):
    xf = x.astype(jnp.float32)
    dx = sx.astype(jnp.float32) - xf
    xk = (xf + dx * cm["mu_k"]).astype(x.dtype)
    xr = (xf + dx * cm["mu_r"]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ cm["wk"]))
    return jax.nn.sigmoid(xr @ cm["wr"]) * (kk @ cm["wv"])


def _rwkv_block_train(p, x, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    h, hs = _rwkv_heads(cfg)
    if state is None:
        state = _rwkv_block_cache(cfg, "rwkv", b, 0, x.dtype)
    xn = norm_apply(p["ln1"], x, cfg.norm)
    h_out, new_s = _rwkv_time_mix(p["tm"], xn, _shift(xn, state["tm"]),
                                  cfg, state["S"], decode=False)
    x = x + h_out
    xn2 = norm_apply(p["ln2"], x, cfg.norm)
    x = x + _rwkv_channel_mix(p["cm"], xn2, _shift(xn2, state["cm"]))
    new_cache = {"S": new_s, "tm": xn[:, -1], "cm": xn2[:, -1]}
    return x, new_cache


def _rwkv_block_decode(p, x1, cache, pos, cfg: ModelConfig):
    xn = norm_apply(p["ln1"], x1, cfg.norm)
    h_out, new_s = _rwkv_time_mix(p["tm"], xn, cache["tm"][:, None], cfg,
                                  cache["S"], decode=True)
    x1 = x1 + h_out
    xn2 = norm_apply(p["ln2"], x1, cfg.norm)
    x1 = x1 + _rwkv_channel_mix(p["cm"], xn2, cache["cm"][:, None])
    return x1, {"S": new_s, "tm": xn[:, 0], "cm": xn2[:, 0]}


def _rwkv_block_cache(cfg: ModelConfig, kind, batch, cache_len, dtype):
    h, hs = _rwkv_heads(cfg)
    return {"S": jnp.zeros((batch, h, hs, hs), jnp.float32),
            "tm": jnp.zeros((batch, cfg.d_model), dtype),
            "cm": jnp.zeros((batch, cfg.d_model), dtype)}


# ===========================================================================
# Hymba block: parallel GQA attention + Mamba2/SSD heads
# ===========================================================================

def _hymba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    hd = cfg.resolved_head_dim
    nh = cfg.ssm_heads or cfg.num_heads
    return nh, hd, cfg.ssm_state or 16       # (ssm heads, head dim, state N)


def _hymba_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    nh, hd, n = _hymba_dims(cfg)
    sd = nh * hd
    ks = jax.random.split(key, 8)
    f32 = jnp.float32
    p = _attn_block_init(ks[0], cfg, moe=False)
    p["ssm"] = {
        "in_proj": dense_init(ks[1], d, 2 * sd),
        "w_dt": dense_init(ks[2], d, nh, f32),
        "dt_bias": jnp.zeros((nh,), f32),
        "w_b": dense_init(ks[3], d, n),
        "w_c": dense_init(ks[4], d, n),
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, nh)),           # decay rates
        "d_skip": jnp.ones((nh,), f32),
        "out_proj": dense_init(ks[5], sd, d),
    }
    p["ln_attn_out"] = norm_init(d, cfg.norm)
    p["ln_ssm_out"] = norm_init(d, cfg.norm)
    return p


def _ssd_project(ssm, x, cfg):
    b, s, d = x.shape
    nh, hd, n = _hymba_dims(cfg)
    xz = x @ ssm["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                           # (B,S,sd)
    dt = jax.nn.softplus(x.astype(jnp.float32) @ ssm["w_dt"]
                         + ssm["dt_bias"])                      # (B,S,H)
    log_w = -jnp.exp(ssm["a_log"]) * dt                         # (B,S,H)
    bb = (x @ ssm["w_b"]).astype(jnp.float32)                   # (B,S,N)
    cc = (x @ ssm["w_c"]).astype(jnp.float32)                   # (B,S,N)
    xh = xs.reshape(b, s, nh, hd).astype(jnp.float32)
    v = xh * dt[..., None]                                      # dt-scaled
    return xs, z, v, bb, cc, log_w, dt, xh


def _hymba_ssm_train(ssm, x, cfg, state):
    b, s, d = x.shape
    nh, hd, n = _hymba_dims(cfg)
    xs, z, v, bb, cc, log_w, dt, xh = _ssd_project(ssm, x, cfg)
    q = jnp.broadcast_to(cc[:, None], (b, nh, s, n))
    k = jnp.broadcast_to(bb[:, None], (b, nh, s, n))
    vv = v.transpose(0, 2, 1, 3)                                # (B,H,S,hd)
    w = jnp.broadcast_to(log_w.transpose(0, 2, 1)[..., None], (b, nh, s, n))
    y, new_state = chunked_linear_attention(q, k, vv, w, initial_state=state)
    y = y.transpose(0, 2, 1, 3) + ssm["d_skip"][None, None, :, None] * xh
    y = (y.reshape(b, s, nh * hd)).astype(x.dtype) * jax.nn.silu(z)
    return y @ ssm["out_proj"], new_state


def _hymba_ssm_decode(ssm, x1, cfg, state):
    b = x1.shape[0]
    nh, hd, n = _hymba_dims(cfg)
    xs, z, v, bb, cc, log_w, dt, xh = _ssd_project(ssm, x1, cfg)
    q = jnp.broadcast_to(cc[:, 0, None], (b, nh, n))
    k = jnp.broadcast_to(bb[:, 0, None], (b, nh, n))
    vv = v[:, 0]                                                # (B,H,hd)
    w = jnp.broadcast_to(log_w[:, 0, :, None], (b, nh, n))
    y, new_state = linear_attention_decode(q, k, vv, w, state)
    y = y[:, None] + ssm["d_skip"][None, None, :, None] * xh
    y = (y.reshape(b, 1, nh * hd)).astype(x1.dtype) * jax.nn.silu(z)
    return y @ ssm["out_proj"], new_state


def _hymba_block_train(p, x, cfg: ModelConfig, state=None):
    b = x.shape[0]
    if state is None:
        state = _hymba_block_cache(cfg, "hymba", b, 0, x.dtype)["ssm"]
    xn = norm_apply(p["ln1"], x, cfg.norm)
    h_attn = A.attn_train(p["attn"], xn, **_attn_kwargs(cfg, "dense"))
    h_ssm, new_s = _hymba_ssm_train(p["ssm"], xn, cfg, state)
    h = 0.5 * (norm_apply(p["ln_attn_out"], h_attn, cfg.norm)
               + norm_apply(p["ln_ssm_out"], h_ssm, cfg.norm))
    x = x + h
    x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg.norm), cfg.mlp)
    return x, new_s


def _hymba_block_decode(p, x1, cache, pos, cfg: ModelConfig):
    xn = norm_apply(p["ln1"], x1, cfg.norm)
    h_attn, new_kv = A.attn_decode(p["attn"], xn, {"k": cache["k"],
                                                   "v": cache["v"]},
                                   pos, **_attn_kwargs(cfg, "dense"))
    h_ssm, new_s = _hymba_ssm_decode(p["ssm"], xn, cfg, cache["ssm"])
    h = 0.5 * (norm_apply(p["ln_attn_out"], h_attn, cfg.norm)
               + norm_apply(p["ln_ssm_out"], h_ssm, cfg.norm))
    x1 = x1 + h
    x1 = x1 + mlp_apply(p["mlp"], norm_apply(p["ln2"], x1, cfg.norm), cfg.mlp)
    return x1, {"k": new_kv["k"], "v": new_kv["v"], "ssm": new_s}


def _hymba_block_cache(cfg: ModelConfig, kind, batch, cache_len, dtype):
    nh, hd, n = _hymba_dims(cfg)
    c = {"ssm": jnp.zeros((batch, nh, n, hd), jnp.float32)}
    if cache_len:
        c.update(_attn_block_cache(cfg, "dense", batch, cache_len, dtype))
    return c


# ===========================================================================
# Dispatch
# ===========================================================================

ATTN_KINDS = ("dense", "attn_local", "attn_global", "moe")


def block_init(key, cfg: ModelConfig, kind: str):
    if kind in ATTN_KINDS:
        return _attn_block_init(key, cfg, moe=(kind == "moe"))
    if kind == "rwkv":
        return _rwkv_block_init(key, cfg)
    if kind == "hymba":
        return _hymba_block_init(key, cfg)
    raise ValueError(kind)


def block_train(p, x, cfg: ModelConfig, kind: str):
    """Returns (y, aux_loss).  Recurrent kinds start from zero state."""
    if kind in ATTN_KINDS:
        return _attn_block_train(p, x, cfg, kind)
    if kind == "rwkv":
        y, _ = _rwkv_block_train(p, x, cfg)
        return y, jnp.float32(0.0)
    if kind == "hymba":
        y, _ = _hymba_block_train(p, x, cfg)
        return y, jnp.float32(0.0)
    raise ValueError(kind)


def block_prefill(p, x, cfg: ModelConfig, kind: str, cache_len: int,
                  pad_mask=None):
    """``pad_mask``: (B, S) bool, True = real token — masks left-padding
    out of attention (serving).  Recurrent kinds (rwkv, hymba's SSM) carry
    state through pad positions and do not support left-padding."""
    if kind in ATTN_KINDS:
        return _attn_block_prefill(p, x, cfg, kind, cache_len, pad_mask)
    if kind == "rwkv":
        y, cache = _rwkv_block_train(p, x, cfg)
        return y, cache, jnp.float32(0.0)
    if kind == "hymba":
        b = x.shape[0]
        state = _hymba_block_cache(cfg, kind, b, 0, x.dtype)["ssm"]
        xn = norm_apply(p["ln1"], x, cfg.norm)
        h_attn, kv = A.attn_prefill(p["attn"], xn, cache_len=cache_len,
                                    pad_mask=pad_mask,
                                    **_attn_kwargs(cfg, "dense"))
        h_ssm, new_s = _hymba_ssm_train(p["ssm"], xn, cfg, state)
        h = 0.5 * (norm_apply(p["ln_attn_out"], h_attn, cfg.norm)
                   + norm_apply(p["ln_ssm_out"], h_ssm, cfg.norm))
        x = x + h
        x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg.norm), cfg.mlp)
        return x, {"k": kv["k"], "v": kv["v"], "ssm": new_s}, jnp.float32(0.0)
    raise ValueError(kind)


def block_decode(p, x1, cache, pos, cfg: ModelConfig, kind: str,
                 pad_len=None):
    """``pad_len``: (B,) int32 — cache slots before it are left-padding
    (attention kinds only; see block_prefill)."""
    if kind in ATTN_KINDS:
        return _attn_block_decode(p, x1, cache, pos, cfg, kind, pad_len)
    if kind == "rwkv":
        return _rwkv_block_decode(p, x1, cache, pos, cfg)
    if kind == "hymba":
        return _hymba_block_decode(p, x1, cache, pos, cfg)
    raise ValueError(kind)


def block_decode_span(p, x, cache, pos, cfg: ModelConfig, kind: str,
                      pad_len=None, page_map=None, valid_len=None):
    """Multi-token decode over a slab or paged KV cache (see
    attention.attn_decode_span).  Attention kinds only: recurrent state
    (rwkv, hymba) cannot jump to per-slot absolute positions."""
    if kind in ATTN_KINDS:
        return _attn_block_decode_span(p, x, cache, pos, cfg, kind,
                                       pad_len, page_map, valid_len)
    raise ValueError(f"block_decode_span: unsupported kind {kind!r} "
                     "(attention-family layers only)")


def block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                dtype=DTYPE):
    if kind in ATTN_KINDS:
        return _attn_block_cache(cfg, kind, batch, cache_len, dtype)
    if kind == "rwkv":
        return _rwkv_block_cache(cfg, kind, batch, cache_len, dtype)
    if kind == "hymba":
        return _hymba_block_cache(cfg, kind, batch, cache_len, dtype)
    raise ValueError(kind)
