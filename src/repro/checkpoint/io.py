"""Checkpointing: pytree <-> flat-npz, with step metadata.

No orbax offline; .npz keeps it dependency-free and deterministic.  Keys are
"/"-joined pytree paths; dtypes (incl. bf16 via uint16 view) round-trip
exactly.

Two formats share the machinery:

  * params-only  — ``save(path, params)``: flat keys ``embed/...`` (what
    PR-0..3 trainers wrote; serve-time restore still reads it).
  * train-state  — ``save_train_state(path, ...)``: one tree
    ``{"params", "opt", "bstates"}`` covering the model, optimizer moments,
    and the boundary feedback buffers, so ``--resume`` reproduces the exact
    training trajectory (error-feedback state is part of the trajectory).

``restore`` restores the subset of keys named by ``like`` — extra keys in
the file are ignored (that is how ``restore_params`` pulls just the params
out of a train-state file).  Missing or shape-mismatched keys raise a
:class:`CheckpointMismatch` listing every missing, extra, and mismatched
key at once, instead of dying on the first bad leaf.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp


class CheckpointMismatch(ValueError):
    """The checkpoint's keys/shapes do not cover the requested pytree."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[key + "@bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save(path: str, tree, step: int = 0, extra: dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    np.savez(path, __meta__=json.dumps(meta), **flat)


def _load_flat(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat = {}
    for k in data.files:
        if k == "__meta__":
            continue
        if k.endswith("@bf16"):
            flat[k[:-5]] = data[k].view(jnp.bfloat16)
        else:
            flat[k] = data[k]
    return flat, meta


def restore(path: str, like, strict: bool = False) -> Tuple[Any, int]:
    """Restore into the structure of ``like``.

    By default ``like`` may name a SUBSET of the saved keys (extras are
    ignored — how ``restore_params`` pulls params out of a train-state
    file); ``strict=True`` additionally requires ``like`` to consume the
    WHOLE file (train-state resume: a leftover key means the run being
    resumed was configured differently, and silently dropping its state —
    e.g. feedback buffers under different ``--stages`` — would fake an
    exact resume).  A key of ``like`` that is missing from the file, or
    whose stored shape differs, raises :class:`CheckpointMismatch`
    listing ALL missing / extra / shape-mismatched keys.
    """
    flat, meta = _load_flat(path)
    leaves_like, _ = jax.tree_util.tree_flatten_with_path(like)
    wanted, missing, mismatched, leaves = set(), [], [], []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        wanted.add(key)
        arr = flat.get(key)
        if arr is None:
            missing.append(key)
        elif arr.shape != leaf.shape:
            mismatched.append(f"{key}: saved {arr.shape} != "
                              f"expected {leaf.shape}")
        else:
            leaves.append(jnp.asarray(arr))
    extra_found = sorted(set(flat) - wanted)
    if missing or mismatched or (strict and extra_found):
        extra = extra_found

        def fmt(label, items, limit=8):
            if not items:
                return f"  {label}: none"
            shown = ", ".join(items[:limit])
            more = f" (+{len(items) - limit} more)" if len(items) > limit \
                else ""
            return f"  {label} ({len(items)}): {shown}{more}"

        raise CheckpointMismatch(
            f"checkpoint {path!r} does not match the requested pytree:\n"
            + fmt("missing keys", sorted(missing)) + "\n"
            + fmt("shape mismatches", mismatched) + "\n"
            + fmt("extra keys in file", extra)
            + "\n(params-only vs train-state format? see "
            "checkpoint/io.py docstring)")
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, meta["step"]


# ---------------------------------------------------------------------------
# Train-state format: params + optimizer moments + feedback buffers
# ---------------------------------------------------------------------------

def save_train_state(path: str, params, opt_state, bstates, step: int = 0,
                     extra: dict = None, dp_state=None) -> None:
    """One file covering everything ``--resume`` needs (see module doc).

    ``dp_state``: the data-parallel gradient-reduce state
    (:func:`repro.transport.collectives.init_dp_state` — per-replica
    EF/EF21 residuals and the EF21 aggregate).  Like the boundary
    feedback buffers it is part of the training trajectory, so a dp run's
    exact resume must restore it; saved under a ``dp`` key only when
    given, keeping non-dp files byte-compatible with the PR-4 format.
    """
    extra = dict(extra or {})
    extra["format"] = "train-state"
    tree = {"params": params, "opt": opt_state, "bstates": bstates}
    if dp_state is not None:
        tree["dp"] = dp_state
    save(path, tree, step=step, extra=extra)


def restore_train_state(path: str, params_like, opt_like, bstates_like,
                        dp_like=None) -> Tuple[Any, ...]:
    """Strict: the file must match the expected state EXACTLY — leftover
    keys mean the checkpointed run used a different configuration (more
    boundaries, another optimizer, a dp run resumed without --dp), and
    resuming minus that state would not reproduce its trajectory.

    Returns ``(params, opt, bstates, step)``, or
    ``(params, opt, bstates, dp_state, step)`` when ``dp_like`` is given.
    """
    like = {"params": params_like, "opt": opt_like, "bstates": bstates_like}
    if dp_like is not None:
        like["dp"] = dp_like
    state, step = restore(path, like, strict=True)
    if dp_like is not None:
        return (state["params"], state["opt"], state["bstates"],
                state["dp"], step)
    return state["params"], state["opt"], state["bstates"], step


def restore_params(path: str, params_like) -> Tuple[Any, int]:
    """Restore just the model params from EITHER format (serve-time)."""
    flat, _ = _load_flat(path)
    if any(k == "params" or k.startswith("params/") for k in flat):
        state, step = restore(path, {"params": params_like})
        return state["params"], step
    return restore(path, params_like)
