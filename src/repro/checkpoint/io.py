"""Checkpointing: pytree <-> flat-npz, with step metadata.

No orbax offline; .npz keeps it dependency-free and deterministic.  Keys are
"/"-joined pytree paths; dtypes (incl. bf16 via uint16 view) round-trip
exactly.

Two formats share the machinery:

  * params-only  — ``save(path, params)``: flat keys ``embed/...`` (what
    PR-0..3 trainers wrote; serve-time restore still reads it).
  * train-state  — ``save_train_state(path, ...)``: one tree
    ``{"params", "opt", "feedback": {"boundary", ["dp"]}}`` covering the
    model, optimizer moments, and every feedback thread (boundary
    fw/bw :class:`~repro.core.feedback.FeedbackState` list + the optional
    DP gradient-reduce state), so ``--resume`` reproduces the exact
    training trajectory (error-feedback state is part of the trajectory).
    Files written by the older ``bstates``/``dp`` layout are migrated on
    restore — key remap only, arrays untouched, so the resume is bitwise.

``restore`` restores the subset of keys named by ``like`` — extra keys in
the file are ignored (that is how ``restore_params`` pulls just the params
out of a train-state file).  Missing or shape-mismatched keys raise a
:class:`CheckpointMismatch` listing every missing, extra, and mismatched
key at once, instead of dying on the first bad leaf.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp


class CheckpointMismatch(ValueError):
    """The checkpoint's keys/shapes do not cover the requested pytree."""


def _path_key(p) -> str:
    """One path entry -> its key string.  DictKey carries ``.key``,
    GetAttrKey (registered dataclasses like FeedbackState) ``.name``,
    SequenceKey ``.idx`` — and ``.idx`` may be 0, so test against None."""
    for attr in ("key", "name", "idx"):
        v = getattr(p, attr, None)
        if v is not None:
            return str(v)
    return str(p)


def _tree_key(path) -> str:
    return "/".join(_path_key(p) for p in path)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _tree_key(path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[key + "@bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save(path: str, tree, step: int = 0, extra: dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    np.savez(path, __meta__=json.dumps(meta), **flat)


def _load_flat(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat = {}
    for k in data.files:
        if k == "__meta__":
            continue
        if k.endswith("@bf16"):
            flat[k[:-5]] = data[k].view(jnp.bfloat16)
        else:
            flat[k] = data[k]
    return flat, meta


def restore(path: str, like, strict: bool = False) -> Tuple[Any, int]:
    """Restore into the structure of ``like``.

    By default ``like`` may name a SUBSET of the saved keys (extras are
    ignored — how ``restore_params`` pulls params out of a train-state
    file); ``strict=True`` additionally requires ``like`` to consume the
    WHOLE file (train-state resume: a leftover key means the run being
    resumed was configured differently, and silently dropping its state —
    e.g. feedback buffers under different ``--stages`` — would fake an
    exact resume).  A key of ``like`` that is missing from the file, or
    whose stored shape differs, raises :class:`CheckpointMismatch`
    listing ALL missing / extra / shape-mismatched keys.
    """
    flat, meta = _load_flat(path)
    return _restore_from_flat(path, flat, meta, like, strict)


def _restore_from_flat(path: str, flat, meta, like,
                       strict: bool) -> Tuple[Any, int]:
    leaves_like, _ = jax.tree_util.tree_flatten_with_path(like)
    wanted, missing, mismatched, leaves = set(), [], [], []
    for path_, leaf in leaves_like:
        key = _tree_key(path_)
        wanted.add(key)
        arr = flat.get(key)
        if arr is None:
            missing.append(key)
        elif arr.shape != leaf.shape:
            mismatched.append(f"{key}: saved {arr.shape} != "
                              f"expected {leaf.shape}")
        else:
            leaves.append(jnp.asarray(arr))
    extra_found = sorted(set(flat) - wanted)
    if missing or mismatched or (strict and extra_found):
        extra = extra_found

        def fmt(label, items, limit=8):
            if not items:
                return f"  {label}: none"
            shown = ", ".join(items[:limit])
            more = f" (+{len(items) - limit} more)" if len(items) > limit \
                else ""
            return f"  {label} ({len(items)}): {shown}{more}"

        raise CheckpointMismatch(
            f"checkpoint {path!r} does not match the requested pytree:\n"
            + fmt("missing keys", sorted(missing)) + "\n"
            + fmt("shape mismatches", mismatched) + "\n"
            + fmt("extra keys in file", extra)
            + "\n(params-only vs train-state format? see "
            "checkpoint/io.py docstring)")
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, meta["step"]


# ---------------------------------------------------------------------------
# Train-state format: params + optimizer moments + feedback buffers
# ---------------------------------------------------------------------------

def save_train_state(path: str, params, opt_state, bstates, step: int = 0,
                     extra: dict = None, dp_state=None) -> None:
    """One file covering everything ``--resume`` needs (see module doc).

    Every feedback thread lives under one ``feedback`` key:
    ``feedback/boundary`` holds the per-boundary fw/bw
    :class:`~repro.core.feedback.FeedbackState` list and ``feedback/dp``
    (present only for dp runs) the data-parallel gradient-reduce state
    (:func:`repro.transport.collectives.init_dp_state` — per-replica
    EF/EF21 residuals and the EF21 aggregate).  All of it is part of the
    training trajectory, so an exact resume must restore it.
    """
    extra = dict(extra or {})
    extra["format"] = "train-state"
    feedback = {"boundary": bstates}
    if dp_state is not None:
        feedback["dp"] = dp_state
    tree = {"params": params, "opt": opt_state, "feedback": feedback}
    save(path, tree, step=step, extra=extra)


_LEGACY_BSTATE_RE = re.compile(r"^bstates/(.+?)(?:/(send|recv))?$")


def _migrate_legacy_feedback(flat):
    """PR-4/PR-5 era key layout -> the unified ``feedback`` schema.

    Old files stored boundary buffers under ``bstates/...`` (simulated:
    raw per-direction arrays; pipeline: ``{"send", "recv"}`` dicts) and
    the DP reduce state under ``dp/...``.  The remap is key-only — every
    stored array passes through untouched, so a migrated restore is
    bitwise identical to one from the era that wrote the file.
    """
    out = {}
    for k, v in flat.items():
        if k == "dp" or k.startswith("dp/"):
            out["feedback/" + k] = v
        elif k.startswith("bstates/"):
            m = _LEGACY_BSTATE_RE.match(k)
            leaf = {"send": "resid", "recv": "mirror", None: "resid"}
            out[f"feedback/boundary/{m.group(1)}/{leaf[m.group(2)]}"] = v
        else:
            out[k] = v
    return out


def restore_train_state(path: str, params_like, opt_like, bstates_like,
                        dp_like=None) -> Tuple[Any, ...]:
    """Strict: the file must match the expected state EXACTLY — leftover
    keys mean the checkpointed run used a different configuration (more
    boundaries, another optimizer, a dp run resumed without --dp), and
    resuming minus that state would not reproduce its trajectory.

    Files in the pre-``feedback`` layout are migrated transparently (see
    :func:`_migrate_legacy_feedback`); the restored arrays are bitwise
    identical either way.

    Returns ``(params, opt, bstates, step)``, or
    ``(params, opt, bstates, dp_state, step)`` when ``dp_like`` is given.
    """
    like = {"params": params_like, "opt": opt_like,
            "feedback": {"boundary": bstates_like}}
    if dp_like is not None:
        like["feedback"]["dp"] = dp_like
    flat, meta = _load_flat(path)
    legacy = (not any(k.startswith("feedback/") for k in flat)
              and any(k == "dp" or k.startswith(("bstates/", "dp/"))
                      for k in flat))
    if legacy:
        flat = _migrate_legacy_feedback(flat)
        # Legacy files predate FeedbackState, so its always-present
        # size-0 leaves (mirror/agg without a receiver copy) have no
        # stored key — synthesize the empty arrays; stored data is
        # never touched.
        for path_, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            key = _tree_key(path_)
            if key not in flat and leaf.size == 0:
                flat[key] = np.zeros(leaf.shape, leaf.dtype)
    state, step = _restore_from_flat(path, flat, meta, like, strict=True)
    bstates = state["feedback"]["boundary"]
    if dp_like is not None:
        return (state["params"], state["opt"], bstates,
                state["feedback"]["dp"], step)
    return state["params"], state["opt"], bstates, step


def restore_params(path: str, params_like) -> Tuple[Any, int]:
    """Restore just the model params from EITHER format (serve-time)."""
    flat, _ = _load_flat(path)
    if any(k == "params" or k.startswith("params/") for k in flat):
        state, step = restore(path, {"params": params_like})
        return state["params"], step
    return restore(path, params_like)
