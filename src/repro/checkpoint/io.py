"""Checkpointing: pytree <-> flat-npz, with step metadata.

No orbax offline; .npz keeps it dependency-free and deterministic.  Keys are
"/"-joined pytree paths; dtypes (incl. bf16 via uint16 view) round-trip
exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[key + "@bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save(path: str, tree, step: int = 0, extra: dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    np.savez(path, __meta__=json.dumps(meta), **flat)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (same pytree as saved)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat = {}
    for k in data.files:
        if k == "__meta__":
            continue
        if k.endswith("@bf16"):
            flat[k[:-5]] = data[k].view(jnp.bfloat16)
        else:
            flat[k] = data[k]
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, meta["step"]
