"""Prefix-sharing paged KV: refcounted page table + device page pool.

The PR-4 cache gave every serving slot a private, contiguous ``max_seq``
slab.  Real fleets see thousands of concurrent requests sharing a common
system-prompt prefix, so this module replaces the slab with a PAGE POOL
(modeled on MaxText's ``page_manager.PageState``):

  * every cache leaf is laid out ``(groups, num_pages, page_size, ...)`` —
    a global pool of fixed-size token pages instead of per-slot slabs;
  * a slot reads/writes through a per-slot PAGE MAP ``(max_pages,) int32``
    mapping logical page ``t // page_size`` to a physical page id;
  * full prompt pages are indexed by a POSITION-CHAINED hash of their
    token ids, so a new request sharing a prefix re-uses the cached pages
    (refcount++) instead of re-prefilling them;
  * pages are REFCOUNTED: a page is freed exactly when its last user
    releases it — unless it is prefix-indexed, in which case it parks in
    an LRU cache (refcount 0) and is reclaimed only when the free list
    runs dry;
  * a shared page is NEVER written in place: :meth:`PageTable.writable`
    returns a fresh private page (copy-on-write) whenever the mapped page
    has other users or sits in the prefix index.

Physical page 0 is reserved as the TRASH page: masked writes (chunk-pad
positions, inactive decode slots) scatter there, so one pool serves every
slot without conditional writes.  Unallocated logical pages map to 0 too —
their garbage is never valid under the position mask.

All bookkeeping is host-side (numpy + dicts, unit-testable without jax);
the only device code is the pool constructor and the CoW page copy.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

TRASH_PAGE = 0


class PagePoolFull(RuntimeError):
    """No free page and nothing evictable — admission must wait."""


def _sha_chain(parent: bytes, chunk: np.ndarray) -> bytes:
    return hashlib.sha1(parent + chunk.astype(np.int32).tobytes()).digest()


class PageTable:
    """Host-side page allocator with prefix-hash sharing and CoW.

    ``hash_fn(parent_digest, chunk) -> digest`` is injectable so the
    collision fallback (full token-id comparison) is testable with a
    deliberately colliding hash.
    """

    def __init__(self, num_pages: int, page_size: int,
                 hash_fn: Optional[Callable] = None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1: {page_size}")
        self.num_pages, self.page_size = num_pages, page_size
        self._hash = hash_fn or _sha_chain
        # allocate low page ids first (deterministic for tests)
        self._free: List[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self.ref = np.zeros(num_pages, np.int64)
        self._index: Dict[bytes, int] = {}       # chain digest -> page id
        self._meta: Dict[int, Tuple[bytes, np.ndarray]] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # rc==0, cached
        self.cow_copies = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    # -- queries ------------------------------------------------------------

    def available(self) -> int:
        """Pages allocatable right now (free + evictable cached)."""
        return len(self._free) + len(self._lru)

    def active_pages(self) -> int:
        return int((self.ref > 0).sum())

    def cached_pages(self) -> int:
        return len(self._lru)

    def stats(self) -> dict:
        return {"pages": self.num_pages - 1,
                "page_size": self.page_size,
                "active_pages": self.active_pages(),
                "cached_pages": self.cached_pages(),
                "free_pages": len(self._free),
                "cow_copies": self.cow_copies,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_tokens": self.prefix_hit_tokens}

    # -- allocation ---------------------------------------------------------

    def alloc(self) -> int:
        """A fresh private page (refcount 1).  Evicts the least-recently
        used cached prefix page when the free list is empty."""
        if self._free:
            pid = self._free.pop()
        elif self._lru:
            pid, _ = self._lru.popitem(last=False)       # oldest
            digest, _toks = self._meta.pop(pid)
            del self._index[digest]
        else:
            raise PagePoolFull(
                f"all {self.num_pages - 1} pages active — wait for a "
                "release before admitting")
        assert self.ref[pid] == 0
        self.ref[pid] = 1
        return pid

    def release(self, page_ids) -> None:
        """Drop one reference per page.  A page whose refcount hits zero is
        freed — or parked in the LRU cache if it is prefix-indexed."""
        for pid in page_ids:
            if pid == TRASH_PAGE:
                continue
            if self.ref[pid] <= 0:
                raise ValueError(f"release of page {pid} with refcount "
                                 f"{self.ref[pid]}")
            self.ref[pid] -= 1
            if self.ref[pid] == 0:
                if pid in self._meta:
                    self._lru[pid] = None                # cached, evictable
                else:
                    self._free.append(pid)

    # -- prefix sharing -----------------------------------------------------

    def _chain(self, tokens: np.ndarray):
        """(digest, chunk) per FULL page of ``tokens[:-1]`` — the last
        prompt token is always recomputed (its logits seed generation), so
        only pages fully covered by ``tokens[:-1]`` are shareable."""
        p = self.page_size
        full = (len(tokens) - 1) // p
        out, parent = [], b""
        for i in range(full):
            chunk = np.asarray(tokens[i * p:(i + 1) * p], np.int32)
            parent = self._hash(parent, chunk)
            out.append((parent, chunk))
        return out

    def match_prefix(self, tokens: np.ndarray) -> List[int]:
        """Longest chain of cached pages matching ``tokens``'s leading full
        pages.  Matched pages are increfed (caller owns one reference each
        and must ``release`` them).  A digest hit whose stored token ids
        differ (hash collision) stops the match — correctness never rests
        on the hash alone."""
        matched: List[int] = []
        for digest, chunk in self._chain(np.asarray(tokens)):
            pid = self._index.get(digest)
            if pid is None:
                break
            _, stored = self._meta[pid]
            if not np.array_equal(stored, chunk):        # collision
                break
            if self.ref[pid] == 0:
                del self._lru[pid]
            self.ref[pid] += 1
            matched.append(pid)
        if matched:
            self.prefix_hits += 1
            self.prefix_hit_tokens += len(matched) * self.page_size
        return matched

    def register_prefix(self, tokens: np.ndarray, page_ids: List[int]) -> None:
        """Index ``tokens``'s full prompt pages (backed by ``page_ids``,
        the slot's allocated pages in logical order) for future sharing.
        Pages whose digest is already indexed keep the existing entry (the
        newer copy stays private)."""
        for (digest, chunk), pid in zip(self._chain(np.asarray(tokens)),
                                        page_ids):
            if digest in self._index or pid in self._meta:
                continue
            self._index[digest] = pid
            self._meta[pid] = (digest, chunk)

    # -- copy-on-write ------------------------------------------------------

    def shared(self, pid: int) -> bool:
        """Writing this page in place would corrupt another reader: it has
        more than one reference, or the prefix index points at it."""
        return pid == TRASH_PAGE or self.ref[pid] > 1 or pid in self._meta

    def writable(self, pid: int) -> Tuple[int, bool]:
        """(page to write, copy_needed).  Private unindexed pages are
        returned as-is; shared/indexed pages trigger CoW — a fresh page is
        allocated, the old reference dropped, and the caller must copy the
        old contents device-side before writing (``copy_pages``)."""
        if pid != TRASH_PAGE and self.ref[pid] == 1 and pid not in self._meta:
            return pid, False
        fresh = self.alloc()
        self.release([pid])
        self.cow_copies += 1
        return fresh, True

    # -- test support -------------------------------------------------------

    def check_invariants(self) -> None:
        """Every page is in exactly one state; refcounts never negative."""
        free = set(self._free)
        cached = set(self._lru)
        assert not free & cached, "page both free and cached"
        for pid in range(1, self.num_pages):
            rc = self.ref[pid]
            assert rc >= 0, f"page {pid}: negative refcount {rc}"
            states = [pid in free, pid in cached, rc > 0]
            assert sum(states) == 1, \
                f"page {pid} leak: free={states[0]} cached={states[1]} " \
                f"rc={rc}"
            if pid in cached:
                assert pid in self._meta, f"cached page {pid} not indexed"
        for digest, pid in self._index.items():
            assert self._meta[pid][0] == digest
        assert self.ref[TRASH_PAGE] == 0


# ---------------------------------------------------------------------------
# Device pool
# ---------------------------------------------------------------------------

def init_page_pool(mod, cfg: ModelConfig, num_pages: int, page_size: int,
                   dtype=jnp.bfloat16):
    """The transformer cache pytree with the (batch, cache_len) axes as
    (num_pages, page_size) — one pool shared by every slot."""
    if cfg.window is not None:
        raise ValueError(
            f"{cfg.arch_id}: paged KV needs absolute cache positions; "
            "sliding-window ring buffers are unsupported (serve with the "
            "slab cache: --no-prefix-cache / prefill_chunk=None)")
    return mod.init_caches(cfg, num_pages, page_size, dtype)


@jax.jit
def copy_pages(pool, src, dst):
    """CoW device copy: ``pool[:, dst] = pool[:, src]`` on every leaf (page
    axis is 1, after the layer-group axis).  src/dst: scalar int32."""
    def leaf(a):
        page = jax.lax.dynamic_index_in_dim(a, src, axis=1, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(a, page, dst, axis=1)
    return jax.tree.map(leaf, pool)


def pool_bytes(pool) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(pool))


def pages_for(max_seq: int, page_size: int) -> int:
    """Logical pages a slot needs to cover ``max_seq`` positions."""
    return -(-max_seq // page_size)
