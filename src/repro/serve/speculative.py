"""Speculative decoding: draft proposer + greedy acceptance.

A small DRAFT model proposes ``k`` greedy tokens per tick; the TARGET
model scores all ``k + 1`` positions in ONE batched ``decode_span``
forward and accepts the longest matching prefix.  Every emitted token is
the target's own greedy argmax, so the output is EXACTLY what plain
per-token greedy decode would produce — for any draft, good or bad; the
draft only sets how many target positions each forward amortises.

Compression semantics (paper finding F3): a draft trained with boundary
compression must also SERVE compressed, so the draft carries its own
CompressionPolicy and packs its stage cuts through the same wire-codec
registry as the target.  The target's verification span packs PER
(request, token) (``boundary_wire_eval_tokens``) — payload-identical to a
T=1 decode tick — which is what keeps accepted-token numerics bit-equal
to non-speculative decode.

The draft keeps the PR-4 slab cache (per-slot contiguous rows, bucketed
left-padded prefill) even when the target is paged: draft state is tiny
and never prefix-shared.  After each round the draft "rolls back" by pos
arithmetic only — rejected positions hold garbage K/V that the next
propose overwrites before it ever becomes valid under the position mask.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import cache as C


def accept_greedy(proposals: np.ndarray, target_greedy: np.ndarray,
                  k: int) -> int:
    """Accepted-token count for one slot.

    ``proposals``: (k,) draft tokens d_1..d_k; ``target_greedy``: (k+1,)
    the target's argmax at every span position — g_j is the target's
    next token after seeing ...x0, d_1..d_j.  Returns ``a`` = longest
    prefix with d_{j+1} == g_j; the emitted tokens are g_0..g_{e-1} with
    ``e = min(a + 1, k)``.

    The bonus token (e = a + 1) is DROPPED when every proposal is
    accepted: capping e at k keeps the draft cache gap-free — position
    ``pd + e - 1`` was always written during propose, so the next round
    needs no backfill forward.
    """
    a = 0
    while a < k and int(proposals[a]) == int(target_greedy[a]):
        a += 1
    return a


class DraftWorker:
    """Per-slot draft state + the two draft programs (insert, propose).

    Mirrors the legacy ContinuousEngine slab path: bucketed left-padded
    prefill into a per-slot row, then greedy multi-step decode via one
    jit'd ``lax.scan``.  All bookkeeping (pos / pad) is host-side numpy;
    rollback after a verification round is pure position arithmetic.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, num_slots: int = 4,
                 max_seq: int = 256, buckets: Optional[List[int]] = None,
                 spec_k: int = 4):
        from repro.serve.engine import left_pad_unsupported, _make_batch
        bad = left_pad_unsupported(cfg)
        if bad:
            raise ValueError(
                f"draft arch {cfg.arch_id}: speculative proposing needs "
                f"maskable left-padding; {sorted(bad)} supports none")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1: {spec_k}")
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress, self.spec_k = compress, spec_k
        self.num_slots, self.max_seq = num_slots, max_seq
        self.buckets = buckets or C.prompt_buckets(max_seq // 2)
        self._caches = C.init_slot_caches(transformer, cfg, num_slots,
                                          max_seq)
        self.pos = np.zeros(num_slots, np.int32)
        self.pad = np.zeros(num_slots, np.int32)
        self.proposed = 0
        self.accepted = 0
        cfg_, pol_, k_ = cfg, policy, spec_k

        def _insert(params, tokens, pad, caches, slot):
            """Bucketed draft prefill spliced into ``slot``; the prefill
            logits are discarded — the first propose round re-feeds the
            target's first emitted token."""
            _, one = transformer.prefill(
                params, _make_batch(cfg_, tokens), cfg_, pol_,
                cache_len=max_seq, compress=compress, pad_len=pad,
                wire=True)
            return C.write_slot(caches, one, slot)

        def _propose(params, last, caches, pos, pad):
            """``spec_k`` greedy draft steps for every slot in one scan;
            returns (B, k) proposals d_1..d_k.  Inactive slots decode
            garbage into their own rows only (invalid under the position
            mask, overwritten on refill)."""
            def body(carry, _):
                tok, caches, pos = carry
                logits, caches = transformer.decode_step(
                    params, tok, caches, pos, cfg_, pol_,
                    compress=compress, pad_len=pad, wire=True)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (tok, caches, pos + 1), tok
            (_, caches, _), hist = jax.lax.scan(
                body, (last, caches, pos), None, length=k_)
            return jnp.transpose(hist), caches          # (B, k)

        self._insert = jax.jit(_insert, donate_argnums=(3,))
        self._propose = jax.jit(_propose, donate_argnums=(2,))

    def insert(self, slot: int, prompt: np.ndarray) -> None:
        """Prefill ``prompt`` into the draft row for ``slot``."""
        bucket = C.bucket_for(len(prompt), self.buckets)
        if bucket + self.spec_k >= self.max_seq:
            raise ValueError(
                f"draft bucket {bucket} + spec_k {self.spec_k} exceeds "
                f"draft max_seq={self.max_seq}")
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - len(prompt):] = prompt
        pad = bucket - len(prompt)
        self._caches = self._insert(
            self.params, jnp.asarray(toks), jnp.asarray([pad], jnp.int32),
            self._caches, jnp.int32(slot))
        self.pos[slot] = bucket
        self.pad[slot] = pad

    def propose(self, last_tok: np.ndarray) -> np.ndarray:
        """(B, k) greedy proposals continuing each slot from ``last_tok``.
        Does NOT advance ``self.pos`` — the engine commits the accepted
        count per slot via :meth:`commit`."""
        props, self._caches = self._propose(
            self.params, jnp.asarray(last_tok, jnp.int32), self._caches,
            jnp.asarray(self.pos), jnp.asarray(self.pad))
        return np.asarray(props)

    def commit(self, slot: int, emitted: int) -> None:
        """Advance ``slot`` past its ``emitted`` accepted tokens.  With
        ``e <= k`` (bonus capped, see :func:`accept_greedy`) position
        ``pos + e - 1`` was written during propose with the right token,
        so the draft cache is gap-free; positions beyond hold garbage the
        next propose overwrites (write-before-attend)."""
        self.pos[slot] += emitted

    def record(self, proposed: int, accepted: int) -> None:
        self.proposed += proposed
        self.accepted += accepted

    def warm(self) -> None:
        """Compile every draft program (insert per bucket + propose)."""
        for b in self.buckets:
            if b + self.spec_k < self.max_seq:
                self.insert(0, np.zeros(b, np.int32))
        self.propose(np.zeros(self.num_slots, np.int32))
        self.pos[:] = 0
        self.pad[:] = 0

    def stats(self) -> dict:
        return {"spec_k": self.spec_k,
                "draft_arch": self.cfg.arch_id,
                "proposed": self.proposed,
                "accepted": self.accepted,
                "acceptance_rate": (round(self.accepted / self.proposed, 3)
                                    if self.proposed else 0.0),
                "draft_cache_bytes": C.slot_bytes(self._caches,
                                                  self.num_slots)}

    def compile_stats(self) -> dict:
        return {"draft_insert_compiles": self._insert._cache_size(),
                "propose_compiles": self._propose._cache_size()}
