"""Slot-indexed KV cache for continuous-batching serve.

One decode "page" per serving slot: every cache leaf is laid out
``(groups, num_slots, cache_len, ...)`` (the transformer's per-group cache
pytree with the batch axis as the slot axis).  A slot is claimed by a
request at admission, filled by a bucketed prefill, advanced in place by
the shared decode program (the caches are DONATED across ticks, so XLA
updates them in place on TPU), and handed to the next request on eviction
without touching the other slots.

Slot hygiene needs no explicit zeroing: the decode attention mask only
admits cache positions ``idx <= pos[slot]`` (ring: within the current
window), and a refill overwrites exactly the positions the new request's
prompt occupies — stale keys from the previous occupant are never valid.
``reset_slot`` exists for callers that want hard isolation anyway (e.g.
debugging a masking regression).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def init_slot_caches(mod, cfg: ModelConfig, num_slots: int, cache_len: int,
                     dtype=jnp.bfloat16):
    """The transformer's cache pytree with ``num_slots`` batch slots."""
    return mod.init_caches(cfg, num_slots, cache_len, dtype)


def write_slot(caches, one_caches, slot):
    """Insert a prefilled batch-1 cache pytree into slot ``slot`` in place.

    ``one_caches`` leaves are ``(groups, 1, ...)`` (a batch-1 prefill);
    ``slot`` may be a traced int32 — the write lowers to one
    dynamic-update per leaf, so slot refill never recompiles.
    """
    return jax.tree.map(
        lambda big, one: jax.lax.dynamic_update_index_in_dim(
            big, one[:, 0].astype(big.dtype), slot, axis=1),
        caches, one_caches)


def reset_slot(caches, slot):
    """Zero one slot's cache (optional hygiene; see module docstring)."""
    return jax.tree.map(
        lambda big: jax.lax.dynamic_update_index_in_dim(
            big, jnp.zeros(big.shape[:1] + big.shape[2:], big.dtype),
            slot, axis=1),
        caches)


def slot_bytes(caches, num_slots: int) -> int:
    """Per-slot cache footprint in bytes (engine metrics)."""
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(caches))
    return total // max(1, num_slots)


def prompt_buckets(max_prompt: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to ``max_prompt``.

    A request's prefill runs at the smallest bucket >= its prompt length
    (left-padded inside the bucket), so the prefill program compiles once
    per bucket — a bounded, warm-able set — instead of once per distinct
    prompt length.
    """
    buckets = []
    b = min_bucket
    while b < max_prompt:
        buckets.append(b)
        b *= 2
    buckets.append(max_prompt)
    return tuple(buckets)


def bucket_for(length: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds the largest bucket "
                     f"{buckets[-1]} (raise max_prompt/max_seq)")
