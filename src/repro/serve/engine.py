"""Batched serving engine: continuous token generation over a KV cache.

Serving semantics of the paper's technique: a model trained with boundary
compression must be SERVED with compression on (paper Table 2 / finding F3),
so the engine carries the CompressionPolicy and applies ``boundary_eval`` at
each stage cut during both prefill and decode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    """Static-batch engine: pad/stack prompts, prefill once, decode greedily.

    Production notes: the decode step is a single jit'd program with donated
    caches (in-place on TPU); batch slots are fixed at construction —
    continuous batching would swap finished slots via the same program.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, max_batch: int = 8,
                 max_seq: int = 256):
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress = compress
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mod = encdec if cfg.enc_dec else transformer
        cfg_, pol_, mod_ = cfg, policy, self.mod

        def _prefill(params, batch, pad_len):
            return mod_.prefill(params, batch, cfg_, pol_,
                                cache_len=max_seq, compress=compress,
                                pad_len=pad_len)

        def _decode(params, token, caches, pos, pad_len):
            return mod_.decode_step(params, token, caches, pos, cfg_, pol_,
                                    compress=compress, pad_len=pad_len)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def _make_batch(self, prompts: np.ndarray) -> dict:
        b = {"tokens": jnp.asarray(prompts)}
        if self.cfg.frontend == "vision":
            b["patch_embeds"] = jnp.zeros(
                (prompts.shape[0], self.cfg.num_patches, self.cfg.d_model),
                jnp.bfloat16)
        if self.cfg.enc_dec:
            b["enc_embeds"] = jnp.zeros(
                (prompts.shape[0], self.cfg.enc_seq, self.cfg.d_model),
                jnp.bfloat16)
        return b

    def generate(self, requests: List[Request]) -> List[Request]:
        assert len(requests) <= self.max_batch
        # left-align prompts to a common length (static batch); the
        # per-request pad length masks the padding out of attention, so a
        # short prompt generates exactly what it would alone (RoPE archs —
        # recurrent rwkv/hymba state and abs-position enc-dec decoders do
        # not support left-padding; serve those with equal-length prompts)
        plen = max(len(r.prompt) for r in requests)
        b = len(requests)
        if plen != min(len(r.prompt) for r in requests):
            unsupported = ({"rwkv", "hymba"} & set(self.cfg.layer_kinds())
                           or ({"enc-dec"} if self.cfg.enc_dec else set()))
            if unsupported:
                raise ValueError(
                    f"mixed-length prompts need left-padding, which "
                    f"{sorted(unsupported)} layers cannot mask (recurrent "
                    f"state / absolute positions carry the padding) — "
                    f"batch equal-length prompts for this arch")
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        pad_len = jnp.asarray(
            [plen - len(r.prompt) for r in requests], jnp.int32)
        steps = max(r.max_new_tokens for r in requests)

        logits, caches = self._prefill(self.params, self._make_batch(prompts),
                                       pad_len)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        outs = [token]
        for i in range(steps - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(plen + i), pad_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(token)
        gen = np.stack([np.asarray(t) for t in outs], axis=1)   # (B, steps)
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new_tokens]
        return requests

    def throughput_probe(self, batch: int, prompt_len: int,
                         new_tokens: int) -> dict:
        """Tokens/s measurement for the benchmark harness."""
        rng = np.random.RandomState(0)
        reqs = [Request(rng.randint(0, self.cfg.vocab_size, prompt_len)
                        .astype(np.int32), new_tokens)
                for _ in range(batch)]
        t0 = time.time()
        self.generate(reqs)
        dt = time.time() - t0
        return {"batch": batch, "prompt": prompt_len, "new": new_tokens,
                "wall_s": dt, "tok_per_s": batch * new_tokens / dt}
