"""Serving engines: static-batch baseline + continuous batching.

Serving semantics of the paper's technique: a model trained with boundary
compression must be SERVED with compression on (paper Table 2 / finding F3),
so both engines carry the CompressionPolicy and compress every stage cut
during prefill and decode.  The cuts route through the WIRE-CODEC registry
(``transport/codecs.py`` via ``core.boundary.boundary_wire_eval``): a served
decode packs/unpacks the same q8/TopK payloads the training pipeline puts on
the network, packed per request (each slot is its own stream).

Two engines:

  * :class:`ServeEngine` — static batch: left-pad every prompt to the
    longest in the batch, decode everyone until the global max-new-tokens.
    Kept as the throughput baseline.
  * :class:`ContinuousEngine` — continuous batching: a streaming
    ``submit()/step()/drain()`` API over ``num_slots`` decode slots.  A
    finished slot (EOS or max-new-tokens) is evicted and refilled from the
    admission queue on the next tick.  All slots advance through ONE jit'd
    decode program with per-slot positions/padding/PRNG keys — slot swaps
    never recompile — and prompts prefill at power-of-two length buckets,
    so the prefill program set is bounded and warm-able.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.boundary import boundary_wire_bytes_per_token
from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.serve import cache as C
from repro.serve.sampling import GREEDY, SamplingConfig, request_key, \
    sample_tokens
from repro.serve.scheduler import Scheduler, ServeRequest


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


def left_pad_unsupported(cfg: ModelConfig) -> set:
    """Arch features incompatible with masked left-padding (and so with
    mixed-length static batches and with continuous batching): recurrent
    state and absolute positions carry the padding; the vision patch
    prefix splices into the sequence FRONT, exactly where left-padding
    goes."""
    bad = {"rwkv", "hymba"} & set(cfg.layer_kinds())
    if cfg.enc_dec:
        bad.add("enc-dec")
    if cfg.frontend == "vision":
        bad.add("vision-frontend")
    return bad


def _make_batch(cfg: ModelConfig, prompts) -> dict:
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.zeros(
            (b["tokens"].shape[0], cfg.num_patches, cfg.d_model),
            jnp.bfloat16)
    if cfg.enc_dec:
        b["enc_embeds"] = jnp.zeros(
            (b["tokens"].shape[0], cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


class ServeEngine:
    """Static-batch engine: pad/stack prompts, prefill once, decode greedily.

    Production notes: the decode step is a single jit'd program with donated
    caches (in-place on TPU); batch slots are fixed at construction — see
    :class:`ContinuousEngine` for the version that swaps finished slots.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, max_batch: int = 8,
                 max_seq: int = 256, wire: bool = True):
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress, self.wire = compress, wire
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mod = encdec if cfg.enc_dec else transformer
        cfg_, pol_, mod_ = cfg, policy, self.mod

        def _prefill(params, batch, pad_len):
            return mod_.prefill(params, batch, cfg_, pol_,
                                cache_len=max_seq, compress=compress,
                                pad_len=pad_len, wire=wire)

        def _decode(params, token, caches, pos, pad_len):
            return mod_.decode_step(params, token, caches, pos, cfg_, pol_,
                                    compress=compress, pad_len=pad_len,
                                    wire=wire)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def _pack(self, requests: List[Request]):
        """Left-align prompts to a common length (static batch); the
        per-request pad length masks the padding out of attention, so a
        short prompt generates exactly what it would alone (RoPE archs —
        recurrent rwkv/hymba state and abs-position enc-dec decoders do
        not support left-padding; serve those with equal-length prompts)."""
        plen = max(len(r.prompt) for r in requests)
        b = len(requests)
        if plen != min(len(r.prompt) for r in requests):
            unsupported = left_pad_unsupported(self.cfg)
            if unsupported:
                raise ValueError(
                    "mixed-length prompts need left-padding, which "
                    f"{sorted(unsupported)} cannot support (see "
                    "left_pad_unsupported) — batch equal-length "
                    "prompts for this arch")
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        pad_len = jnp.asarray(
            [plen - len(r.prompt) for r in requests], jnp.int32)
        return prompts, pad_len, plen

    def generate(self, requests: List[Request]) -> List[Request]:
        assert len(requests) <= self.max_batch
        prompts, pad_len, plen = self._pack(requests)
        steps = max(r.max_new_tokens for r in requests)

        logits, caches = self._prefill(self.params,
                                       _make_batch(self.cfg, prompts),
                                       pad_len)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        outs = [token]
        for i in range(steps - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(plen + i), pad_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(token)
        gen = np.stack([np.asarray(t) for t in outs], axis=1)   # (B, steps)
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new_tokens]
        return requests

    def throughput_probe(self, batch: int, prompt_len: int,
                         new_tokens: int) -> dict:
        """Tokens/s measurement for the benchmark harness.

        Warms the MEASURED (batch, prompt_len) shape first — compiling a
        different shape (the old batch=1/new=2 warmup) would time XLA
        compilation into tok_per_s — then reports prefill and decode
        throughput separately (they bound different production regimes:
        TTFT vs steady-state decode).
        """
        rng = np.random.RandomState(0)
        reqs = [Request(rng.randint(0, self.cfg.vocab_size, prompt_len)
                        .astype(np.int32), new_tokens)
                for _ in range(batch)]
        t0 = time.time()
        # warm: same (batch, prompt_len) shapes, 2 decode tokens compiles
        # the decode program too (its shape is independent of new_tokens)
        self.generate([Request(r.prompt.copy(), 2) for r in reqs])
        warm_s = time.time() - t0

        prompts, pad_len, plen = self._pack(reqs)
        t0 = time.time()
        logits, caches = self._prefill(self.params,
                                       _make_batch(self.cfg, prompts),
                                       pad_len)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        jax.block_until_ready(token)
        prefill_s = time.time() - t0
        t0 = time.time()
        for i in range(new_tokens - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(plen + i), pad_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(token)
        decode_s = time.time() - t0
        wall = prefill_s + decode_s
        return {"batch": batch, "prompt": prompt_len, "new": new_tokens,
                "warm_s": round(warm_s, 3), "wall_s": wall,
                "prefill_s": round(prefill_s, 4),
                "prefill_tok_per_s": round(batch * prompt_len / prefill_s, 1),
                "decode_s": round(decode_s, 4),
                "decode_tok_per_s": round(
                    batch * (new_tokens - 1) / decode_s, 1)
                if new_tokens > 1 else 0.0,
                "tok_per_s": batch * new_tokens / wall}


class ContinuousEngine:
    """Continuous-batching engine: streaming submit()/step()/drain().

    Restrictions: decoder-only stacks whose attention masks left-padding
    (RoPE / attention-family layers).  Recurrent kinds (rwkv, hymba SSM)
    carry state through pad positions and enc-dec decoders use absolute
    positions; serve those with the static engine and equal-length batches.

    Multi-step decode: when no slot can complete (or be refilled) within
    the next ``tick_chunk`` ticks and no active request watches for EOS,
    the engine runs ``tick_chunk`` decode steps inside ONE jit'd
    ``lax.scan`` call and syncs the host once — per-dispatch overhead is
    the decode bottleneck for small models, and the scheduler only needs
    token values back at completion/refill boundaries.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, num_slots: int = 4,
                 max_seq: int = 256, sampling: SamplingConfig = GREEDY,
                 max_prompt: Optional[int] = None, tick_chunk: int = 8):
        bad = left_pad_unsupported(cfg)
        if bad:
            raise ValueError(
                "continuous batching needs maskable left-padding and "
                f"per-slot positions; {sorted(bad)} supports neither "
                "(see left_pad_unsupported) — use ServeEngine "
                "(--engine static) with equal-length batches")
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress, self.sampling = compress, sampling
        self.num_slots, self.max_seq = num_slots, max_seq
        self.tick_chunk = max(1, tick_chunk)
        self.buckets = C.prompt_buckets(min(max_prompt or max_seq // 2,
                                            max_seq))
        self.sched = Scheduler(num_slots)
        self._caches = C.init_slot_caches(transformer, cfg, num_slots,
                                          max_seq)
        self.pos = np.zeros(num_slots, np.int32)     # next decode position
        self.pad = np.zeros(num_slots, np.int32)     # left-pad inside bucket
        self.last_tok = np.zeros(num_slots, np.int32)
        self._keys = jnp.zeros((num_slots, 2), jnp.uint32)
        self.ticks = 0
        self.active_slot_ticks = 0
        cfg_, pol_, smp_ = cfg, policy, sampling

        def _insert(params, tokens, pad, caches, slot, key):
            """Prefill one request at its bucket length and splice its KV
            into ``slot``; returns its first sampled token (the TTFT
            token comes out of the prefill logits, no extra decode)."""
            logits, one = transformer.prefill(
                params, _make_batch(cfg_, tokens), cfg_, pol_,
                cache_len=max_seq, compress=compress, pad_len=pad, wire=True)
            caches = C.write_slot(caches, one, slot)
            tok, key1 = sample_tokens(logits.reshape(1, -1), key[None], smp_)
            return tok[0], caches, key1[0]

        def _decode(params, tokens, caches, pos, pad, keys):
            """One tick for every slot: per-slot position/pad/PRNG key.
            Inactive slots decode garbage into their own row only; it is
            never valid under the position mask and is overwritten by the
            next refill."""
            logits, caches = transformer.decode_step(
                params, tokens, caches, pos, cfg_, pol_, compress=compress,
                pad_len=pad, wire=True)
            toks, keys = sample_tokens(logits, keys, smp_)
            return toks, caches, keys

        chunk = self.tick_chunk

        def _decode_chunk(params, tokens, caches, pos, pad, active, keys):
            """``tick_chunk`` decode ticks in one program: inactive slots'
            tokens/positions are frozen (their garbage writes stay in
            their own row, invalid under the position mask); returns the
            (chunk, B) token history for ONE host sync."""
            def body(carry, _):
                tokens, caches, pos, keys = carry
                logits, caches = transformer.decode_step(
                    params, tokens, caches, pos, cfg_, pol_,
                    compress=compress, pad_len=pad, wire=True)
                toks, keys = sample_tokens(logits, keys, smp_)
                toks = jnp.where(active, toks, tokens)
                pos = pos + active.astype(pos.dtype)
                return (toks, caches, pos, keys), toks
            (tokens, caches, pos, keys), hist = jax.lax.scan(
                body, (tokens, caches, pos, keys), None, length=chunk)
            return tokens, caches, pos, keys, hist

        self._insert = jax.jit(_insert, donate_argnums=(3,))
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._decode_chunk = jax.jit(_decode_chunk, donate_argnums=(2,))

    # -- streaming API ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_token: Optional[int] = None, seed: int = 0) -> int:
        """Queue a request; returns its request id."""
        prompt = np.asarray(prompt, np.int32)
        bucket = C.bucket_for(len(prompt), self.buckets)
        if bucket + max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"prompt bucket {bucket} + {max_new_tokens} new tokens "
                f"exceeds max_seq={self.max_seq}")
        return self.sched.submit(prompt, max_new_tokens, eos_token,
                                 seed).req_id

    def step(self) -> List[ServeRequest]:
        """One engine tick: refill free slots from the queue (bucketed
        prefill per new request), then one decode step for every slot.
        Returns the requests that completed this tick."""
        finished = []
        for slot, req in self.sched.fills():
            bucket = C.bucket_for(len(req.prompt), self.buckets)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - len(req.prompt):] = req.prompt
            pad = bucket - len(req.prompt)
            tok, self._caches, key = self._insert(
                self.params, jnp.asarray(toks),
                jnp.asarray([pad], jnp.int32), self._caches,
                jnp.int32(slot), request_key(req.seed))
            self._keys = self._keys.at[slot].set(key)
            self.pos[slot] = bucket
            self.pad[slot] = pad
            self.last_tok[slot] = int(tok)      # blocks => honest TTFT
            done = self.sched.started(slot, int(tok))
            if done is not None:
                finished.append(done)
        active = self.sched.active_slots
        if not active:
            return finished
        reqs = [self.sched.slots[s] for s in active]
        min_rem = min(r.max_new_tokens - len(r.tokens) for r in reqs)
        chunkable = (self.tick_chunk > 1
                     and min_rem >= self.tick_chunk
                     and all(r.eos_token is None for r in reqs))
        if chunkable:
            # no slot can complete inside the chunk and none watches for
            # EOS => run tick_chunk decode steps in one program, one sync
            mask = np.zeros(self.num_slots, bool)
            mask[active] = True
            last, self._caches, _, self._keys, hist = self._decode_chunk(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad),
                jnp.asarray(mask), self._keys)
            hist_np = np.asarray(hist)              # (chunk, B)
            self.ticks += self.tick_chunk
            self.active_slot_ticks += self.tick_chunk * len(active)
            for slot in active:
                self.pos[slot] += self.tick_chunk
                self.last_tok[slot] = hist_np[-1, slot]
                for t in hist_np[:, slot]:
                    done = self.sched.token(slot, t)
                    if done is not None:            # only the last can
                        finished.append(done)
        else:
            toks, self._caches, self._keys = self._decode(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad), self._keys)
            toks_np = np.asarray(toks)
            self.ticks += 1
            self.active_slot_ticks += len(active)
            for slot in active:
                self.pos[slot] += 1
                self.last_tok[slot] = toks_np[slot]
                done = self.sched.token(slot, toks_np[slot])
                if done is not None:
                    finished.append(done)
        return finished

    def drain(self) -> List[ServeRequest]:
        """Run steps until queue and slots are empty; returns everything
        that finished during the drain (in completion order)."""
        out = []
        while not self.sched.idle:
            out.extend(self.step())
        return out

    def warmup(self) -> dict:
        """Compile every prompt-bucket insert program + the decode program
        by serving dummy requests, then reset the scheduler/metrics.  After
        this, slot eviction/refill at ANY prompt length triggers zero
        recompilations (see compile_stats)."""
        for b in self.buckets:
            new = min(self.tick_chunk + 2, self.max_seq - b + 1)
            self.submit(np.zeros(b, np.int32), max_new_tokens=new)
        self.drain()
        if self.tick_chunk > 1:
            # the drain may never satisfy the chunkable condition (slot
            # count / bucket-headroom geometry), so compile the multi-tick
            # program directly: an all-inactive mask freezes every slot's
            # tokens/positions and the scheduler is idle, so only benign
            # garbage rows are written (invalid under the position mask)
            mask = np.zeros(self.num_slots, bool)
            _, self._caches, _, _, _ = self._decode_chunk(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad),
                jnp.asarray(mask), self._keys)
        self.sched = Scheduler(self.num_slots)
        self.ticks = self.active_slot_ticks = 0
        return self.compile_stats()

    # -- metrics ------------------------------------------------------------

    def compile_stats(self) -> dict:
        """jit compilation-cache sizes: one decode entry, one multi-tick
        chunk entry, one insert entry per warmed prompt bucket.  Unchanged
        counts across a serving run == zero recompilations."""
        return {"decode_compiles": self._decode._cache_size(),
                "decode_chunk_compiles": self._decode_chunk._cache_size(),
                "insert_compiles": self._insert._cache_size()}

    def stats(self) -> dict:
        s = self.sched.stats()
        s.update({
            "ticks": self.ticks,
            "slot_utilization": (round(
                self.active_slot_ticks / (self.ticks * self.num_slots), 3)
                if self.ticks else 0.0),
            "slot_cache_bytes": C.slot_bytes(self._caches, self.num_slots),
            "boundary_bytes_per_tok": (
                round(boundary_wire_bytes_per_token(
                    self.policy, self.cfg.d_model,
                    num_cuts=max(0, len(transformer.segment_bounds(
                        self.cfg.num_groups,
                        self.policy.num_stages)) - 1)), 1)
                if self.compress else 0.0),
            "sampling": self.sampling.name,
        })
        s.update(self.compile_stats())
        return s
