"""Serving engines: static-batch baseline + continuous batching.

Serving semantics of the paper's technique: a model trained with boundary
compression must be SERVED with compression on (paper Table 2 / finding F3),
so both engines carry the CompressionPolicy and compress every stage cut
during prefill and decode.  The cuts route through the WIRE-CODEC registry
(``transport/codecs.py`` via ``core.boundary.boundary_wire_eval``): a served
decode packs/unpacks the same q8/TopK payloads the training pipeline puts on
the network, packed per request (each slot is its own stream).

Two engines:

  * :class:`ServeEngine` — static batch: left-pad every prompt to the
    longest in the batch, decode everyone until the global max-new-tokens.
    Kept as the throughput baseline.
  * :class:`ContinuousEngine` — continuous batching: a streaming
    ``submit()/step()/drain()`` API over ``num_slots`` decode slots.  A
    finished slot (EOS or max-new-tokens) is evicted and refilled from the
    admission queue on the next tick.  All slots advance through ONE jit'd
    decode program with per-slot positions/padding/PRNG keys — slot swaps
    never recompile — and prompts prefill at power-of-two length buckets,
    so the prefill program set is bounded and warm-able.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.boundary import boundary_wire_bytes_per_token
from repro.core.policy import CompressionPolicy, NO_POLICY
from repro.obs import trace
from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.serve import cache as C
from repro.serve import pages as PG
from repro.serve.sampling import GREEDY, SamplingConfig, request_key, \
    sample_tokens
from repro.serve.scheduler import Scheduler, ServeRequest
from repro.serve.speculative import DraftWorker, accept_greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


def left_pad_unsupported(cfg: ModelConfig) -> set:
    """Arch features incompatible with masked left-padding (and so with
    mixed-length static batches and with continuous batching): recurrent
    state and absolute positions carry the padding; the vision patch
    prefix splices into the sequence FRONT, exactly where left-padding
    goes."""
    bad = {"rwkv", "hymba"} & set(cfg.layer_kinds())
    if cfg.enc_dec:
        bad.add("enc-dec")
    if cfg.frontend == "vision":
        bad.add("vision-frontend")
    return bad


def _make_batch(cfg: ModelConfig, prompts) -> dict:
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.zeros(
            (b["tokens"].shape[0], cfg.num_patches, cfg.d_model),
            jnp.bfloat16)
    if cfg.enc_dec:
        b["enc_embeds"] = jnp.zeros(
            (b["tokens"].shape[0], cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


class ServeEngine:
    """Static-batch engine: pad/stack prompts, prefill once, decode greedily.

    Production notes: the decode step is a single jit'd program with donated
    caches (in-place on TPU); batch slots are fixed at construction — see
    :class:`ContinuousEngine` for the version that swaps finished slots.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, max_batch: int = 8,
                 max_seq: int = 256, wire: bool = True):
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress, self.wire = compress, wire
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mod = encdec if cfg.enc_dec else transformer
        cfg_, pol_, mod_ = cfg, policy, self.mod

        def _prefill(params, batch, pad_len):
            return mod_.prefill(params, batch, cfg_, pol_,
                                cache_len=max_seq, compress=compress,
                                pad_len=pad_len, wire=wire)

        def _decode(params, token, caches, pos, pad_len):
            return mod_.decode_step(params, token, caches, pos, cfg_, pol_,
                                    compress=compress, pad_len=pad_len,
                                    wire=wire)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def _pack(self, requests: List[Request]):
        """Left-align prompts to a common length (static batch); the
        per-request pad length masks the padding out of attention, so a
        short prompt generates exactly what it would alone (RoPE archs —
        recurrent rwkv/hymba state and abs-position enc-dec decoders do
        not support left-padding; serve those with equal-length prompts)."""
        plen = max(len(r.prompt) for r in requests)
        b = len(requests)
        if plen != min(len(r.prompt) for r in requests):
            unsupported = left_pad_unsupported(self.cfg)
            if unsupported:
                raise ValueError(
                    "mixed-length prompts need left-padding, which "
                    f"{sorted(unsupported)} cannot support (see "
                    "left_pad_unsupported) — batch equal-length "
                    "prompts for this arch")
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        pad_len = jnp.asarray(
            [plen - len(r.prompt) for r in requests], jnp.int32)
        return prompts, pad_len, plen

    def generate(self, requests: List[Request]) -> List[Request]:
        assert len(requests) <= self.max_batch
        prompts, pad_len, plen = self._pack(requests)
        steps = max(r.max_new_tokens for r in requests)

        logits, caches = self._prefill(self.params,
                                       _make_batch(self.cfg, prompts),
                                       pad_len)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        outs = [token]
        for i in range(steps - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(plen + i), pad_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(token)
        gen = np.stack([np.asarray(t) for t in outs], axis=1)   # (B, steps)
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new_tokens]
        return requests

    def throughput_probe(self, batch: int, prompt_len: int,
                         new_tokens: int) -> dict:
        """Tokens/s measurement for the benchmark harness.

        Warms the MEASURED (batch, prompt_len) shape first — compiling a
        different shape (the old batch=1/new=2 warmup) would time XLA
        compilation into tok_per_s — then reports prefill and decode
        throughput separately (they bound different production regimes:
        TTFT vs steady-state decode).
        """
        rng = np.random.RandomState(0)
        reqs = [Request(rng.randint(0, self.cfg.vocab_size, prompt_len)
                        .astype(np.int32), new_tokens)
                for _ in range(batch)]
        t0 = time.time()
        # warm: same (batch, prompt_len) shapes, 2 decode tokens compiles
        # the decode program too (its shape is independent of new_tokens)
        self.generate([Request(r.prompt.copy(), 2) for r in reqs])
        warm_s = time.time() - t0

        prompts, pad_len, plen = self._pack(reqs)
        t0 = time.time()
        logits, caches = self._prefill(self.params,
                                       _make_batch(self.cfg, prompts),
                                       pad_len)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        jax.block_until_ready(token)
        prefill_s = time.time() - t0
        t0 = time.time()
        for i in range(new_tokens - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(plen + i), pad_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(token)
        decode_s = time.time() - t0
        wall = prefill_s + decode_s
        return {"batch": batch, "prompt": prompt_len, "new": new_tokens,
                "warm_s": round(warm_s, 3), "wall_s": wall,
                "prefill_s": round(prefill_s, 4),
                "prefill_tok_per_s": round(batch * prompt_len / prefill_s, 1),
                "decode_s": round(decode_s, 4),
                "decode_tok_per_s": round(
                    batch * (new_tokens - 1) / decode_s, 1)
                if new_tokens > 1 else 0.0,
                "tok_per_s": batch * new_tokens / wall}


class ContinuousEngine:
    """Continuous-batching engine: streaming submit()/step()/drain().

    Restrictions: decoder-only stacks whose attention masks left-padding
    (RoPE / attention-family layers).  Recurrent kinds (rwkv, hymba SSM)
    carry state through pad positions and enc-dec decoders use absolute
    positions; serve those with the static engine and equal-length batches.

    Multi-step decode: when no slot can complete (or be refilled) within
    the next ``tick_chunk`` ticks and no active request watches for EOS,
    the engine runs ``tick_chunk`` decode steps inside ONE jit'd
    ``lax.scan`` call and syncs the host once — per-dispatch overhead is
    the decode bottleneck for small models, and the scheduler only needs
    token values back at completion/refill boundaries.

    PAGED MODE (``prefix_cache`` / ``prefill_chunk`` / ``draft_params``):
    the per-slot KV slabs are replaced by a shared refcounted page pool
    (serve/pages.py) addressed through per-slot page maps.  Three coupled
    features ride on it:

      * prefix sharing — a new request whose leading full token pages are
        already cached skips their prefill entirely (refcount++), and its
        own full prompt pages are indexed for future requests on prefill
        completion;
      * chunked prefill — prompt ingestion runs as ``prefill_chunk``-sized
        ``decode_span`` chunks, ONE chunk per prefilling slot per tick,
        interleaved with the decode tick, so a long prompt never stalls
        the slots that are already decoding (the batch-1 prefill stall of
        the slab path);
      * speculative decoding — a draft model proposes ``spec_k`` greedy
        tokens per tick and the target verifies all of them in one
        ``decode_span`` forward (serve/speculative.py); stage cuts pack
        per (request, token), so emitted tokens are bit-identical to
        plain greedy decode.

    Prompts occupy positions ``[0, L)`` (no left-padding — page sharing
    needs position-stable content), decode continues at ``L``, and masked
    or inactive writes land in the reserved trash page, so the whole tick
    is position-masked scatter/gather with zero recompilation across
    admission, eviction, prefix hits, and CoW.
    """

    def __init__(self, params, cfg: ModelConfig,
                 policy: CompressionPolicy = NO_POLICY,
                 compress: bool = True, num_slots: int = 4,
                 max_seq: int = 256, sampling: SamplingConfig = GREEDY,
                 max_prompt: Optional[int] = None, tick_chunk: int = 8,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None, page_size: int = 16,
                 num_pages: Optional[int] = None, draft_params=None,
                 draft_cfg: Optional[ModelConfig] = None,
                 draft_policy: CompressionPolicy = NO_POLICY,
                 spec_k: int = 4, metrics_every: int = 1):
        bad = left_pad_unsupported(cfg)
        if bad:
            raise ValueError(
                "continuous batching needs maskable left-padding and "
                f"per-slot positions; {sorted(bad)} supports neither "
                "(see left_pad_unsupported) — use ServeEngine "
                "(--engine static) with equal-length batches")
        self.params, self.cfg, self.policy = params, cfg, policy
        self.compress, self.sampling = compress, sampling
        self.num_slots, self.max_seq = num_slots, max_seq
        self.tick_chunk = max(1, tick_chunk)
        self.buckets = C.prompt_buckets(min(max_prompt or max_seq // 2,
                                            max_seq))
        self.sched = Scheduler(num_slots)
        self.pos = np.zeros(num_slots, np.int32)     # next decode position
        self.pad = np.zeros(num_slots, np.int32)     # left-pad inside bucket
        self.last_tok = np.zeros(num_slots, np.int32)
        self._keys = jnp.zeros((num_slots, 2), jnp.uint32)
        self.ticks = 0
        self.active_slot_ticks = 0
        self.prefill_chunks = 0
        self.metrics_every = max(1, metrics_every)
        self.paged = bool(prefix_cache or prefill_chunk
                          or draft_params is not None)
        self.prefix_cache, self.prefill_chunk = prefix_cache, prefill_chunk
        cfg_, pol_, smp_ = cfg, policy, sampling

        if self.paged:
            if prefill_chunk is not None and prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1: "
                                 f"{prefill_chunk}")
            self.page_size = page_size
            self.slot_pages = PG.pages_for(max_seq, page_size)
            self.num_pages = num_pages or 1 + num_slots * self.slot_pages
            self._pool = PG.init_page_pool(transformer, cfg,
                                           self.num_pages, page_size)
            self.pages = PG.PageTable(self.num_pages, page_size)
            self.page_map = np.zeros((num_slots, self.slot_pages), np.int32)
            self._owned = [[] for _ in range(num_slots)]
            self.cursor = np.full(num_slots, -1, np.int32)  # -1: not prefill
            self.plen = np.zeros(num_slots, np.int32)
            self.spec = None
            if draft_params is not None:
                if not sampling.greedy:
                    raise ValueError(
                        "speculative decoding is greedy-only (acceptance "
                        "compares argmax streams) — use GREEDY sampling")
                self.spec = DraftWorker(
                    draft_params, draft_cfg, draft_policy,
                    compress=compress, num_slots=num_slots,
                    max_seq=max_seq, buckets=list(self.buckets),
                    spec_k=spec_k)

            def _span_chunk(params, tokens, pool, pos, page_map, valid_len):
                """One prefill chunk for one slot: tokens (1, c) at
                absolute positions pos..pos+c-1 (valid_len masks the
                padded tail); returns the logits of the LAST VALID
                position — the first generated token on the final
                chunk."""
                logits, pool = transformer.decode_span(
                    params, tokens, pool, pos, cfg_, pol_,
                    compress=compress, page_map=page_map,
                    valid_len=valid_len, wire=True)
                last = jnp.take_along_axis(
                    logits, (valid_len - 1)[:, None, None], axis=1)[:, 0]
                return last, pool

            def _decode_paged(params, tokens, pool, pos, page_map, keys):
                """T=1 decode tick for every slot through the page maps.
                Non-decoding slots ride along with pos 0 and an all-trash
                map row — their garbage lands in the trash page."""
                logits, pool = transformer.decode_span(
                    params, tokens[:, None], pool, pos, cfg_, pol_,
                    compress=compress, page_map=page_map, wire=True)
                toks, keys = sample_tokens(logits[:, 0], keys, smp_)
                return toks, pool, keys

            def _verify(params, span, pool, pos, page_map):
                """Speculative verification: span (B, k+1) = [last token,
                k draft proposals]; the target's greedy argmax at every
                position decides acceptance host-side."""
                logits, pool = transformer.decode_span(
                    params, span, pool, pos, cfg_, pol_,
                    compress=compress, page_map=page_map, wire=True)
                return jnp.argmax(logits, -1).astype(jnp.int32), pool

            def _sample1(logits, key):
                tok, key1 = sample_tokens(logits, key[None], smp_)
                return tok[0], key1[0]

            self._span_chunk = jax.jit(_span_chunk, donate_argnums=(2,))
            self._decode_paged = jax.jit(_decode_paged, donate_argnums=(2,))
            self._verify = jax.jit(_verify, donate_argnums=(2,))
            self._sample1 = jax.jit(_sample1)
            return

        self._caches = C.init_slot_caches(transformer, cfg, num_slots,
                                          max_seq)

        def _insert(params, tokens, pad, caches, slot, key):
            """Prefill one request at its bucket length and splice its KV
            into ``slot``; returns its first sampled token (the TTFT
            token comes out of the prefill logits, no extra decode)."""
            logits, one = transformer.prefill(
                params, _make_batch(cfg_, tokens), cfg_, pol_,
                cache_len=max_seq, compress=compress, pad_len=pad, wire=True)
            caches = C.write_slot(caches, one, slot)
            tok, key1 = sample_tokens(logits.reshape(1, -1), key[None], smp_)
            return tok[0], caches, key1[0]

        def _decode(params, tokens, caches, pos, pad, keys):
            """One tick for every slot: per-slot position/pad/PRNG key.
            Inactive slots decode garbage into their own row only; it is
            never valid under the position mask and is overwritten by the
            next refill."""
            logits, caches = transformer.decode_step(
                params, tokens, caches, pos, cfg_, pol_, compress=compress,
                pad_len=pad, wire=True)
            toks, keys = sample_tokens(logits, keys, smp_)
            return toks, caches, keys

        chunk = self.tick_chunk

        def _decode_chunk(params, tokens, caches, pos, pad, active, keys):
            """``tick_chunk`` decode ticks in one program: inactive slots'
            tokens/positions are frozen (their garbage writes stay in
            their own row, invalid under the position mask); returns the
            (chunk, B) token history for ONE host sync."""
            def body(carry, _):
                tokens, caches, pos, keys = carry
                logits, caches = transformer.decode_step(
                    params, tokens, caches, pos, cfg_, pol_,
                    compress=compress, pad_len=pad, wire=True)
                toks, keys = sample_tokens(logits, keys, smp_)
                toks = jnp.where(active, toks, tokens)
                pos = pos + active.astype(pos.dtype)
                return (toks, caches, pos, keys), toks
            (tokens, caches, pos, keys), hist = jax.lax.scan(
                body, (tokens, caches, pos, keys), None, length=chunk)
            return tokens, caches, pos, keys, hist

        self._insert = jax.jit(_insert, donate_argnums=(3,))
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._decode_chunk = jax.jit(_decode_chunk, donate_argnums=(2,))

    # -- streaming API ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_token: Optional[int] = None, seed: int = 0) -> int:
        """Queue a request; returns its request id."""
        prompt = np.asarray(prompt, np.int32)
        if self.paged:
            k = self.spec.spec_k if self.spec else 0
            need = len(prompt) + max_new_tokens + k
            if need > self.max_seq:
                raise ValueError(
                    f"prompt {len(prompt)} + {max_new_tokens} new tokens"
                    + (f" + spec_k {k}" if k else "")
                    + f" exceeds max_seq={self.max_seq}")
            if PG.pages_for(need, self.page_size) > self.num_pages - 1:
                raise ValueError(
                    f"request needs {PG.pages_for(need, self.page_size)} "
                    f"pages; pool has {self.num_pages - 1}")
            if self.spec:
                bucket = C.bucket_for(len(prompt), self.buckets)
                if bucket + max_new_tokens + k > self.max_seq:
                    raise ValueError(
                        f"draft bucket {bucket} + {max_new_tokens} new + "
                        f"spec_k {k} exceeds draft max_seq={self.max_seq}")
        else:
            bucket = C.bucket_for(len(prompt), self.buckets)
            if bucket + max_new_tokens - 1 > self.max_seq:
                raise ValueError(
                    f"prompt bucket {bucket} + {max_new_tokens} new tokens "
                    f"exceeds max_seq={self.max_seq}")
        return self.sched.submit(prompt, max_new_tokens, eos_token,
                                 seed).req_id

    def step(self) -> List[ServeRequest]:
        """One engine tick: refill free slots from the queue (bucketed
        prefill per new request), then one decode step for every slot.
        Returns the requests that completed this tick."""
        finished = self._step_paged() if self.paged else self._step_slab()
        self._trace_tick(finished)
        return finished

    def _trace_tick(self, finished: List[ServeRequest]) -> None:
        """Per-tick telemetry: scheduler occupancy (+ page-pool occupancy
        and prefix-hit counters in paged mode) as counter tracks, one
        instant per completed request carrying its TTFT and decode rate.
        Pure host-side arithmetic on state the tick already computed —
        zero device ops, and a disabled tracer returns on the first
        line."""
        tr = trace.get_tracer()
        if tr is None:
            return
        for r in finished:
            tr.instant("serve.request_done", cat="serve",
                       tokens=len(r.tokens), ttft_s=round(r.ttft_s, 6),
                       decode_tok_per_s=round(r.decode_tok_per_s, 2))
        if self.ticks % self.metrics_every:
            return
        tr.counter("serve.sched", cat="serve", **self.sched.snapshot())
        if self.paged:
            ps = self.pages.stats()
            tr.counter("serve.pages", cat="serve",
                       **{k: ps[k] for k in
                          ("active_pages", "cached_pages", "free_pages",
                           "cow_copies", "prefix_hits",
                           "prefix_hit_tokens")})

    def _step_slab(self) -> List[ServeRequest]:
        """The non-paged (slab KV cache) tick body of :meth:`step`."""
        finished = []
        fills = self.sched.fills()
        if fills:
            with trace.span("serve.prefill", cat="serve",
                            slots=len(fills)):
                for slot, req in fills:
                    bucket = C.bucket_for(len(req.prompt), self.buckets)
                    toks = np.zeros((1, bucket), np.int32)
                    toks[0, bucket - len(req.prompt):] = req.prompt
                    pad = bucket - len(req.prompt)
                    tok, self._caches, key = self._insert(
                        self.params, jnp.asarray(toks),
                        jnp.asarray([pad], jnp.int32), self._caches,
                        jnp.int32(slot), request_key(req.seed))
                    self._keys = self._keys.at[slot].set(key)
                    self.pos[slot] = bucket
                    self.pad[slot] = pad
                    self.last_tok[slot] = int(tok)  # blocks => honest TTFT
                    done = self.sched.started(slot, int(tok))
                    if done is not None:
                        finished.append(done)
        active = self.sched.active_slots
        if not active:
            return finished
        reqs = [self.sched.slots[s] for s in active]
        min_rem = min(r.max_new_tokens - len(r.tokens) for r in reqs)
        chunkable = (self.tick_chunk > 1
                     and min_rem >= self.tick_chunk
                     and all(r.eos_token is None for r in reqs))
        with trace.span("serve.decode", cat="serve", slots=len(active),
                        ticks=self.tick_chunk if chunkable else 1):
            finished.extend(self._slab_decode(active, chunkable))
        return finished

    def _slab_decode(self, active, chunkable) -> List[ServeRequest]:
        finished = []
        if chunkable:
            # no slot can complete inside the chunk and none watches for
            # EOS => run tick_chunk decode steps in one program, one sync
            mask = np.zeros(self.num_slots, bool)
            mask[active] = True
            last, self._caches, _, self._keys, hist = self._decode_chunk(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad),
                jnp.asarray(mask), self._keys)
            hist_np = np.asarray(hist)              # (chunk, B)
            self.ticks += self.tick_chunk
            self.active_slot_ticks += self.tick_chunk * len(active)
            for slot in active:
                self.pos[slot] += self.tick_chunk
                self.last_tok[slot] = hist_np[-1, slot]
                for t in hist_np[:, slot]:
                    done = self.sched.token(slot, t)
                    if done is not None:            # only the last can
                        finished.append(done)
        else:
            toks, self._caches, self._keys = self._decode(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad), self._keys)
            toks_np = np.asarray(toks)
            self.ticks += 1
            self.active_slot_ticks += len(active)
            for slot in active:
                self.pos[slot] += 1
                self.last_tok[slot] = toks_np[slot]
                done = self.sched.token(slot, toks_np[slot])
                if done is not None:
                    finished.append(done)
        return finished

    # -- paged mode: admission / chunked prefill / decode / speculation -----

    def _can_place(self, req: ServeRequest) -> bool:
        """Admission gate: enough pages (free + LRU-evictable) to cover the
        request's whole span.  Conservative — a prefix hit only reduces
        the fresh-page need."""
        k = self.spec.spec_k if self.spec else 0
        need = PG.pages_for(len(req.prompt) + req.max_new_tokens + k,
                            self.page_size)
        return self.pages.available() >= need

    def _place(self, slot: int, req: ServeRequest) -> None:
        """Claim pages for the whole span [0, L + max_new (+ spec_k)),
        splice any cached prefix in front, and start the prefill cursor
        after the matched tokens."""
        L = len(req.prompt)
        k = self.spec.spec_k if self.spec else 0
        matched = (self.pages.match_prefix(req.prompt)
                   if self.prefix_cache else [])
        n_need = PG.pages_for(L + req.max_new_tokens + k, self.page_size)
        row = np.zeros(self.slot_pages, np.int32)
        row[:len(matched)] = matched
        owned = list(matched)
        for j in range(len(matched), n_need):
            pid = self.pages.alloc()
            row[j] = pid
            owned.append(pid)
        self.page_map[slot] = row
        self._owned[slot] = owned
        self.cursor[slot] = len(matched) * self.page_size
        self.plen[slot] = L

    def _release(self, slot: int) -> None:
        self.pages.release(self._owned[slot])
        self._owned[slot] = []
        self.page_map[slot] = 0
        self.cursor[slot] = -1
        self.pos[slot] = 0

    def _prefill_tick(self, slot: int) -> Optional[ServeRequest]:
        """Advance one prefill chunk for ``slot``.  On the final chunk,
        sample the first token (TTFT), index the prompt's full pages for
        sharing, and prefill the draft; a 1-token request can complete
        right here."""
        req = self.sched.slots[slot]
        L, cur = int(self.plen[slot]), int(self.cursor[slot])
        c = self.prefill_chunk or C.bucket_for(L - cur, self.buckets)
        cl = min(c, L - cur)
        toks = np.zeros((1, c), np.int32)
        toks[0, :cl] = req.prompt[cur:cur + cl]
        last, self._pool = self._span_chunk(
            self.params, jnp.asarray(toks), self._pool,
            jnp.asarray([cur], jnp.int32),
            jnp.asarray(self.page_map[slot:slot + 1]),
            jnp.asarray([cl], jnp.int32))
        self.prefill_chunks += 1
        cur += cl
        if cur < L:
            self.cursor[slot] = cur
            return None
        tok, key = self._sample1(last, request_key(req.seed))
        self._keys = self._keys.at[slot].set(key)
        self.cursor[slot] = -1
        self.pos[slot] = L
        self.last_tok[slot] = int(tok)
        if self.prefix_cache:
            full = (L - 1) // self.page_size
            self.pages.register_prefix(
                req.prompt, [int(p) for p in self.page_map[slot, :full]])
        if self.spec:
            self.spec.insert(slot, req.prompt)
        done = self.sched.started(slot, int(tok))
        if done is not None:
            self._release(slot)
        return done

    def _cow_guard(self, slots: List[int], span: int) -> None:
        """Before a decode tick writes positions [pos, pos + span), route
        every logical page FIRST touched this tick through
        ``PageTable.writable`` — a shared or prefix-indexed page is
        copy-on-write swapped for a private one.  The engine's own
        invariants (prefix match capped at full prompt pages, decode
        pages allocated fresh) make a copy rare, but the gate is what
        guarantees a shared page is never written in place."""
        p = self.page_size
        for s in slots:
            t = int(self.pos[s])
            for j in range(-(-t // p), (t + span - 1) // p + 1):
                pid = int(self.page_map[s, j])
                if pid == PG.TRASH_PAGE:
                    continue            # beyond the allocated span
                new, copy = self.pages.writable(pid)
                if new != pid:
                    if copy:
                        self._pool = PG.copy_pages(
                            self._pool, jnp.int32(pid), jnp.int32(new))
                    self.page_map[s, j] = new
                    own = self._owned[s]
                    own[own.index(pid)] = new

    def _step_paged(self) -> List[ServeRequest]:
        """One paged tick: admit while pages last, advance ONE chunk per
        prefilling slot, then one decode (or speculative) tick for every
        decoding slot — prefill chunks interleave with decode instead of
        stalling it."""
        finished = []
        for slot, req in self.sched.fills(self._can_place):
            self._place(slot, req)
        pref = [s for s in self.sched.active_slots if self.cursor[s] >= 0]
        if pref:
            with trace.span("serve.prefill", cat="serve",
                            slots=len(pref)):
                for slot in pref:
                    done = self._prefill_tick(slot)
                    if done is not None:
                        finished.append(done)
        dec = [s for s in self.sched.active_slots if self.cursor[s] < 0]
        if not dec:
            return finished
        span = 1 + (self.spec.spec_k if self.spec else 0)
        self._cow_guard(dec, span)
        toks = self.last_tok.copy()
        posv = np.zeros(self.num_slots, np.int32)
        pmap = np.zeros_like(self.page_map)
        posv[dec] = self.pos[dec]
        pmap[dec] = self.page_map[dec]
        self.ticks += 1
        self.active_slot_ticks += len(dec)
        if self.spec:
            with trace.span("serve.spec", cat="serve", slots=len(dec),
                            spec_k=self.spec.spec_k):
                finished.extend(self._spec_tick(dec, toks, posv, pmap))
            return finished
        with trace.span("serve.decode", cat="serve", slots=len(dec),
                        ticks=1):
            t, self._pool, self._keys = self._decode_paged(
                self.params, jnp.asarray(toks), self._pool,
                jnp.asarray(posv), jnp.asarray(pmap), self._keys)
            t_np = np.asarray(t)
        for s in dec:
            self.pos[s] += 1
            self.last_tok[s] = t_np[s]
            done = self.sched.token(s, t_np[s])
            if done is not None:
                finished.append(done)
                self._release(s)
        return finished

    def _spec_tick(self, dec, toks, posv, pmap) -> List[ServeRequest]:
        """Draft proposes k tokens per slot; target verifies all k+1
        positions in one span; the longest matching prefix (bonus capped
        at k, see speculative.accept_greedy) is emitted.  Every emitted
        token is the target's own argmax — output is exactly plain
        greedy."""
        finished = []
        k = self.spec.spec_k
        props = self.spec.propose(toks)                     # (B, k)
        span = np.concatenate([toks[:, None], props], 1)    # (B, k+1)
        g, self._pool = self._verify(
            self.params, jnp.asarray(span), self._pool, jnp.asarray(posv),
            jnp.asarray(pmap))
        g_np = np.asarray(g)
        for s in dec:
            a = accept_greedy(props[s], g_np[s], k)
            self.spec.record(k, a)
            req = self.sched.slots[s]
            e = min(a + 1, k, req.max_new_tokens - len(req.tokens))
            e = max(e, 1)
            used, done = 0, None
            for tok in g_np[s, :e]:
                used += 1
                done = self.sched.token(s, int(tok))
                if done is not None:
                    break
            self.pos[s] += used
            self.last_tok[s] = int(g_np[s, used - 1])
            self.spec.commit(s, used)
            if done is not None:
                finished.append(done)
                self._release(s)
        return finished

    def drain(self) -> List[ServeRequest]:
        """Run steps until queue and slots are empty; returns everything
        that finished during the drain (in completion order)."""
        out = []
        while not self.sched.idle:
            out.extend(self.step())
        return out

    def warmup(self) -> dict:
        """Compile every prompt-bucket insert program + the decode program
        by serving dummy requests, then reset the scheduler/metrics.  After
        this, slot eviction/refill at ANY prompt length triggers zero
        recompilations (see compile_stats)."""
        if self.paged:
            return self._warmup_paged()
        for b in self.buckets:
            new = min(self.tick_chunk + 2, self.max_seq - b + 1)
            self.submit(np.zeros(b, np.int32), max_new_tokens=new)
        self.drain()
        if self.tick_chunk > 1:
            # the drain may never satisfy the chunkable condition (slot
            # count / bucket-headroom geometry), so compile the multi-tick
            # program directly: an all-inactive mask freezes every slot's
            # tokens/positions and the scheduler is idle, so only benign
            # garbage rows are written (invalid under the position mask)
            mask = np.zeros(self.num_slots, bool)
            _, self._caches, _, _, _ = self._decode_chunk(
                self.params, jnp.asarray(self.last_tok), self._caches,
                jnp.asarray(self.pos), jnp.asarray(self.pad),
                jnp.asarray(mask), self._keys)
        self.sched = Scheduler(self.num_slots)
        self.ticks = self.active_slot_ticks = 0
        return self.compile_stats()

    def _warmup_paged(self) -> dict:
        """Compile the full paged program set (every chunk shape + decode
        + sampling + speculation) by serving dummy requests, then reset
        the scheduler, the page table and all metrics.  Prefix matching is
        disabled during the warm drain so every chunk-shape bucket really
        compiles (a dummy-prefix hit would skip a shape)."""
        k = self.spec.spec_k if self.spec else 0
        prefix, self.prefix_cache = self.prefix_cache, False
        lens = {b for b in self.buckets if b + 2 + k <= self.max_seq}
        for n in sorted(lens):
            self.submit(np.zeros(n, np.int32), max_new_tokens=2)
        self.drain()
        self.prefix_cache = prefix
        self.pages = PG.PageTable(self.num_pages, self.page_size)
        self.page_map[:] = 0
        self._owned = [[] for _ in range(self.num_slots)]
        self.cursor[:] = -1
        self.pos[:] = 0
        self.last_tok[:] = 0
        self.sched = Scheduler(self.num_slots)
        self.ticks = self.active_slot_ticks = self.prefill_chunks = 0
        if self.spec:
            self.spec.proposed = self.spec.accepted = 0
        return self.compile_stats()

    # -- metrics ------------------------------------------------------------

    def compile_stats(self) -> dict:
        """jit compilation-cache sizes: one decode entry, one multi-tick
        chunk entry, one insert entry per warmed prompt bucket.  Unchanged
        counts across a serving run == zero recompilations."""
        if self.paged:
            s = {"decode_compiles": self._decode_paged._cache_size(),
                 "span_compiles": self._span_chunk._cache_size(),
                 "sample_compiles": self._sample1._cache_size(),
                 "verify_compiles": self._verify._cache_size()}
            if self.spec:
                s.update(self.spec.compile_stats())
            return s
        return {"decode_compiles": self._decode._cache_size(),
                "decode_chunk_compiles": self._decode_chunk._cache_size(),
                "insert_compiles": self._insert._cache_size()}

    def stats(self) -> dict:
        s = self.sched.stats()
        s.update({
            "ticks": self.ticks,
            "slot_utilization": (round(
                self.active_slot_ticks / (self.ticks * self.num_slots), 3)
                if self.ticks else 0.0),
            "slot_cache_bytes": (
                PG.pool_bytes(self._pool) // self.num_slots if self.paged
                else C.slot_bytes(self._caches, self.num_slots)),
            "boundary_bytes_per_tok": (
                round(boundary_wire_bytes_per_token(
                    self.policy, self.cfg.d_model,
                    num_cuts=max(0, len(transformer.segment_bounds(
                        self.cfg.num_groups,
                        self.policy.num_stages)) - 1)), 1)
                if self.compress else 0.0),
            "sampling": self.sampling.name,
        })
        if self.paged:
            s["prefill_chunks"] = self.prefill_chunks
            s["prefill_chunk"] = self.prefill_chunk or 0
            s.update(self.pages.stats())
            if self.spec:
                s.update(self.spec.stats())
        s.update(self.compile_stats())
        return s
