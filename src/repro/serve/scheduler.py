"""Continuous-batching scheduler: admission queue + per-slot lifecycle.

Pure host-side logic (no jax) so it is unit-testable in isolation.  The
engine owns the device programs; the scheduler owns WHO runs WHERE:

  submit(..)        -> request enters the FIFO admission queue
  fills()           -> (slot, request) placements for every free slot
  started(..)       -> request is prefilled and decoding (records TTFT)
  token(..)         -> append a decoded token; reports completion
                       (EOS or max_new_tokens)
  finished(..)      -> slot freed (immediately refillable), request done

Completion semantics: the EOS token, when configured, is appended to the
output and ends the request (the standard "include the stop token" rule);
``max_new_tokens`` bounds the output length either way.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

FREE, ACTIVE = "free", "active"


@dataclasses.dataclass
class ServeRequest:
    """One generation request and its per-request serve metrics."""
    req_id: int
    prompt: np.ndarray                    # (L,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    seed: int = 0
    # -- lifecycle / results (filled by the scheduler) ----------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    slot: int = -1

    @property
    def out(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    @property
    def ttft_s(self) -> float:
        """Submit -> first token (queue wait + prefill)."""
        return self.first_token_t - self.submit_t

    @property
    def decode_tok_per_s(self) -> float:
        dt = self.finish_t - self.first_token_t
        n = len(self.tokens) - 1                  # tokens after the first
        return n / dt if dt > 0 and n > 0 else 0.0

    def metrics(self) -> dict:
        return {"req_id": self.req_id, "prompt_len": int(len(self.prompt)),
                "new_tokens": len(self.tokens),
                "ttft_s": round(self.ttft_s, 4),
                "decode_tok_per_s": round(self.decode_tok_per_s, 1)}


class Scheduler:
    """FIFO admission over ``num_slots`` decode slots."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.queue: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * num_slots
        self.done: List[ServeRequest] = []
        self._next_id = 0

    # -- admission ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_token: Optional[int] = None, seed: int = 0,
               now: Optional[float] = None) -> ServeRequest:
        req = ServeRequest(self._next_id, np.asarray(prompt, np.int32),
                           max_new_tokens, eos_token, seed,
                           submit_t=time.time() if now is None else now)
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self._next_id += 1
        self.queue.append(req)
        return req

    def fills(self, can_place=None) -> List[Tuple[int, ServeRequest]]:
        """Pop queued requests into free slots (FIFO, lowest slot first).

        ``can_place(req) -> bool``: optional admission gate (e.g. "enough
        KV pages free").  Admission stops at the first non-placeable
        request — later queue entries never jump the FIFO order.
        """
        placements = []
        for slot in range(self.num_slots):
            if not self.queue:
                break
            if self.slots[slot] is None:
                if can_place is not None and not can_place(self.queue[0]):
                    break
                req = self.queue.popleft()
                req.slot = slot
                self.slots[slot] = req
                placements.append((slot, req))
        return placements

    # -- per-tick lifecycle -------------------------------------------------

    def started(self, slot: int, first_token: int,
                now: Optional[float] = None) -> Optional[ServeRequest]:
        """Prefill produced the request's first token (TTFT point)."""
        req = self.slots[slot]
        req.first_token_t = time.time() if now is None else now
        return self._append(req, first_token, req.first_token_t)

    def token(self, slot: int, token: int,
              now: Optional[float] = None) -> Optional[ServeRequest]:
        """A decode tick produced ``token`` for ``slot``.  Returns the
        request iff it just completed (slot is freed for refill)."""
        return self._append(self.slots[slot], token,
                            time.time() if now is None else now)

    def _append(self, req: ServeRequest, token: int,
                now: float) -> Optional[ServeRequest]:
        req.tokens.append(int(token))
        eos = req.eos_token is not None and int(token) == req.eos_token
        if eos or len(req.tokens) >= req.max_new_tokens:
            req.finish_t = now
            self.slots[req.slot] = None
            self.done.append(req)
            return req
        return None

    # -- state --------------------------------------------------------------

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and not any(self.slots)

    def snapshot(self) -> dict:
        """Instantaneous occupancy counters — the per-tick telemetry grain
        (:func:`stats` aggregates the whole run; this is one moment).
        Cheap enough to call every tick: pure host-side len() arithmetic."""
        active = len(self.active_slots)
        return {
            "queued": len(self.queue),
            "active_slots": active,
            "free_slots": len(self.slots) - active,
            "completed": len(self.done),
        }

    def stats(self) -> dict:
        done = self.done
        return {
            "completed": len(done),
            "queued": len(self.queue),
            "active": len(self.active_slots),
            "mean_ttft_s": (round(float(np.mean([r.ttft_s for r in done])), 4)
                            if done else 0.0),
            "mean_decode_tok_per_s": (
                round(float(np.mean([r.decode_tok_per_s for r in done])), 1)
                if done else 0.0),
        }
