"""Token sampling for the serve engines.

One frozen :class:`SamplingConfig` per engine (hashable => a jit-static
argument: switching greedy/temperature/top-k/top-p picks a program, it is
not a traced branch), with PER-SLOT PRNG keys: every request carries its
own key chain derived from its seed, so a slot's sample stream is a pure
function of the request — independent of which other requests share the
batch and of which slot it landed in.  That is what keeps sampled
continuous-batching output identical to serving the request alone.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """``temperature == 0`` => greedy argmax (top_k/top_p ignored).

    ``top_k > 0``  : keep only the k highest-probability tokens.
    ``top_p < 1``  : nucleus — keep the smallest probability mass >= top_p.
    Filters compose (top-k first, then top-p), as in standard samplers.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @property
    def name(self) -> str:
        if self.greedy:
            return "greedy"
        parts = [f"t={self.temperature:g}"]
        if self.top_k:
            parts.append(f"k={self.top_k}")
        if self.top_p < 1:
            parts.append(f"p={self.top_p:g}")
        return ",".join(parts)


GREEDY = SamplingConfig()


def request_key(seed: int) -> jnp.ndarray:
    """The per-request PRNG key a slot starts from."""
    return jax.random.PRNGKey(seed)


def _filter_logits(logits, cfg: SamplingConfig):
    """Mask logits outside the top-k / nucleus to -inf.  logits: (B, V)."""
    v = logits.shape[-1]
    if cfg.top_k and cfg.top_k < v:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][:, -1:]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    if cfg.top_p < 1.0:
        sorted_ = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep every token up to and including the one crossing top_p
        keep_sorted = cum - probs < cfg.top_p
        cutoff = jnp.min(jnp.where(keep_sorted, sorted_, jnp.inf),
                         axis=-1)[:, None]
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return logits


def sample_tokens(logits, keys, cfg: SamplingConfig):
    """Next token per slot.  logits: (B, V); keys: (B, 2) uint32.

    Returns ``(tokens (B,) int32, new_keys (B, 2))``.  Greedy never
    consumes randomness, so the key chain only advances when sampling —
    the same request replayed greedy/sampled stays reproducible.
    """
    logits = logits.astype(jnp.float32)
    if cfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
    logits = _filter_logits(logits / cfg.temperature, cfg)

    def one(lg, key):
        step_key, next_key = jax.random.split(key)
        return jax.random.categorical(step_key, lg).astype(jnp.int32), next_key

    return jax.vmap(one)(logits, keys)
