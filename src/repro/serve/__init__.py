"""Serving subsystem: continuous batching over compressed stage boundaries.

  engine.py      — ServeEngine (static batch) + ContinuousEngine
                   (streaming submit()/step()/drain(), slot eviction/
                   refill; paged mode: prefix sharing, chunked prefill,
                   speculative decoding)
  scheduler.py   — admission queue + per-slot request lifecycle (host-side)
  cache.py       — slot-indexed KV slabs, bucketed prompt lengths
  pages.py       — refcounted page pool, prefix-hash sharing, CoW
  speculative.py — draft proposer + greedy acceptance
  sampling.py    — greedy / temperature / top-k / top-p, per-slot PRNG keys
"""
from repro.serve.engine import ContinuousEngine, Request, ServeEngine
from repro.serve.pages import PagePoolFull, PageTable
from repro.serve.sampling import GREEDY, SamplingConfig
from repro.serve.scheduler import Scheduler, ServeRequest
from repro.serve.speculative import DraftWorker

__all__ = ["ContinuousEngine", "Request", "ServeEngine", "GREEDY",
           "SamplingConfig", "Scheduler", "ServeRequest", "PageTable",
           "PagePoolFull", "DraftWorker"]
