"""Serving subsystem: continuous batching over compressed stage boundaries.

  engine.py    — ServeEngine (static batch) + ContinuousEngine
                 (streaming submit()/step()/drain(), slot eviction/refill)
  scheduler.py — admission queue + per-slot request lifecycle (host-side)
  cache.py     — slot-indexed KV pages, bucketed prompt lengths
  sampling.py  — greedy / temperature / top-k / top-p, per-slot PRNG keys
"""
from repro.serve.engine import ContinuousEngine, Request, ServeEngine
from repro.serve.sampling import GREEDY, SamplingConfig
from repro.serve.scheduler import Scheduler, ServeRequest

__all__ = ["ContinuousEngine", "Request", "ServeEngine", "GREEDY",
           "SamplingConfig", "Scheduler", "ServeRequest"]
