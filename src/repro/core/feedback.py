"""Error-compensation state and message functions (paper Sec. 2.4, 2.5).

One abstraction covers every compensation thread in the repo:

  * the per-boundary fw/bw buffers of the simulated boundary
    (core/boundary.py) and the real pipeline (transport/pipeline.py);
  * AQ-SGD's dataset-indexed ``(num_samples, *feat)`` buffer;
  * the data-parallel gradient-reduce residuals
    (transport/collectives.py).

:class:`FeedbackState` is the unified pytree: static ``(scope, direction,
mode)`` metadata plus three array slots — ``resid`` (the sender-side
compensation buffer), ``mirror`` (the receiver-side replica a real wire
keeps for delta-coded modes) and ``agg`` (the replicated aggregate of the
DP EF21 reduce).  Unused slots are size-0 placeholders so the pytree
structure is mode-stable (jit caches don't fragment per policy).

:data:`FEEDBACK_REGISTRY` holds one :class:`FeedbackMode` entry per mode:
its message function, whether it is delta-coded (the receiver cannot
decode the payload without a mirror), whether its buffer is indexed by
dataset example id, and which scopes may use it.

Message semantics (each maps ``(compressor, x, buffer) -> (message,
new_buffer)``; ``message`` is what crosses the wire):

  EF       (Seide et al.):     m = C(x + e);           e' = x + e - m
  EF21     (Richtarik et al.): m = g + C(x - g);       g' = m
  EF-mixed (this paper):       m = C_{K/2}(x) + C_{K/2}(e);  e' = x + e - m
  AQ-SGD   (Wang et al.):      per-example EF21 on activations only:
                               m_i = b_i + C(x_i - b_i); b_i' = m_i

Buffers are plain arrays; AQ-SGD's buffer is ``(num_samples, *feat)`` and
is gathered/scattered by example id.  All functions are pure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor, topk_compress


def ef_message(comp: Compressor, x: jnp.ndarray, e: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xe = x + e
    m = comp(xe)
    return m, xe - m


def ef21_message(comp: Compressor, x: jnp.ndarray, g: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = g + comp(x - g)
    return m, m


def efmixed_message(comp: Compressor, x: jnp.ndarray, e: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if comp.kind != "topk":
        raise ValueError("EF-mixed is defined for TopK compression")
    half = comp.k_frac / 2.0
    m = topk_compress(x, half) + topk_compress(e, half)
    return m, (x + e) - m


def aqsgd_message(comp: Compressor, x: jnp.ndarray, buf: jnp.ndarray,
                  ids: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-example EF21.  ``buf``: (num_samples, *feat); ``ids``: (B,) int32."""
    b = buf[ids]                                # (B, *feat)
    m = b + comp(x - b)
    new_buf = buf.at[ids].set(m)
    return m, new_buf


# ---------------------------------------------------------------------------
# The mode registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FeedbackMode:
    """One registry entry: how a compensation mode inits, messages, and
    addresses its buffer.

    ``delta_coded``: the wire message is a compressed DELTA against the
    buffer (m = buf + C(x - buf)) — the receiver cannot reconstruct m from
    the payload alone, so a real (packed-wire) transport keeps a
    receiver-side MIRROR of the sender's buffer (Wang et al. AQ-SGD
    Sec. 3: both machines store the activation buffer).  EF / EF-mixed
    messages decode directly from the payload.

    ``per_example``: the buffer is ``(num_samples, *feat)``, indexed by
    dataset example id (AQ-SGD); otherwise it is slotted by batch row /
    microbatch index.

    ``scopes``: where the mode is valid — at a stage boundary
    ("boundary"), on the DP gradient reduce ("dp"), and/or on the
    tensor-parallel activation all-gather ("tp").
    """
    name: str
    message: Callable
    delta_coded: bool = False
    per_example: bool = False
    scopes: Tuple[str, ...] = ("boundary",)


def _none_message(comp, x, buf, ids=None):
    return comp(x), buf


FEEDBACK_REGISTRY = {
    "none": FeedbackMode("none", _none_message,
                         scopes=("boundary", "dp", "tp")),
    "ef": FeedbackMode(
        "ef", lambda comp, x, buf, ids=None: ef_message(comp, x, buf),
        scopes=("boundary", "dp", "tp")),
    "ef21": FeedbackMode(
        "ef21", lambda comp, x, buf, ids=None: ef21_message(comp, x, buf),
        delta_coded=True, scopes=("boundary", "dp", "tp")),
    "efmixed": FeedbackMode(
        "efmixed",
        lambda comp, x, buf, ids=None: efmixed_message(comp, x, buf)),
    "aqsgd": FeedbackMode(
        "aqsgd",
        lambda comp, x, buf, ids=None: aqsgd_message(comp, x, buf, ids),
        delta_coded=True, per_example=True),
}

# Modes whose wire message is a compressed delta (receiver keeps a mirror).
DELTA_CODED_MODES = tuple(m.name for m in FEEDBACK_REGISTRY.values()
                          if m.delta_coded)


def get_mode(mode: str) -> FeedbackMode:
    try:
        return FEEDBACK_REGISTRY[mode]
    except KeyError:
        raise ValueError(f"unknown feedback mode {mode!r}; known: "
                         f"{sorted(FEEDBACK_REGISTRY)}") from None


def needs_recv_mirror(mode: str) -> bool:
    """True when a real (packed-wire) transport of this mode must keep a
    receiver-side replica of the compensation buffer."""
    return get_mode(mode).delta_coded


def feedback_message(mode: str, comp: Compressor, x: jnp.ndarray,
                     buf, ids=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch. ``mode='none'`` ignores the buffer and returns it unchanged."""
    return get_mode(mode).message(comp, x, buf, ids)


# ---------------------------------------------------------------------------
# The unified state pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FeedbackState:
    """One compensation thread's state, as a registered pytree.

    Array slots (pytree data — what checkpoints persist):
      resid  : the sender-side buffer (EF's error e / EF21's model g /
               AQ-SGD's per-example rows / the DP residuals).
      mirror : the receiver-side replica of ``resid`` a real packed-wire
               transport keeps for delta-coded modes (size-0 otherwise;
               the simulated boundary collapses both ends into ``resid``).
      agg    : the DP EF21 reduce's replicated aggregate G = sum_r w_r
               (size-0 otherwise).

    Static metadata (pytree aux — jit-hashable, never traced):
      scope     : "boundary" | "dp"
      direction : "fw" | "bw" (boundary) | "grad" (dp)
      mode      : a :data:`FEEDBACK_REGISTRY` key
    """
    resid: Any
    mirror: Any
    agg: Any
    scope: str = "boundary"
    direction: str = "fw"
    mode: str = "none"

    def __post_init__(self):
        spec = get_mode(self.mode)
        if self.scope not in spec.scopes:
            raise ValueError(
                f"feedback mode {self.mode!r} is not valid at scope "
                f"{self.scope!r} (valid scopes: {spec.scopes})")

    @property
    def spec(self) -> FeedbackMode:
        return FEEDBACK_REGISTRY[self.mode]

    def replace(self, **kw) -> "FeedbackState":
        return dataclasses.replace(self, **kw)

    def map(self, f) -> "FeedbackState":
        """Apply ``f`` to every array slot (structure/metadata preserved)."""
        return self.replace(resid=jax.tree.map(f, self.resid),
                            mirror=jax.tree.map(f, self.mirror),
                            agg=jax.tree.map(f, self.agg))


jax.tree_util.register_dataclass(
    FeedbackState, data_fields=("resid", "mirror", "agg"),
    meta_fields=("scope", "direction", "mode"))


def init_buffer(mode: str, feat_shape, dtype=jnp.float32, num_samples: int = 0,
                batch: int = 0):
    """Initial buffer array for one boundary direction.

    Global-buffer modes (ef/ef21/efmixed) keep one buffer of the full
    boundary-tensor shape ``(batch, *feat)`` (paper: "global error buffer
    ... added to the next batch").  AQ-SGD keeps ``(num_samples, *feat)``.
    ``mode='none'`` returns a size-0 placeholder so pytree structure is
    stable across policies.
    """
    spec = get_mode(mode)
    if mode == "none":
        return jnp.zeros((0,), dtype=dtype)
    if spec.per_example:
        assert num_samples > 0, f"{mode} needs the dataset size"
        return jnp.zeros((num_samples, *feat_shape), dtype=dtype)
    assert batch > 0, "global EF buffer needs the batch size"
    return jnp.zeros((batch, *feat_shape), dtype=dtype)


def init_feedback(mode: str, feat_shape, *, scope: str = "boundary",
                  direction: str = "fw", dtype=jnp.float32,
                  num_samples: int = 0, batch: int = 0) -> FeedbackState:
    """A fresh single-program :class:`FeedbackState` for one boundary
    direction (the simulated transport's view: ``mirror`` collapsed into
    ``resid``, ``agg`` unused)."""
    z = jnp.zeros((0,), dtype=dtype)
    return FeedbackState(
        resid=init_buffer(mode, feat_shape, dtype=dtype,
                          num_samples=num_samples, batch=batch),
        mirror=z, agg=z, scope=scope, direction=direction, mode=mode)


# ---------------------------------------------------------------------------
# Buffer row addressing (shared by every scan-carry consumer)
# ---------------------------------------------------------------------------
#
# The pipeline's scan carries stage-local buffers and touches ONE
# microbatch slice per tick; the row is the microbatch slot for global
# modes and the example ids for per-example modes.  These two helpers are
# the schedule- and scope-agnostic gather/scatter the registry exports:
# transport/pipeline.py uses them for both directions of every schedule,
# and the same addressing backs the dataset-sharded AQ-SGD + DP split
# (train/steps.py slices the example-id axis instead).

def gather_rows(buf, k, slot, ids, mode: str, v: int = 1):
    """One microbatch's slice of a feedback buffer (size-0 passes
    through).  ``k`` selects the virtual chunk when ``v > 1``; the row is
    ``ids`` for per-example modes, the microbatch ``slot`` otherwise."""
    if mode == "none":
        return buf
    row = ids if get_mode(mode).per_example else slot
    return buf[row] if v == 1 else buf[k, row]


def scatter_rows(buf, k, slot, ids, mode: str, v: int, new_slice, old_slice,
                 valid):
    """Masked functional update of one microbatch's slice (the inverse of
    :func:`gather_rows`)."""
    if mode == "none":
        return buf
    upd = jnp.where(valid, new_slice, old_slice).astype(buf.dtype)
    row = ids if get_mode(mode).per_example else slot
    return buf.at[row].set(upd) if v == 1 else buf.at[k, row].set(upd)


def shard_ids(ids, replica, num_samples: int, dp: int):
    """Translate global example ids into a replica's id-shard rows.

    AQ-SGD + DP shards the ``(num_samples, *feat)`` buffer by example id
    over the data axis: replica ``r`` owns rows
    ``[r * num_samples/dp, (r+1) * num_samples/dp)`` and gathers/scatters
    with LOCAL row indices, so the per-example compensation never leaves
    the replica.  The data stream must route example ``i`` to replica
    ``i // (num_samples/dp)`` (the synthetic stream's contiguous id blocks
    do; see launch/train.py) — an out-of-shard id would clamp to the
    shard edge, compensating against a wrong row.
    """
    if num_samples % dp:
        raise ValueError(
            f"aqsgd + dp shards the per-example buffer by id: num_samples "
            f"{num_samples} must be divisible by dp {dp}")
    return ids - replica * (num_samples // dp)
