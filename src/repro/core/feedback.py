"""Error-compensation message functions (paper Sec. 2.4, 2.5).

Each function maps ``(compressor, x, buffer) -> (message, new_buffer)``.
``message`` is what crosses the wire (and what the downstream stage sees);
``new_buffer`` is the updated compensation state.

Modes:
  EF       (Seide et al.):     m = C(x + e);           e' = x + e - m
  EF21     (Richtarik et al.): m = g + C(x - g);       g' = m
  EF-mixed (this paper):       m = C_{K/2}(x) + C_{K/2}(e);  e' = x + e - m
  AQ-SGD   (Wang et al.):      per-example EF21 on activations only:
                               m_i = b_i + C(x_i - b_i); b_i' = m_i

Buffers are plain arrays; AQ-SGD's buffer is ``(num_samples, *feat)`` and is
gathered/scattered by example id.  All functions are pure.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.compressors import Compressor, topk_compress


# Modes whose wire message is a compressed DELTA against the buffer
# (m = buf + C(x - buf)): the receiver cannot reconstruct m from the payload
# alone, so a real transport keeps a receiver-side MIRROR of the sender's
# buffer (Wang et al. AQ-SGD Sec. 3: both machines store the activation
# buffer).  EF / EF-mixed messages decode directly from the payload.
DELTA_CODED_MODES = ("ef21", "aqsgd")


def needs_recv_mirror(mode: str) -> bool:
    """True when a real (packed-wire) transport of this mode must keep a
    receiver-side replica of the compensation buffer."""
    return mode in DELTA_CODED_MODES


def ef_message(comp: Compressor, x: jnp.ndarray, e: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xe = x + e
    m = comp(xe)
    return m, xe - m


def ef21_message(comp: Compressor, x: jnp.ndarray, g: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = g + comp(x - g)
    return m, m


def efmixed_message(comp: Compressor, x: jnp.ndarray, e: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if comp.kind != "topk":
        raise ValueError("EF-mixed is defined for TopK compression")
    half = comp.k_frac / 2.0
    m = topk_compress(x, half) + topk_compress(e, half)
    return m, (x + e) - m


def aqsgd_message(comp: Compressor, x: jnp.ndarray, buf: jnp.ndarray,
                  ids: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-example EF21.  ``buf``: (num_samples, *feat); ``ids``: (B,) int32."""
    b = buf[ids]                                # (B, *feat)
    m = b + comp(x - b)
    new_buf = buf.at[ids].set(m)
    return m, new_buf


def feedback_message(mode: str, comp: Compressor, x: jnp.ndarray,
                     buf, ids=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch. ``mode='none'`` ignores the buffer and returns it unchanged."""
    if mode == "none":
        return comp(x), buf
    if mode == "ef":
        return ef_message(comp, x, buf)
    if mode == "ef21":
        return ef21_message(comp, x, buf)
    if mode == "efmixed":
        return efmixed_message(comp, x, buf)
    if mode == "aqsgd":
        return aqsgd_message(comp, x, buf, ids)
    raise ValueError(f"unknown feedback mode {mode}")


def init_buffer(mode: str, feat_shape, dtype=jnp.float32, num_samples: int = 0,
                batch: int = 0):
    """Initial buffer for a boundary direction.

    Global-buffer modes (ef/ef21/efmixed) keep one buffer of the full
    boundary-tensor shape ``(batch, *feat)`` (paper: "global error buffer
    ... added to the next batch").  AQ-SGD keeps ``(num_samples, *feat)``.
    ``mode='none'`` returns a size-0 placeholder so pytree structure is
    stable across policies.
    """
    if mode == "none":
        return jnp.zeros((0,), dtype=dtype)
    if mode == "aqsgd":
        assert num_samples > 0, "aqsgd needs the dataset size"
        return jnp.zeros((num_samples, *feat_shape), dtype=dtype)
    assert batch > 0, "global EF buffer needs the batch size"
    return jnp.zeros((batch, *feat_shape), dtype=dtype)
