"""Compression boundary: ``jax.custom_vjp`` around a SimulatedTransport.

A boundary sits at a pipeline-stage cut.  In a real MP system the forward
activation and the backward activation-gradient cross the network here; the
paper compresses both.  Following the paper (Sec. 2.1) we integrate the
boundary directly into the model with ``jax.custom_vjp`` — convergence-
equivalent to the distributed system.  The compression itself is delegated
to :class:`repro.transport.simulated.SimulatedTransport`, which implements
the shared ``Transport.fw/bw`` interface over the wire-codec registry
(repro/transport/codecs.py) — the same registry the real differentiable
``ppermute`` pipeline (repro/transport/pipeline.py) packs bytes with, so
both paths see identical numbers at the boundary.

Semantics (training):
  forward : y  = F(x)   where F is the fw compressor, optionally wrapped in
                         EF / EF21 / EF-mixed / AQ-SGD feedback;
  backward: gx = G(gy)  where G is the bw compressor, optionally wrapped in
                         EF / EF21 / EF-mixed feedback, or — with
                         ``reuse_indices`` — masking by the forward TopK mask.

State threading: feedback buffers are functional.  The *forward* buffer's
update is returned as a second output.  The *backward* buffer's update is
only known during backprop, so it is returned **as the cotangent of the
``bw_buf`` argument** — take ``grad`` w.r.t. ``bw_buf`` in the train step and
read the updated buffer out of the gradient pytree (see train/steps.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import BoundaryPolicy


def _transport(policy: BoundaryPolicy):
    # Lazy: repro.core.__init__ imports this module, and the transport
    # package imports repro.core.policy — a top-level import would cycle.
    from repro.transport.simulated import simulated_transport
    return simulated_transport(policy)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def boundary_apply(policy: BoundaryPolicy, x, fw_buf, bw_buf, ids):
    """Training-time boundary.  Returns ``(y, new_fw_state)``.

    ``fw_buf``/``bw_buf``: per-direction
    :class:`~repro.core.feedback.FeedbackState` (``resid`` size-0 when the
    direction has no feedback).  ``ids``: (B,) int32 example ids (AQ-SGD
    only; zeros otherwise).  The updated backward state is delivered as
    the cotangent of ``bw_buf``.
    """
    m, new_fw, _ = _transport(policy).fw(x, fw_buf, ids)
    return m, new_fw


def _boundary_fwd(policy: BoundaryPolicy, x, fw_buf, bw_buf, ids):
    m, new_fw, ctx = _transport(policy).fw(x, fw_buf, ids)
    residuals = (ctx, fw_buf, bw_buf, ids)
    return (m, new_fw), residuals


def _boundary_bwd(policy: BoundaryPolicy, residuals, cotangents):
    ctx, fw_buf, bw_buf, ids = residuals
    g_y, _g_new_fw = cotangents          # buffer output is aux — no gradient
    g_x, new_bw = _transport(policy).bw(g_y, bw_buf, ctx)
    zero_fw = jax.tree.map(jnp.zeros_like, fw_buf)
    zero_ids = np.zeros(ids.shape, dtype=jax.dtypes.float0)
    return (g_x, zero_fw, new_bw, zero_ids)


boundary_apply.defvjp(_boundary_fwd, _boundary_bwd)


def boundary_eval(policy: BoundaryPolicy, x, compress: bool):
    """Inference-time boundary: plain fw compressor or identity.

    The paper evaluates each trained model BOTH ways (Tables 1-4):
    compression kept on at inference vs switched off.
    """
    return policy.fw(x) if compress else x


def boundary_wire_eval(policy: BoundaryPolicy, x, compress: bool):
    """Serve-time boundary through the wire-codec registry.

    Unlike :func:`boundary_eval` (the in-process C(x)), this actually packs
    the stage-cut tensor into the same q8/TopK payload pytree the training
    pipeline puts on the wire (transport/codecs.py) and unpacks it on the
    "receiving" stage — a served decode exercises the real byte format.

    Packing is PER REQUEST (vmap over the batch dim): each serving slot is
    an independent stream on a real wire, so quantization scales are
    computed per request.  This also keeps a slot's numerics independent of
    its batch neighbours — the property that makes continuous-batching
    output bit-identical to solo generation.  TopK is per-example in the
    codec already; q8/q4 get per-request (rather than per-microbatch)
    scales, the only difference from the training-time payload.
    """
    if not compress or policy.fw.kind == "none":
        return x
    from repro.transport.codecs import codec_for
    codec = codec_for(policy.fw)
    k_frac = policy.fw.k_frac

    def one(xe):
        payload = codec.pack(xe[None], k_frac)
        return codec.unpack(payload, (1,) + xe.shape, xe.dtype)[0]

    return jax.vmap(one)(x)


def boundary_wire_eval_tokens(policy: BoundaryPolicy, x, compress: bool):
    """Per-(request, token) wire packing for multi-token decode spans.

    ``x``: (B, T, d).  Each token's cut tensor is packed as its OWN payload
    — exactly the granularity :func:`boundary_wire_eval` gives a T=1
    decode tick (the codec sees a (1, d) tensor either way, so scales and
    TopK counts are identical).  This is what keeps a speculative
    verification span's numerics bit-identical to plain per-token greedy
    decode, and it is the byte stream a draft/target pair sharing this
    stage cut would actually exchange.
    """
    if not compress or policy.fw.kind == "none":
        return x
    from repro.transport.codecs import codec_for
    codec = codec_for(policy.fw)
    k_frac = policy.fw.k_frac

    def one(xt):                                          # (d,)
        payload = codec.pack(xt[None], k_frac)
        return codec.unpack(payload, (1,) + xt.shape, xt.dtype)[0]

    return jax.vmap(jax.vmap(one))(x)


def boundary_wire_bytes_per_token(policy, d_model: int,
                                  num_cuts: Optional[int] = None) -> float:
    """Bytes per decoded token crossing the stage cuts of a
    :class:`~repro.core.policy.CompressionPolicy` (serve metrics).

    ``num_cuts``: the EFFECTIVE cut count — ``segment_bounds`` caps the
    stage count at the model's group count, so a 4-stage policy on a
    2-group smoke model has 1 cut, not ``policy.num_boundaries``.
    Defaults to ``policy.num_boundaries`` when the caller's stack really
    has that many cuts.
    """
    from repro.transport.codecs import codec_for
    total = 0.0
    cuts = policy.num_boundaries if num_cuts is None else num_cuts
    for i in range(cuts):
        bp = policy.at(i)
        codec = codec_for(bp.fw)
        total += codec.wire_bytes_per_elem(d_model, 2, bp.fw.k_frac) * d_model
    return total


# ---------------------------------------------------------------------------
# State container helpers
# ---------------------------------------------------------------------------

def empty_boundary_state(dtype=jnp.float32):
    """Buffer-free ``{'fw', 'bw'}`` FeedbackState pair — what a boundary
    without feedback threads through :func:`boundary_apply` (size-0
    ``resid``, stable pytree structure across policies)."""
    from repro.core.feedback import init_feedback
    return {"fw": init_feedback("none", (), direction="fw", dtype=dtype),
            "bw": init_feedback("none", (), direction="bw", dtype=dtype)}


def init_boundary_state(policy: BoundaryPolicy, feat_shape, *, batch: int,
                        num_samples: int = 0, dtype=jnp.float32):
    """``{'fw': FeedbackState, 'bw': FeedbackState}`` for one boundary
    (``resid`` is size-0 when the direction has no feedback)."""
    from repro.core.feedback import init_feedback
    fw = init_feedback(policy.feedback, feat_shape, direction="fw",
                       dtype=dtype, num_samples=num_samples, batch=batch)
    bw = init_feedback(policy.bw_feedback, feat_shape, direction="bw",
                       dtype=dtype, num_samples=num_samples, batch=batch)
    return {"fw": fw, "bw": bw}


def init_all_boundary_states(comp_policy, feat_shape, *, batch: int,
                             num_samples: int = 0, dtype=jnp.float32):
    """One state dict per boundary of a CompressionPolicy."""
    return [init_boundary_state(comp_policy.at(i), feat_shape, batch=batch,
                                num_samples=num_samples, dtype=dtype)
            for i in range(comp_policy.num_boundaries)]
