"""Compression boundary: the paper's technique as a composable JAX module.

A boundary sits at a pipeline-stage cut.  In a real MP system the forward
activation and the backward activation-gradient cross the network here; the
paper compresses both.  Following the paper (Sec. 2.1) we integrate the
boundary directly into the model with ``jax.custom_vjp`` — convergence-
equivalent to the distributed system, while ``core/pipeline.py`` provides the
real ``shard_map``/``ppermute`` path for performance work.

Semantics (training):
  forward : y  = F(x)   where F is the fw compressor, optionally wrapped in
                         EF / EF21 / EF-mixed / AQ-SGD feedback;
  backward: gx = G(gy)  where G is the bw compressor, optionally wrapped in
                         EF / EF21 / EF-mixed feedback, or — with
                         ``reuse_indices`` — masking by the forward TopK mask.

State threading: feedback buffers are functional.  The *forward* buffer's
update is returned as a second output.  The *backward* buffer's update is
only known during backprop, so it is returned **as the cotangent of the
``bw_buf`` argument** — take ``grad`` w.r.t. ``bw_buf`` in the train step and
read the updated buffer out of the gradient pytree (see train/steps.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.compressors import apply_mask, topk_mask
from repro.core.feedback import feedback_message
from repro.core.policy import BoundaryPolicy


def _fw_message(policy: BoundaryPolicy, x, fw_buf, ids):
    """Forward message + new fw buffer + the TopK mask (for index reuse)."""
    m, new_fw = feedback_message(policy.feedback, policy.fw, x, fw_buf, ids)
    mask = None
    if policy.reuse_indices:
        # Mask of what the forward direction actually kept.  With plain TopK
        # this is the TopK mask of x itself (paper Table 5).
        src = x if policy.feedback == "none" else m
        mask = topk_mask(src, policy.fw.k_frac)
    return m, new_fw, mask


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def boundary_apply(policy: BoundaryPolicy, x, fw_buf, bw_buf, ids):
    """Training-time boundary.  Returns ``(y, new_fw_buf)``.

    ``fw_buf``/``bw_buf``: feedback buffers (size-0 arrays when unused).
    ``ids``: (B,) int32 example ids (AQ-SGD only; zeros otherwise).
    The updated backward buffer is delivered as the cotangent of ``bw_buf``.
    """
    m, new_fw, _ = _fw_message(policy, x, fw_buf, ids)
    return m, new_fw


def _boundary_fwd(policy: BoundaryPolicy, x, fw_buf, bw_buf, ids):
    m, new_fw, mask = _fw_message(policy, x, fw_buf, ids)
    residuals = (mask, fw_buf, bw_buf, ids)
    return (m, new_fw), residuals


def _boundary_bwd(policy: BoundaryPolicy, residuals, cotangents):
    mask, fw_buf, bw_buf, ids = residuals
    g_y, _g_new_fw = cotangents          # buffer output is aux — no gradient
    if policy.reuse_indices:
        # Paper Table 5: reuse the forward TopK indices on the gradient.
        g_x = apply_mask(g_y, mask)
        new_bw = jnp.zeros_like(bw_buf)
    else:
        g_x, new_bw = feedback_message(policy.bw_feedback, policy.bw, g_y, bw_buf)
    zero_fw = jax.tree.map(jnp.zeros_like, fw_buf)
    zero_ids = np.zeros(ids.shape, dtype=jax.dtypes.float0)
    return (g_x, zero_fw, new_bw, zero_ids)


boundary_apply.defvjp(_boundary_fwd, _boundary_bwd)


def boundary_eval(policy: BoundaryPolicy, x, compress: bool):
    """Inference-time boundary: plain fw compressor or identity.

    The paper evaluates each trained model BOTH ways (Tables 1-4):
    compression kept on at inference vs switched off.
    """
    return policy.fw(x) if compress else x


# ---------------------------------------------------------------------------
# State container helpers
# ---------------------------------------------------------------------------

def init_boundary_state(policy: BoundaryPolicy, feat_shape, *, batch: int,
                        num_samples: int = 0, dtype=jnp.float32):
    """``{'fw': buf, 'bw': buf}`` for one boundary (size-0 when unused)."""
    from repro.core.feedback import init_buffer
    fw = init_buffer(policy.feedback, feat_shape, dtype=dtype,
                     num_samples=num_samples, batch=batch)
    bw = init_buffer(policy.bw_feedback, feat_shape, dtype=dtype,
                     num_samples=num_samples, batch=batch)
    return {"fw": fw, "bw": bw}


def init_all_boundary_states(comp_policy, feat_shape, *, batch: int,
                             num_samples: int = 0, dtype=jnp.float32):
    """One state dict per boundary of a CompressionPolicy."""
    return [init_boundary_state(comp_policy.at(i), feat_shape, batch=batch,
                                num_samples=num_samples, dtype=dtype)
            for i in range(comp_policy.num_boundaries)]
