"""Compression operators for model-parallel boundary communication.

Implements the paper's two operator families (Sec. 2.2, 2.3):

* uniform k-bit min-max quantization  (``quantize_kbit`` / ``dequantize_kbit``)
* TopK sparsification                 (``topk_mask`` / ``topk_compress``)

All operators are pure functions over jnp arrays so they can be used inside
``jax.custom_vjp`` boundaries, ``shard_map`` pipeline sends, and Pallas
kernel reference tests.  Compression is applied along the *flattened* trailing
feature dimensions of a per-example tensor unless stated otherwise, matching
the paper ("input vector" = the activation tensor crossing the boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Uniform k-bit min-max quantization (paper Sec. 2.2)
# ---------------------------------------------------------------------------

def quantize_kbit(x: jnp.ndarray, bits: int, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Uniform k-bit quantization with min-max scaling.

    Maps ``x`` to ``[0, 2**bits - 1]`` integer levels.  ``axis=None`` uses a
    single global (per-tensor) min/max, as in the paper; a tuple of axes
    yields per-slice scales (used by the per-tile Pallas variant).

    Returns ``(codes_uint, x_min, scale)`` where
    ``dequant = codes * scale + x_min``.
    """
    levels = (1 << bits) - 1
    x_min = jnp.min(x, axis=axis, keepdims=axis is not None)
    x_max = jnp.max(x, axis=axis, keepdims=axis is not None)
    span = x_max - x_min
    # Guard degenerate constant tensors.
    scale = jnp.where(span > 0, span / levels, jnp.ones_like(span))
    codes = jnp.clip(jnp.round((x - x_min) / scale), 0, levels)
    codes = codes.astype(jnp.uint8 if bits <= 8 else jnp.uint16)
    return codes, x_min, scale


def dequantize_kbit(codes: jnp.ndarray, x_min: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (codes.astype(dtype) * scale.astype(dtype) + x_min.astype(dtype))


def quantize_dequantize(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """The C(x) used in convergence experiments: quantize then dequantize."""
    codes, x_min, scale = quantize_kbit(x, bits, axis=axis)
    return dequantize_kbit(codes, x_min, scale, dtype=x.dtype)


# ---------------------------------------------------------------------------
# TopK sparsification (paper Sec. 2.3)
# ---------------------------------------------------------------------------

def _flatten_per_example(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """(B, ...) -> (B, N).  The paper compresses the per-example activation
    vector crossing the boundary."""
    b = x.shape[0]
    return x.reshape(b, -1), x.shape


def topk_mask(x: jnp.ndarray, k_frac: float, per_example: bool = True) -> jnp.ndarray:
    """Boolean mask selecting the largest-|.| ``k_frac`` of entries.

    ``per_example=True`` selects top-K within each batch element (paper's
    setting: the communicated message is a per-example activation vector).
    """
    if not per_example:
        flat = x.reshape(1, -1)
    else:
        flat, _ = _flatten_per_example(x)
    n = flat.shape[-1]
    k = max(1, int(round(k_frac * n)))
    mag = jnp.abs(flat)
    # threshold = k-th largest magnitude per row
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    mask = mag >= thresh
    return mask.reshape(x.shape)


def topk_compress(x: jnp.ndarray, k_frac: float, per_example: bool = True) -> jnp.ndarray:
    """C(x) for TopK: zero all but the largest-|.| K% entries."""
    return jnp.where(topk_mask(x, k_frac, per_example), x, jnp.zeros_like(x))


def topk_values_indices(x: jnp.ndarray, k_frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Wire format of TopK: (values, int32 indices), per example.

    Used by the real pipeline path (core/pipeline.py) to compute actual
    bytes-on-wire: 4 (fp32 value) + 4 (index) per kept entry, or 2+4 for bf16.
    """
    flat, _ = _flatten_per_example(x)
    n = flat.shape[-1]
    k = max(1, int(round(k_frac * n)))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = jnp.take_along_axis(flat, idx, axis=-1)
    return vals, idx


def topk_scatter(vals: jnp.ndarray, idx: jnp.ndarray, shape: Tuple[int, ...],
                 dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of topk_values_indices: scatter back into a dense zero tensor."""
    b = vals.shape[0]
    n = 1
    for s in shape[1:]:
        n *= s
    flat = jnp.zeros((b, n), dtype=dtype)
    flat = jax.vmap(lambda f, i, v: f.at[i].set(v))(flat, idx, vals.astype(dtype))
    return flat.reshape(shape)


def apply_mask(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(mask, x, jnp.zeros_like(x))


# ---------------------------------------------------------------------------
# Operator objects (used by CompressionPolicy / boundary)
# ---------------------------------------------------------------------------

# Which implementation C(x) runs on: "auto" uses the Pallas kernels on TPU
# (per-tile scales / block-local TopK — the DESIGN.md §4 TPU adaptation)
# and pure jnp elsewhere; "pallas" forces the kernels (interpret mode on
# CPU — used by tests); "jnp" forces the references.
KERNEL_BACKEND = "auto"


def _use_pallas() -> bool:
    if KERNEL_BACKEND == "pallas":
        return True
    if KERNEL_BACKEND == "jnp":
        return False
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A named compression operator C(x) plus its wire-cost model.

    ``kind``: "none" | "quant" | "topk"
    ``bits``: quantization bits (quant)
    ``k_frac``: kept fraction (topk)
    """
    kind: str = "none"
    bits: int = 8
    k_frac: float = 1.0

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.kind == "none":
            return x
        if self.kind == "quant":
            if _use_pallas():
                from repro.kernels.ops import quant_dequant_op
                return quant_dequant_op(x, self.bits)
            return quantize_dequantize(x, self.bits)
        if self.kind == "topk":
            if _use_pallas():
                from repro.kernels.ops import topk_block_op
                return topk_block_op(x, self.k_frac)
            return topk_compress(x, self.k_frac)
        raise ValueError(f"unknown compressor kind: {self.kind}")

    # -- wire-cost model (bytes per element of the uncompressed tensor) -----
    def wire_bytes_per_elem(self, elem_bytes: int = 2,
                            n: Optional[int] = None) -> float:
        """Bytes actually communicated per original element (bf16 baseline=2).

        quant: bits/8 (+ negligible per-tensor scale);
        topk:  k_frac * (elem_bytes + idx_bytes) — value + index, where the
               index is uint16 when the flattened feature dim ``n`` fits in
               16 bits (see transport/codecs.py), int32 otherwise (also the
               conservative default when ``n`` is unknown).
        """
        if self.kind == "none":
            return float(elem_bytes)
        if self.kind == "quant":
            return self.bits / 8.0
        if self.kind == "topk":
            idx_bytes = 2 if (n is not None and n <= (1 << 16)) else 4
            return self.k_frac * (elem_bytes + idx_bytes)
        raise ValueError(self.kind)

    @property
    def name(self) -> str:
        if self.kind == "none":
            return "none"
        if self.kind == "quant":
            return f"q{self.bits}"
        return f"top{int(round(self.k_frac * 100))}%"


IDENTITY = Compressor("none")


def quant(bits: int) -> Compressor:
    return Compressor("quant", bits=bits)


def topk(k_frac: float) -> Compressor:
    return Compressor("topk", k_frac=k_frac)
