"""Unified parallelism spec: one object for every communication axis.

The repo grew its axes one kwarg family at a time — ``dp/dp_codec/
dp_feedback/dp_k_frac`` for data parallelism, ``policy`` + ``stage_axis``
for the pipeline — and a third (tensor) axis the same way would mean a
third copy of the family on already ~18-parameter signatures.
:class:`ParallelSpec` collapses them: a mapping from axis name
(``"data" | "stage" | "tensor"``) to an :class:`AxisSpec` carrying the
axis size and its WIRE configuration (codec, feedback mode, top-k
fraction).  ``make_lm_train_step`` / ``run_lm_experiment`` accept it as a
single ``parallel=`` argument; the legacy kwargs survive behind a
deprecation shim (:func:`from_legacy`) that constructs the equivalent
spec and warns with :class:`ParallelDeprecationWarning`.

An axis codec may be a plain codec name (``"q8"``) or a policy-rule list
(``"q4@bandwidth<1e9;q8"`` — the grammar of ``core.policy.parse_rule``);
rule specs are resolved against the axis' wire size and an optional
measured bandwidth (obs/probes.py) via :meth:`ParallelSpec.resolved`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping, Optional, Tuple, Union

import jax

from repro.core.feedback import FEEDBACK_REGISTRY
from repro.core.policy import (
    BoundaryPolicy,
    CompressionPolicy,
    _rule_compressor,
    parse_policy_rules,
)

AXIS_NAMES = ("data", "stage", "tensor")

# "model" is the historical sharding/specs.py name for the tensor axis
# (kept as an alias so existing meshes keep resolving); "dp"/"pp"/"tp"
# are accepted shorthands in CLI specs.
AXIS_ALIASES = {
    "model": "tensor",
    "dp": "data",
    "pp": "stage",
    "tp": "tensor",
}

# Which FeedbackState scope an axis' feedback buffers live in.
AXIS_SCOPES = {"data": "dp", "stage": "boundary", "tensor": "tp"}


class ParallelDeprecationWarning(DeprecationWarning):
    """Category for the legacy ``dp_*``/axis-kwarg deprecation shim (so CI
    can ``-W error::`` this category without tripping on third-party
    DeprecationWarnings)."""


def canonical_axis(name: str) -> str:
    """Resolve an axis name or alias ("model" -> "tensor") to canonical."""
    name = AXIS_ALIASES.get(name, name)
    if name not in AXIS_NAMES:
        raise ValueError(
            f"unknown parallel axis {name!r}; valid: {AXIS_NAMES} "
            f"(aliases: {tuple(AXIS_ALIASES)})"
        )
    return name


def _is_rule_spec(codec: str) -> bool:
    return ("@" in codec) or (";" in codec) or (":" in codec)


def _feedback_modes_for(axis: str) -> Tuple[str, ...]:
    scope = AXIS_SCOPES[axis]
    return tuple(
        n for n, m in FEEDBACK_REGISTRY.items() if scope in m.scopes
    )


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One mesh axis: its size and the wire that crosses it.

    ``codec`` is a wire-codec name (``none/q8/q4/topk``) or an unresolved
    policy-rule list (anything containing ``@``/``;``/``:``) picked per
    axis by the rule engine — including bandwidth predicates, which only
    fire when a probe measurement is supplied at resolve time.
    """

    size: int = 1
    codec: str = "none"
    feedback: str = "none"
    k_frac: float = 0.1

    def __post_init__(self):
        if not isinstance(self.size, int) or self.size < 1:
            raise ValueError(f"axis size must be a positive int, got {self.size!r}")
        if not 0.0 < self.k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {self.k_frac}")
        if _is_rule_spec(self.codec):
            parse_policy_rules(self.codec)  # raises on a malformed rule list
        else:
            from repro.transport.codecs import registered_codecs

            if self.codec not in registered_codecs():
                raise ValueError(
                    f"unknown wire codec {self.codec!r}; registered: "
                    f"{registered_codecs()} (or a policy-rule spec)"
                )
        if self.feedback not in FEEDBACK_REGISTRY:
            raise ValueError(
                f"unknown feedback mode {self.feedback!r}; "
                f"known: {tuple(FEEDBACK_REGISTRY)}"
            )

    @property
    def is_rules(self) -> bool:
        return _is_rule_spec(self.codec)

    def resolve(self, wire_size: int, bandwidth: Optional[float] = None) -> "AxisSpec":
        """Collapse a rule-spec codec to a concrete one for this axis'
        wire size (per-example element count crossing the axis) and an
        optional measured ``bandwidth`` (bytes/s, from obs/probes.py)."""
        if not self.is_rules:
            return self
        rule = parse_policy_rules(self.codec).pick(
            wire_size, 0, "fw", bandwidth=bandwidth
        )
        return dataclasses.replace(self, codec=rule.codec, k_frac=rule.k_frac)


_AXES_T = Tuple[Tuple[str, AxisSpec], ...]


@dataclasses.dataclass(frozen=True, init=False)
class ParallelSpec:
    """The full parallelism plan: ``{axis name -> AxisSpec}``.

    Canonical axis order is ``(data, stage, tensor)``; missing axes
    default to size 1 with no wire compression.  Hashable (usable as a
    jit static argument) and registered as a pytree of pure metadata.
    """

    axes: _AXES_T

    def __init__(
        self,
        axes: Union[None, Mapping[str, Union[AxisSpec, int]], _AXES_T] = None,
    ):
        entries = dict(axes or {})
        normalized = {}
        for name, spec in entries.items():
            name = canonical_axis(name)
            if name in normalized:
                raise ValueError(f"duplicate axis {name!r} in ParallelSpec")
            if isinstance(spec, int):
                spec = AxisSpec(size=spec)
            if not isinstance(spec, AxisSpec):
                raise TypeError(
                    f"axis {name!r} must be an AxisSpec or int size, got {spec!r}"
                )
            normalized[name] = spec
        full = tuple(
            (n, normalized.get(n, AxisSpec())) for n in AXIS_NAMES
        )
        object.__setattr__(self, "axes", full)
        self._validate()

    def _validate(self):
        for name, spec in self.axes:
            modes = _feedback_modes_for(name)
            if spec.feedback not in modes:
                raise ValueError(
                    f"feedback {spec.feedback!r} is not valid on the "
                    f"{name!r} axis (scope {AXIS_SCOPES[name]!r} supports "
                    f"{modes})"
                )

    # -- accessors ---------------------------------------------------------

    def axis(self, name: str) -> AxisSpec:
        name = canonical_axis(name)
        return dict(self.axes)[name]

    @property
    def data(self) -> AxisSpec:
        return self.axis("data")

    @property
    def stage(self) -> AxisSpec:
        return self.axis("stage")

    @property
    def tensor(self) -> AxisSpec:
        return self.axis("tensor")

    @property
    def dp(self) -> int:
        return self.data.size

    @property
    def stages(self) -> int:
        return self.stage.size

    @property
    def tp(self) -> int:
        return self.tensor.size

    @property
    def num_devices(self) -> int:
        return self.dp * self.stages * self.tp

    @property
    def name(self) -> str:
        parts = []
        for n, s in self.axes:
            if s.size == 1 and s.codec == "none":
                continue
            wire = s.codec
            if s.feedback != "none":
                wire += f"+{s.feedback}"
            if s.codec == "topk" or (s.codec != "none" and s.k_frac != 0.1):
                wire += f":{s.k_frac:g}"
            parts.append(f"{n}={s.size}({wire})" if wire != "none" else f"{n}={s.size}")
        return ",".join(parts) or "solo"

    # -- derived plans -----------------------------------------------------

    def resolved(
        self,
        wire_sizes: Optional[Mapping[str, int]] = None,
        bandwidth: Optional[float] = None,
    ) -> "ParallelSpec":
        """Resolve any rule-spec axis codecs (see :meth:`AxisSpec.resolve`).
        ``wire_sizes`` maps axis name -> per-example element count on that
        axis' wire; axes without an entry resolve with size 0."""
        sizes = dict(wire_sizes or {})
        return ParallelSpec(
            {
                n: s.resolve(sizes.get(n, 0), bandwidth)
                for n, s in self.axes
            }
        )

    def stage_policy(self) -> Optional[CompressionPolicy]:
        """A uniform boundary :class:`CompressionPolicy` from the stage
        axis' wire spec, or None when the stage wire is uncompressed with
        no feedback (callers then keep their explicit ``policy``)."""
        s = self.stage
        if s.codec == "none" and s.feedback == "none":
            return None
        if s.is_rules:
            return parse_policy_rules(s.codec, num_stages=s.size)
        comp = _rule_compressor(s.codec, s.k_frac)
        return CompressionPolicy(
            num_stages=s.size,
            boundary=BoundaryPolicy(
                fw=comp,
                bw=comp,
                feedback=s.feedback,
                bw_feedback=s.feedback if s.feedback != "aqsgd" else "none",
            ),
        )


jax.tree_util.register_dataclass(
    AxisSpec,
    data_fields=(),
    meta_fields=("size", "codec", "feedback", "k_frac"),
)
jax.tree_util.register_dataclass(
    ParallelSpec, data_fields=(), meta_fields=("axes",)
)


# ---------------------------------------------------------------------------
# Compact CLI specs:  --mesh data=2,stage=2,tensor=2
#                     --wire data=q8+ef:0.1,tensor=q4
# ---------------------------------------------------------------------------


def parse_mesh_spec(spec: str) -> dict:
    """``"data=2,stage=2,tensor=2"`` -> ``{"data": 2, "stage": 2, "tensor": 2}``.
    Axis aliases (``model``/``dp``/``pp``/``tp``) are accepted."""
    out = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        name, eq, size_s = item.partition("=")
        if not eq:
            raise ValueError(
                f"bad mesh item {item!r} (want axis=<int>, e.g. data=2)"
            )
        name = canonical_axis(name.strip())
        try:
            size = int(size_s)
        except ValueError:
            raise ValueError(f"bad mesh size {size_s!r} for axis {name!r}")
        if size < 1:
            raise ValueError(f"mesh axis {name!r} size must be >= 1, got {size}")
        if name in out:
            raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
        out[name] = size
    if not out:
        raise ValueError(f"empty mesh spec {spec!r}")
    return out


def parse_wire_item(item: str) -> Tuple[str, str, Optional[float]]:
    """``"q8+ef:0.1"`` -> ``("q8", "ef", 0.1)`` (k_frac None if omitted)."""
    head, colon, k_s = item.partition(":")
    k_frac = None
    if colon:
        try:
            k_frac = float(k_s)
        except ValueError:
            raise ValueError(f"bad k_frac {k_s!r} in wire item {item!r}")
    codec, plus, feedback = head.partition("+")
    codec = codec.strip() or "none"
    feedback = feedback.strip() if plus else "none"
    return codec, feedback, k_frac


def parse_wire_spec(spec: str) -> dict:
    """``"data=q8+ef:0.1,tensor=q4"`` ->
    ``{"data": ("q8", "ef", 0.1), "tensor": ("q4", "none", None)}``."""
    out = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        name, eq, wire = item.partition("=")
        if not eq:
            raise ValueError(
                f"bad wire item {item!r} (want axis=codec[+feedback][:k_frac])"
            )
        name = canonical_axis(name.strip())
        if name in out:
            raise ValueError(f"duplicate wire axis {name!r} in {spec!r}")
        out[name] = parse_wire_item(wire.strip())
    if not out:
        raise ValueError(f"empty wire spec {spec!r}")
    return out


def spec_from_cli(
    mesh: Optional[str] = None, wire: Optional[str] = None
) -> ParallelSpec:
    """Build a :class:`ParallelSpec` from the compact ``--mesh``/``--wire``
    CLI strings (either may be None)."""
    sizes = parse_mesh_spec(mesh) if mesh else {}
    wires = parse_wire_spec(wire) if wire else {}
    axes = {}
    for name in AXIS_NAMES:
        kw = {"size": sizes.get(name, 1)}
        if name in wires:
            codec, feedback, k_frac = wires[name]
            kw["codec"] = codec
            kw["feedback"] = feedback
            if k_frac is not None:
                kw["k_frac"] = k_frac
        axes[name] = AxisSpec(**kw)
    return ParallelSpec(axes)


# ---------------------------------------------------------------------------
# Legacy-kwarg shim
# ---------------------------------------------------------------------------


def from_legacy(
    *,
    dp: int = 1,
    dp_codec: str = "none",
    dp_feedback: str = "none",
    dp_k_frac: float = 0.1,
    num_stages: int = 1,
    tp: int = 1,
    tp_codec: str = "none",
    tp_feedback: str = "none",
    tp_k_frac: float = 0.1,
) -> ParallelSpec:
    """The spec the legacy kwarg family described."""
    return ParallelSpec(
        {
            "data": AxisSpec(
                size=dp, codec=dp_codec, feedback=dp_feedback, k_frac=dp_k_frac
            ),
            "stage": AxisSpec(size=num_stages),
            "tensor": AxisSpec(
                size=tp, codec=tp_codec, feedback=tp_feedback, k_frac=tp_k_frac
            ),
        }
    )


def warn_legacy(api: str, kwargs: Tuple[str, ...]) -> None:
    """Issue the one deprecation warning for a legacy-kwarg call site.

    Under the default warning filters Python de-duplicates per call
    location, so a training loop warns once; ``pytest.warns`` /
    ``-W error::…ParallelDeprecationWarning`` still see every call.
    """
    warnings.warn(
        f"{api}: the {', '.join(kwargs)} kwarg(s) are deprecated — pass "
        f"parallel=ParallelSpec({{...}}) instead (see core/parallel.py and "
        "the README 'Parallelism & wire configuration' section)",
        ParallelDeprecationWarning,
        stacklevel=3,
    )
