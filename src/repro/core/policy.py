"""Compression policy configuration for stage boundaries.

A :class:`BoundaryPolicy` describes what happens at ONE pipeline-stage cut:
which compressor is applied to the forward activations, which to the backward
activation-gradients, and which error-compensation technique (if any) wraps
each direction.  A :class:`CompressionPolicy` is the per-model plan: the list
of stage cut points plus the boundary policy (the paper uses the same policy
at every cut; we allow per-cut overrides).

On top of the static policies sits the adaptive rule engine:
:class:`PolicyRule` maps a predicate over (tensor size, boundary depth,
direction) to a ``(codec, k_frac)`` choice, and :class:`PolicyRules`
resolves an ordered rule list into a plain :class:`CompressionPolicy`
given the per-boundary tensor sizes — entirely in Python at trace time,
so the resolved policy is as jit-hashable as a hand-written one
(cf. Hivemind's ``SizeAdaptiveCompression`` and Agarwal et al. 2103.00543
on per-tensor, bandwidth-aware codec choice).

Frozen dataclasses => hashable => usable as ``jax.custom_vjp`` /
``jax.jit`` static arguments.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple, Union

from repro.core.compressors import Compressor, IDENTITY, quant, topk


FEEDBACK_MODES = ("none", "ef", "ef21", "efmixed", "aqsgd")

# The backward direction excludes aqsgd: the paper applies per-example
# feedback to activations only (Sec. 2.5).
BW_FEEDBACK_MODES = ("none", "ef", "ef21", "efmixed")


@dataclasses.dataclass(frozen=True)
class BoundaryPolicy:
    """Per-boundary compression behaviour.

    fw / bw         : compressors for activations / activation-gradients.
    feedback        : error compensation wrapping the FORWARD direction.
                      "aqsgd" keeps a per-example buffer (paper Sec. 2.5),
                      others keep one global buffer (paper Sec. 2.4).
    bw_feedback     : error compensation wrapping the BACKWARD direction
                      ("aqsgd" is not valid here; the paper applies AQ-SGD
                      to activations only).
    reuse_indices   : reuse the forward TopK mask to compress the backward
                      gradient (paper Table 5 — required for LM fine-tuning).
    compress_eval   : apply ``fw`` during inference.  The paper shows models
                      trained with strong compression need this (Table 2).
    """
    fw: Compressor = IDENTITY
    bw: Compressor = IDENTITY
    feedback: str = "none"
    bw_feedback: str = "none"
    reuse_indices: bool = False
    compress_eval: bool = True

    def __post_init__(self):
        if self.feedback not in FEEDBACK_MODES:
            raise ValueError(f"bad feedback mode {self.feedback!r}; "
                             f"valid modes: {FEEDBACK_MODES}")
        if self.bw_feedback not in BW_FEEDBACK_MODES:
            raise ValueError(
                f"bad bw_feedback mode {self.bw_feedback!r}; valid modes: "
                f"{BW_FEEDBACK_MODES} ('aqsgd' is activations-only — the "
                "paper keeps per-example feedback on the forward direction)")
        if self.reuse_indices and self.fw.kind != "topk":
            raise ValueError("reuse_indices requires a TopK forward compressor")

    @property
    def needs_fw_buffer(self) -> bool:
        return self.feedback in ("ef", "ef21", "efmixed", "aqsgd")

    @property
    def needs_bw_buffer(self) -> bool:
        return self.bw_feedback in ("ef", "ef21", "efmixed")

    @property
    def name(self) -> str:
        parts = [f"fw={self.fw.name}", f"bw={self.bw.name}"]
        if self.feedback != "none":
            parts.append(self.feedback)
        if self.bw_feedback != "none":
            parts.append(f"bw-{self.bw_feedback}")
        if self.reuse_indices:
            parts.append("reuse")
        return ",".join(parts)


NO_COMPRESSION = BoundaryPolicy()


def quant_policy(fw_bits: int, bw_bits: int) -> BoundaryPolicy:
    """Paper's fw[A]-bw[B] quantization mode (Table 1)."""
    return BoundaryPolicy(fw=quant(fw_bits), bw=quant(bw_bits))


def topk_policy(k_frac: float, reuse_indices: bool = False) -> BoundaryPolicy:
    """Paper's TopK mode (Tables 2, 5)."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                          reuse_indices=reuse_indices)


def ef_policy(k_frac: float, mode: str = "ef") -> BoundaryPolicy:
    """Paper's error-feedback modes (Table 3): EF / EF21 / EF-mixed on both
    directions, TopK compressors."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                          feedback=mode, bw_feedback=mode)


def aqsgd_policy(k_frac: float) -> BoundaryPolicy:
    """Paper's AQ-SGD + TopK mode (Table 4): per-example feedback on
    activations, plain TopK on gradients."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac), feedback="aqsgd")


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Model-level plan: where the stage cuts are and what happens at each.

    ``num_stages`` stages => ``num_stages - 1`` boundaries (paper: MP degree
    4 => 3 compression operations).  ``boundary`` is used at every cut unless
    ``overrides`` provides a per-cut policy.
    """
    num_stages: int = 4
    boundary: BoundaryPolicy = NO_COMPRESSION
    overrides: Tuple[Tuple[int, BoundaryPolicy], ...] = ()

    @property
    def num_boundaries(self) -> int:
        return max(0, self.num_stages - 1)

    @property
    def name(self) -> str:
        """Stable identity of the resolved plan — what the closed loop
        compares across epochs to detect a codec flip (train/loop.py)."""
        if not self.overrides:
            return f"{self.num_stages}x({self.boundary.name})"
        cuts = ",".join(f"{i}:({self.at(i).name})"
                        for i in range(self.num_boundaries))
        return f"{self.num_stages}x[{cuts}]"

    def at(self, i: int) -> BoundaryPolicy:
        for j, p in self.overrides:
            if j == i:
                return p
        return self.boundary

    def cut_layers(self, num_layers: int) -> Tuple[int, ...]:
        """Layer indices AFTER which a boundary sits (even partition)."""
        if self.num_stages <= 1:
            return ()
        per = num_layers / self.num_stages
        return tuple(int(round(per * (s + 1))) - 1 for s in range(self.num_stages - 1))


NO_POLICY = CompressionPolicy(num_stages=1)


# ---------------------------------------------------------------------------
# Adaptive per-boundary policy rule engine
# ---------------------------------------------------------------------------

RULE_CODECS = ("none", "q8", "q4", "topk")


def _rule_compressor(codec: str, k_frac: float) -> Compressor:
    if codec == "none":
        return IDENTITY
    if codec == "q8":
        return quant(8)
    if codec == "q4":
        return quant(4)
    return topk(k_frac)


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One rule: a predicate over the boundary tensor -> a codec choice.

    The predicate sees three static facts about each boundary direction:

      size      : per-example element count of the boundary tensor
                  (``prod(feat_shape)`` — what the wire cost scales with);
      depth     : the boundary index (0 = the cut after the first stage);
      direction : "fw" (activations) or "bw" (activation-gradients);
      bandwidth : (optional) MEASURED link bytes/s from a probe
                  (obs/probes.py).  A rule with a bandwidth term only
                  fires when a measurement is supplied — without one
                  (the no-probe config) it is skipped, so static runs
                  resolve exactly as before.

    ``matches`` is pure Python over static shapes and a host-side float,
    so rule resolution happens at trace time and the result stays
    jit-hashable.
    """
    codec: str
    k_frac: float = 0.1
    min_size: int = 0
    max_size: Optional[int] = None
    min_depth: int = 0
    max_depth: Optional[int] = None
    direction: str = "both"
    min_bandwidth: float = 0.0
    max_bandwidth: Optional[float] = None

    def __post_init__(self):
        if self.codec not in RULE_CODECS:
            raise ValueError(f"unknown rule codec {self.codec!r}; "
                             f"known: {RULE_CODECS}")
        if self.direction not in ("fw", "bw", "both"):
            raise ValueError(f"rule direction must be 'fw', 'bw' or "
                             f"'both', got {self.direction!r}")
        if not 0.0 < self.k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {self.k_frac}")

    @property
    def needs_bandwidth(self) -> bool:
        return self.min_bandwidth > 0 or self.max_bandwidth is not None

    def matches(self, size: int, depth: int, direction: str,
                bandwidth: Optional[float] = None) -> bool:
        if self.direction != "both" and direction != self.direction:
            return False
        if size < self.min_size:
            return False
        if self.max_size is not None and size >= self.max_size:
            return False
        if depth < self.min_depth:
            return False
        if self.max_depth is not None and depth >= self.max_depth:
            return False
        if self.needs_bandwidth:
            # no measurement => a bandwidth-conditioned rule never fires
            # (degenerate no-probe configs resolve exactly as before)
            if bandwidth is None:
                return False
            if bandwidth < self.min_bandwidth:
                return False
            if self.max_bandwidth is not None \
                    and bandwidth >= self.max_bandwidth:
                return False
        return True

    @property
    def name(self) -> str:
        conds = []
        if self.direction != "both":
            conds.append(f"dir={self.direction}")
        if self.min_size:
            conds.append(f"size>={self.min_size}")
        if self.max_size is not None:
            conds.append(f"size<{self.max_size}")
        if self.min_depth:
            conds.append(f"depth>={self.min_depth}")
        if self.max_depth is not None:
            conds.append(f"depth<{self.max_depth}")
        if self.min_bandwidth:
            conds.append(f"bandwidth>={self.min_bandwidth:g}")
        if self.max_bandwidth is not None:
            conds.append(f"bandwidth<{self.max_bandwidth:g}")
        codec = (f"{self.codec}:{self.k_frac}" if self.codec == "topk"
                 else self.codec)
        return codec + (("@" + ",".join(conds)) if conds else "")


@dataclasses.dataclass(frozen=True)
class PolicyRules:
    """An ordered rule list + stage count: the unresolved adaptive policy.

    ``resolve(boundary_sizes)`` evaluates the rules per boundary and per
    direction — FIRST match wins, like a routing table — and returns a
    plain :class:`CompressionPolicy` (per-cut overrides collapse to a
    uniform boundary when every cut resolves identically, so a degenerate
    one-rule policy is EQUAL to its hand-written static counterpart and
    reuses its jit caches).  A boundary no rule covers is an error: end
    the list with a catch-all rule (e.g. ``none``).
    """
    rules: Tuple[PolicyRule, ...]
    num_stages: int = 4

    def __post_init__(self):
        if not self.rules:
            raise ValueError("PolicyRules needs at least one rule")

    @property
    def num_boundaries(self) -> int:
        return max(0, self.num_stages - 1)

    def pick(self, size: int, depth: int, direction: str,
             bandwidth: Optional[float] = None) -> PolicyRule:
        for r in self.rules:
            if r.matches(size, depth, direction, bandwidth):
                return r
        raise ValueError(
            f"no policy rule matches boundary {depth} "
            f"(size={size}, direction={direction!r}, "
            f"bandwidth={bandwidth!r}) — rule list: "
            f"[{'; '.join(r.name for r in self.rules)}]. Append a "
            "catch-all rule (e.g. 'none') so every boundary resolves.")

    def resolve(self, boundary_sizes: Union[int, Sequence[int]],
                bandwidth: Optional[float] = None) -> CompressionPolicy:
        """Rules x per-boundary tensor sizes -> a static policy.

        ``boundary_sizes``: per-example element count at each cut (an int
        broadcasts to every cut — the transformer's uniform ``seq *
        d_model``; heterogeneous stacks like the CNN pass one per cut).

        ``bandwidth``: measured link bytes/s (obs/probes.py) evaluated by
        ``bandwidth>=X`` / ``bandwidth<X`` rule terms.  Without a
        measurement (None — the degenerate no-probe config), bandwidth-
        conditioned rules never fire and resolution is IDENTICAL to the
        static engine's, bit for bit.
        """
        if isinstance(boundary_sizes, int):
            sizes = (boundary_sizes,) * self.num_boundaries
        else:
            sizes = tuple(int(s) for s in boundary_sizes)
        if len(sizes) != self.num_boundaries:
            raise ValueError(
                f"got {len(sizes)} boundary sizes for "
                f"{self.num_boundaries} boundaries (num_stages="
                f"{self.num_stages})")
        bps = []
        for i, n in enumerate(sizes):
            fw_rule = self.pick(n, i, "fw", bandwidth)
            bw_rule = self.pick(n, i, "bw", bandwidth)
            bps.append(BoundaryPolicy(
                fw=_rule_compressor(fw_rule.codec, fw_rule.k_frac),
                bw=_rule_compressor(bw_rule.codec, bw_rule.k_frac)))
        if not bps:
            return CompressionPolicy(num_stages=self.num_stages)
        if all(bp == bps[0] for bp in bps):
            return CompressionPolicy(num_stages=self.num_stages,
                                     boundary=bps[0])
        return CompressionPolicy(
            num_stages=self.num_stages, boundary=bps[0],
            overrides=tuple((i, bp) for i, bp in enumerate(bps)))

    @property
    def name(self) -> str:
        return ";".join(r.name for r in self.rules)


_COND_RE = re.compile(
    r"^(size|depth|bandwidth)(>=|<)(\d+(?:\.\d+)?(?:[eE]\+?\d+)?)$"
    r"|^dir=(fw|bw)$")


def parse_rule(spec: str) -> PolicyRule:
    """``codec[:k_frac][@cond,...]`` -> :class:`PolicyRule`.

    Conditions: ``size>=N`` / ``size<N`` (per-example element count),
    ``depth>=N`` / ``depth<N`` (boundary index), ``dir=fw`` / ``dir=bw``,
    ``bandwidth>=X`` / ``bandwidth<X`` (measured link bytes/s, scientific
    notation welcome — fires only when a probe measurement is supplied at
    resolve time).  Examples: ``q8``, ``topk:0.1``,
    ``topk:0.05@size>=65536,dir=fw``, ``none@bandwidth>=50e9``.
    """
    spec = spec.strip()
    head, _, conds = spec.partition("@")
    codec, _, kf = head.partition(":")
    codec = codec.strip()
    kw = {}
    if kf:
        try:
            kw["k_frac"] = float(kf)
        except ValueError:
            raise ValueError(f"bad k_frac {kf!r} in rule {spec!r}") from None
    for cond in filter(None, (c.strip() for c in conds.split(","))):
        m = _COND_RE.match(cond)
        if not m:
            raise ValueError(
                f"bad rule condition {cond!r} in {spec!r} — expected "
                "size>=N, size<N, depth>=N, depth<N, bandwidth>=X, "
                "bandwidth<X, dir=fw or dir=bw")
        if m.group(4):
            kw["direction"] = m.group(4)
        else:
            key, op, raw = m.group(1), m.group(2), m.group(3)
            if key == "bandwidth":
                val = float(raw)
            else:
                try:
                    val = int(raw)
                except ValueError:
                    raise ValueError(
                        f"bad rule condition {cond!r} in {spec!r} — "
                        f"{key} thresholds must be integers") from None
            kw[("min_" if op == ">=" else "max_") + key] = val
    return PolicyRule(codec=codec, **kw)


def parse_policy_rules(spec: str, num_stages: int = 4) -> PolicyRules:
    """A ``;``-separated rule list -> :class:`PolicyRules`.

    E.g. ``"topk:0.1@size>=65536;q8"``: TopK-10% at any cut whose tensor
    has >= 64Ki elements per example, 8-bit quantization everywhere else
    (the Hivemind ``SizeAdaptiveCompression`` shape).
    """
    rules = tuple(parse_rule(r) for r in spec.split(";") if r.strip())
    if not rules:
        raise ValueError(f"empty policy rule spec {spec!r}")
    return PolicyRules(rules=rules, num_stages=num_stages)


def resolve_policy(policy, boundary_sizes,
                   bandwidth: Optional[float] = None) -> CompressionPolicy:
    """Accept either a static :class:`CompressionPolicy` (returned as-is)
    or unresolved :class:`PolicyRules` (resolved against the boundary
    sizes, and — when a probe measurement is supplied — the measured link
    ``bandwidth`` in bytes/s) — the single entry point train/steps.py and
    the launchers thread an adaptive policy through."""
    if isinstance(policy, PolicyRules):
        return policy.resolve(boundary_sizes, bandwidth)
    return policy
