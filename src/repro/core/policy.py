"""Compression policy configuration for stage boundaries.

A :class:`BoundaryPolicy` describes what happens at ONE pipeline-stage cut:
which compressor is applied to the forward activations, which to the backward
activation-gradients, and which error-compensation technique (if any) wraps
each direction.  A :class:`CompressionPolicy` is the per-model plan: the list
of stage cut points plus the boundary policy (the paper uses the same policy
at every cut; we allow per-cut overrides).

Frozen dataclasses => hashable => usable as ``jax.custom_vjp`` /
``jax.jit`` static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.compressors import Compressor, IDENTITY, quant, topk


FEEDBACK_MODES = ("none", "ef", "ef21", "efmixed", "aqsgd")


@dataclasses.dataclass(frozen=True)
class BoundaryPolicy:
    """Per-boundary compression behaviour.

    fw / bw         : compressors for activations / activation-gradients.
    feedback        : error compensation wrapping the FORWARD direction.
                      "aqsgd" keeps a per-example buffer (paper Sec. 2.5),
                      others keep one global buffer (paper Sec. 2.4).
    bw_feedback     : error compensation wrapping the BACKWARD direction
                      ("aqsgd" is not valid here; the paper applies AQ-SGD
                      to activations only).
    reuse_indices   : reuse the forward TopK mask to compress the backward
                      gradient (paper Table 5 — required for LM fine-tuning).
    compress_eval   : apply ``fw`` during inference.  The paper shows models
                      trained with strong compression need this (Table 2).
    """
    fw: Compressor = IDENTITY
    bw: Compressor = IDENTITY
    feedback: str = "none"
    bw_feedback: str = "none"
    reuse_indices: bool = False
    compress_eval: bool = True

    def __post_init__(self):
        if self.feedback not in FEEDBACK_MODES:
            raise ValueError(f"bad feedback mode {self.feedback}")
        if self.bw_feedback not in FEEDBACK_MODES or self.bw_feedback == "aqsgd":
            if self.bw_feedback != "none" and self.bw_feedback not in ("ef", "ef21", "efmixed"):
                raise ValueError(f"bad bw_feedback mode {self.bw_feedback}")
        if self.reuse_indices and self.fw.kind != "topk":
            raise ValueError("reuse_indices requires a TopK forward compressor")

    @property
    def needs_fw_buffer(self) -> bool:
        return self.feedback in ("ef", "ef21", "efmixed", "aqsgd")

    @property
    def needs_bw_buffer(self) -> bool:
        return self.bw_feedback in ("ef", "ef21", "efmixed")

    @property
    def name(self) -> str:
        parts = [f"fw={self.fw.name}", f"bw={self.bw.name}"]
        if self.feedback != "none":
            parts.append(self.feedback)
        if self.bw_feedback != "none":
            parts.append(f"bw-{self.bw_feedback}")
        if self.reuse_indices:
            parts.append("reuse")
        return ",".join(parts)


NO_COMPRESSION = BoundaryPolicy()


def quant_policy(fw_bits: int, bw_bits: int) -> BoundaryPolicy:
    """Paper's fw[A]-bw[B] quantization mode (Table 1)."""
    return BoundaryPolicy(fw=quant(fw_bits), bw=quant(bw_bits))


def topk_policy(k_frac: float, reuse_indices: bool = False) -> BoundaryPolicy:
    """Paper's TopK mode (Tables 2, 5)."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                          reuse_indices=reuse_indices)


def ef_policy(k_frac: float, mode: str = "ef") -> BoundaryPolicy:
    """Paper's error-feedback modes (Table 3): EF / EF21 / EF-mixed on both
    directions, TopK compressors."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac),
                          feedback=mode, bw_feedback=mode)


def aqsgd_policy(k_frac: float) -> BoundaryPolicy:
    """Paper's AQ-SGD + TopK mode (Table 4): per-example feedback on
    activations, plain TopK on gradients."""
    return BoundaryPolicy(fw=topk(k_frac), bw=topk(k_frac), feedback="aqsgd")


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Model-level plan: where the stage cuts are and what happens at each.

    ``num_stages`` stages => ``num_stages - 1`` boundaries (paper: MP degree
    4 => 3 compression operations).  ``boundary`` is used at every cut unless
    ``overrides`` provides a per-cut policy.
    """
    num_stages: int = 4
    boundary: BoundaryPolicy = NO_COMPRESSION
    overrides: Tuple[Tuple[int, BoundaryPolicy], ...] = ()

    @property
    def num_boundaries(self) -> int:
        return max(0, self.num_stages - 1)

    def at(self, i: int) -> BoundaryPolicy:
        for j, p in self.overrides:
            if j == i:
                return p
        return self.boundary

    def cut_layers(self, num_layers: int) -> Tuple[int, ...]:
        """Layer indices AFTER which a boundary sits (even partition)."""
        if self.num_stages <= 1:
            return ()
        per = num_layers / self.num_stages
        return tuple(int(round(per * (s + 1))) - 1 for s in range(self.num_stages - 1))


NO_POLICY = CompressionPolicy(num_stages=1)
