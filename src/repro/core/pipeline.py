"""Compatibility shim — the real pipeline lives in repro.transport.

The wire packing (``pack_payload``/``unpack_payload``/``wire_bytes``) moved
to :mod:`repro.transport.codecs` (a pluggable codec registry shared with the
simulated boundary), and the ``shard_map``/``ppermute`` pipeline moved to
:mod:`repro.transport.pipeline` — now DIFFERENTIABLE: the backward pass
ppermutes a packed gradient payload in the reverse direction, so training
runs through the real compressed wire (see transport/pipeline.py).

This module re-exports the original names for existing callers.
"""
from __future__ import annotations

from repro.transport.codecs import (pack_payload, unpack_payload,  # noqa: F401
                                    wire_bytes)
from repro.transport.pipeline import (pipeline_apply,  # noqa: F401
                                      pipeline_forward)

__all__ = ["pack_payload", "unpack_payload", "wire_bytes",
           "pipeline_apply", "pipeline_forward"]
