"""BEYOND-PAPER: real pipeline parallelism with compressed stage handoffs.

The paper simulates MP on one device.  Here the stage boundary is an actual
``jax.lax.ppermute`` over a mesh axis inside ``shard_map`` — GPipe-style
microbatching, each device holding ``num_layers / stages`` layers.  The
boundary tensor is PACKED before the ppermute:

  * ``none``  — raw bf16                        (2   bytes/elem)
  * ``q8``    — uint8 codes + per-tile scales   (1   byte/elem)
  * ``q4``    — two 4-bit codes packed per int8 (0.5 byte/elem)
  * ``topk``  — (values, int32 indices) pair    (k*(2+4) bytes/elem)

so the collective-permute bytes in the lowered HLO shrink by exactly the
paper's compression ratio — measurable in §Roofline's collective term.

This module implements the FORWARD pipeline (inference / activation
streaming).  The simulated-MP path (core/boundary.py) remains the
convergence-faithful training setup, as in the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.compressors import (dequantize_kbit, quantize_kbit,
                                    topk_scatter, topk_values_indices)


# ---------------------------------------------------------------------------
# Wire packing
# ---------------------------------------------------------------------------

def pack_payload(x: jnp.ndarray, scheme: str, k_frac: float = 0.1):
    """x: (B, S, d) stage output -> wire pytree (static shapes)."""
    b = x.shape[0]
    flat = x.reshape(b, -1)
    if scheme == "none":
        return {"raw": x.astype(jnp.bfloat16)}
    if scheme == "q8":
        codes, mn, sc = quantize_kbit(flat.astype(jnp.float32), 8, axis=(1,))
        return {"codes": codes, "min": mn, "scale": sc}
    if scheme == "q4":
        codes, mn, sc = quantize_kbit(flat.astype(jnp.float32), 4, axis=(1,))
        even = codes[:, 0::2]
        odd = codes[:, 1::2]
        packed = (even | (odd << 4)).astype(jnp.uint8)
        return {"codes4": packed, "min": mn, "scale": sc}
    if scheme == "topk":
        vals, idx = topk_values_indices(flat, k_frac)
        return {"vals": vals.astype(jnp.bfloat16), "idx": idx}
    raise ValueError(scheme)


def unpack_payload(payload, shape, dtype=jnp.bfloat16):
    b = shape[0]
    n = 1
    for s in shape[1:]:
        n *= s
    if "raw" in payload:
        return payload["raw"].astype(dtype)
    if "codes" in payload:
        flat = dequantize_kbit(payload["codes"], payload["min"],
                               payload["scale"])
        return flat.reshape(shape).astype(dtype)
    if "codes4" in payload:
        packed = payload["codes4"]
        even = packed & 0xF
        odd = packed >> 4
        codes = jnp.stack([even, odd], axis=-1).reshape(b, n)
        flat = dequantize_kbit(codes, payload["min"], payload["scale"])
        return flat.reshape(shape).astype(dtype)
    if "vals" in payload:
        return topk_scatter(payload["vals"].astype(jnp.float32),
                            payload["idx"], shape, jnp.float32
                            ).astype(dtype)
    raise ValueError(list(payload))


def wire_bytes(payload) -> int:
    return sum(p.size * p.dtype.itemsize for p in jax.tree.leaves(payload))


# ---------------------------------------------------------------------------
# Pipelined forward over a mesh axis
# ---------------------------------------------------------------------------

def pipeline_forward(stage_fn: Callable, params_stacked, x, mesh: Mesh,
                     axis: str, *, scheme: str = "none", k_frac: float = 0.1,
                     microbatches: Optional[int] = None):
    """Run ``stage_fn(stage_params, x) -> x`` as an S-stage GPipe pipeline
    over mesh axis ``axis``, ppermute-ing PACKED payloads between stages.

    params_stacked: pytree with leading dim S (one slice per stage), sharded
    so stage s lives on axis index s.  x: (B, ...) global batch; microbatch
    count defaults to S (minimum-bubble GPipe).
    """
    s_stages = mesh.shape[axis]
    mb = microbatches or s_stages
    b = x.shape[0]
    assert b % mb == 0, (b, mb)

    x_mb = x.reshape(mb, b // mb, *x.shape[1:])
    feat_shape = x_mb.shape[1:]

    def body(params_local, x_local):
        # params_local: this stage's slice (leading dim 1); x_local: (mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        n_steps = mb + s_stages - 1
        buf = jnp.zeros(feat_shape, x_local.dtype)
        outs = jnp.zeros_like(x_local)

        def step(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others consume the ppermute buf
            inject = jnp.clip(t, 0, mb - 1)
            x_in = jnp.where(idx == 0, x_local[inject], buf)
            y = stage_fn(params_local, x_in)
            payload = pack_payload(y, scheme, k_frac)
            moved = jax.lax.ppermute(
                payload, axis,
                [(i, (i + 1) % s_stages) for i in range(s_stages)])
            buf = unpack_payload(moved, feat_shape, x_local.dtype)
            # the LAST stage's y at step t is microbatch t - (S-1)
            emit = jnp.clip(t - (s_stages - 1), 0, mb - 1)
            outs = jnp.where(t >= s_stages - 1, outs.at[emit].set(y), outs)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(n_steps))
        # only the LAST stage holds the pipeline output; psum delivers it
        # replicated (cheap vs reconstructing a stage-stacked tensor, and
        # in a real training step the loss lives on the last stage anyway)
        outs = jnp.where(idx == s_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_vma=False)(params_stacked, x_mb)
    return out.reshape(b, *x.shape[1:])
