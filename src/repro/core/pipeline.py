"""DEPRECATED compatibility shim — the real pipeline lives in repro.transport.

The wire packing (``pack_payload``/``unpack_payload``/``wire_bytes``) moved
to :mod:`repro.transport.codecs` (a pluggable codec registry shared with the
simulated boundary), and the ``shard_map``/``ppermute`` pipeline moved to
:mod:`repro.transport.pipeline` — now DIFFERENTIABLE (the backward pass
ppermutes a packed gradient payload in the reverse direction) and
SCHEDULED (:mod:`repro.transport.schedules`: gpipe / 1f1b / interleaved).

This module re-exports the original names for existing callers and emits a
DeprecationWarning on import; switch to ``repro.transport``.
"""
from __future__ import annotations

import warnings

from repro.transport.codecs import (pack_payload, unpack_payload,  # noqa: F401
                                    wire_bytes)
from repro.transport.pipeline import (pipeline_apply,  # noqa: F401
                                      pipeline_forward)

warnings.warn(
    "repro.core.pipeline is a deprecated shim: import pack_payload/"
    "unpack_payload/wire_bytes and pipeline_apply/pipeline_forward from "
    "repro.transport instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["pack_payload", "unpack_payload", "wire_bytes",
           "pipeline_apply", "pipeline_forward"]
