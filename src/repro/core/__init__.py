"""Core: the paper's contribution — boundary compression for MP training."""
from repro.core.compressors import (Compressor, IDENTITY, quant, topk,
                                    quantize_kbit, dequantize_kbit,
                                    quantize_dequantize, topk_compress,
                                    topk_mask, topk_values_indices,
                                    topk_scatter)
from repro.core.policy import (BoundaryPolicy, CompressionPolicy,
                               NO_COMPRESSION, NO_POLICY, quant_policy,
                               topk_policy, ef_policy, aqsgd_policy,
                               PolicyRule, PolicyRules, parse_policy_rules,
                               resolve_policy)
from repro.core.feedback import (FeedbackState, FEEDBACK_REGISTRY,
                                 DELTA_CODED_MODES, feedback_message,
                                 init_feedback, needs_recv_mirror)
from repro.core.boundary import (boundary_apply, boundary_eval,
                                 init_boundary_state,
                                 init_all_boundary_states)

__all__ = [
    "Compressor", "IDENTITY", "quant", "topk", "quantize_kbit",
    "dequantize_kbit", "quantize_dequantize", "topk_compress", "topk_mask",
    "topk_values_indices", "topk_scatter",
    "BoundaryPolicy", "CompressionPolicy", "NO_COMPRESSION", "NO_POLICY",
    "quant_policy", "topk_policy", "ef_policy", "aqsgd_policy",
    "PolicyRule", "PolicyRules", "parse_policy_rules", "resolve_policy",
    "FeedbackState", "FEEDBACK_REGISTRY", "DELTA_CODED_MODES",
    "feedback_message", "init_feedback", "needs_recv_mirror",
    "boundary_apply", "boundary_eval", "init_boundary_state",
    "init_all_boundary_states",
]
