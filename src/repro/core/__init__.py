"""Core: the paper's contribution — boundary compression for MP training."""
from repro.core.compressors import (Compressor, IDENTITY, quant, topk,
                                    quantize_kbit, dequantize_kbit,
                                    quantize_dequantize, topk_compress,
                                    topk_mask, topk_values_indices,
                                    topk_scatter)
from repro.core.policy import (BoundaryPolicy, CompressionPolicy,
                               NO_COMPRESSION, NO_POLICY, quant_policy,
                               topk_policy, ef_policy, aqsgd_policy)
from repro.core.boundary import (boundary_apply, boundary_eval,
                                 init_boundary_state,
                                 init_all_boundary_states)

__all__ = [
    "Compressor", "IDENTITY", "quant", "topk", "quantize_kbit",
    "dequantize_kbit", "quantize_dequantize", "topk_compress", "topk_mask",
    "topk_values_indices", "topk_scatter",
    "BoundaryPolicy", "CompressionPolicy", "NO_COMPRESSION", "NO_POLICY",
    "quant_policy", "topk_policy", "ef_policy", "aqsgd_policy",
    "boundary_apply", "boundary_eval", "init_boundary_state",
    "init_all_boundary_states",
]
