"""Deterministic synthetic data pipelines (the container is offline).

Two tasks mirroring the paper's two experiments:

* ``ImageClassData`` — CIFAR-10-like: 10 class templates (smooth random
  fields) + per-sample noise + random shifts.  Learnable to >90% by a small
  CNN, so the paper's accuracy-vs-compression ladders are measurable.
* ``LMData`` — token streams from a seeded order-2 Markov chain over a small
  vocabulary with local copy structure: gives a tiny transformer a
  non-trivial, fast-to-learn next-token task (per-example ids for AQ-SGD).

Both are epoch-iterable with stable example ids, sharded by slicing the
leading batch axis (data parallel).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np
import jax


# ---------------------------------------------------------------------------
# Image classification (paper Sec. 3.1 analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ImageClassData:
    num_train: int = 2000
    num_test: int = 500
    image: int = 32
    num_classes: int = 10
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # smooth class templates: low-freq random fields
        freqs = rng.randn(self.num_classes, 4, 4, 3)
        t = np.linspace(0, 1, self.image)
        basis = np.stack([np.sin(np.pi * (i + 1) * t) for i in range(4)])  # (4,I)
        self.templates = np.einsum("kabc,ai,bj->kijc", freqs, basis, basis)
        self.templates /= np.abs(self.templates).max(axis=(1, 2, 3),
                                                     keepdims=True)

        def make(n, seed):
            r = np.random.RandomState(seed)
            y = r.randint(0, self.num_classes, n)
            x = self.templates[y].copy()
            # random roll (translation invariance pressure)
            for i in range(n):
                x[i] = np.roll(x[i], r.randint(-4, 5, 2), axis=(0, 1))
            x += self.noise * r.randn(*x.shape)
            return x.astype(np.float32), y.astype(np.int32)

        self.x_train, self.y_train = make(self.num_train, self.seed + 1)
        self.x_test, self.y_test = make(self.num_test, self.seed + 2)

    def epoch(self, batch: int, epoch_idx: int
              ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Yields (images, labels, example_ids); drop_last."""
        rng = np.random.RandomState(self.seed + 100 + epoch_idx)
        order = rng.permutation(self.num_train)
        for i in range(0, self.num_train - batch + 1, batch):
            idx = order[i:i + batch]
            yield self.x_train[idx], self.y_train[idx], idx.astype(np.int32)

    def test_batches(self, batch: int):
        for i in range(0, self.num_test - batch + 1, batch):
            yield (self.x_test[i:i + batch], self.y_test[i:i + batch],
                   np.arange(i, i + batch, dtype=np.int32))


# ---------------------------------------------------------------------------
# Language modelling (paper Sec. 3.2 analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LMData:
    num_train: int = 512
    num_test: int = 128
    seq_len: int = 64
    vocab: int = 256
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # sparse order-2 Markov transition structure
        self.succ = rng.randint(0, self.vocab, size=(self.vocab, self.vocab, 4))

        def sample(n, seed):
            r = np.random.RandomState(seed)
            out = np.zeros((n, self.seq_len), np.int32)
            out[:, 0] = r.randint(0, self.vocab, n)
            out[:, 1] = r.randint(0, self.vocab, n)
            for t in range(2, self.seq_len):
                choice = r.randint(0, 4, n)
                out[:, t] = self.succ[out[:, t - 2], out[:, t - 1], choice]
            return out

        self.train = sample(self.num_train, self.seed + 1)
        self.test = sample(self.num_test, self.seed + 2)

    def epoch(self, batch: int, epoch_idx: int):
        rng = np.random.RandomState(self.seed + 100 + epoch_idx)
        order = rng.permutation(self.num_train)
        for i in range(0, self.num_train - batch + 1, batch):
            idx = order[i:i + batch]
            yield self.train[idx], idx.astype(np.int32)

    def test_batches(self, batch: int):
        for i in range(0, self.num_test - batch + 1, batch):
            yield self.test[i:i + batch], np.arange(i, i + batch,
                                                    dtype=np.int32)


def synthetic_lm_batch(key, batch: int, seq: int, vocab: int):
    """Pure-jax synthetic batch for throughput benches / examples."""
    tokens = jax.random.randint(key, (batch, seq), 0, vocab)
    return {"tokens": tokens}
