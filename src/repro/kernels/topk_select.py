"""Pallas TPU kernel: exact per-row TopK threshold, sort-free.

The TopK wire format (transport/codecs.py) sends the largest-|x| k entries
per example as (values, indices).  The jnp path pays for a full
``lax.top_k`` — an O(n log n) sort per row, hostile to the VPU and the
single expensive op on the TopK hot path.  The kernel here replaces the
sort with an EXACT threshold search: |x| is bitcast to int32 (the IEEE
ordering trick — non-negative floats compare identically to their bit
patterns), and 31 fixed bisection steps over the bit space find the
k-th-largest magnitude's exact bit pattern with nothing but vector
compares and per-row sum reductions, the whole row resident in VMEM.
Unlike the approximate magnitude bisection in kernels/topk_mask.py, the
bit-space search terminates at the EXACT k-th value, so the selected set
matches ``lax.top_k`` entry-for-entry.

The select/gather epilogue (tie resolution + index compaction) is a thin
cumsum + one scatter in XLA — O(n) streaming work Mosaic cannot express
(per-lane scatter), and exactly what XLA is good at.  Same on unpack: the
dense scatter stays on ``topk_scatter``.  The selected (values, indices)
SET equals the jnp path's; only the order differs — ascending index here
vs descending value from ``lax.top_k`` — with ties broken toward lower
indices in both, so the scattered dense tensor is bit-identical
(tests/test_codec_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import full_row_block


def _threshold_kernel(x_ref, t_ref, *, k: int):
    mag = jnp.abs(x_ref[...].astype(jnp.float32))       # (bm, n)
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    t = jnp.zeros((bits.shape[0], 1), jnp.int32)
    for b in range(30, -1, -1):                         # static unroll
        cand = t | (1 << b)
        cnt = jnp.sum((bits >= cand).astype(jnp.int32), axis=1,
                      keepdims=True)
        t = jnp.where(cnt >= k, cand, t)
    t_ref[...] = jax.lax.bitcast_convert_type(t, jnp.float32)


def topk_threshold(flat: jnp.ndarray, k: int, *,
                   interpret: bool | None = None) -> jnp.ndarray:
    """flat: (M, N).  Returns the EXACT k-th largest |x| per row, (M, 1)
    float32 — count(|x| >= thresh) >= k and count(|x| > thresh) < k."""
    assert flat.ndim == 2, flat.shape
    m, n = flat.shape
    assert 1 <= k <= n, (k, n)
    bm = full_row_block(m, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_threshold_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(flat)


def topk_select_wire(flat: jnp.ndarray, k: int, *,
                     interpret: bool | None = None):
    """(M, N) -> (values (M, k) flat.dtype, indices (M, k) int32).

    Pallas threshold + cumsum/scatter compaction.  Keeps exactly the
    ``lax.top_k`` set per row (entries above the exact k-th magnitude,
    plus threshold ties broken toward LOWER index — top_k's stable tie
    rule); indices come out ascending instead of value-sorted."""
    m, n = flat.shape
    thresh = topk_threshold(flat, k, interpret=interpret)
    mag = jnp.abs(flat.astype(jnp.float32))
    gt = mag > thresh
    eq = mag == thresh
    c_gt = jnp.sum(gt.astype(jnp.int32), axis=1, keepdims=True)
    tie_rank = jnp.cumsum(eq.astype(jnp.int32), axis=1)
    keep = gt | (eq & (tie_rank <= k - c_gt))           # exactly k per row
    slot = jnp.where(keep, jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1,
                     k)                                 # k == dropped
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    idx = jnp.zeros((m, k), jnp.int32).at[rows, slot].set(
        cols, mode="drop", unique_indices=True)
    vals = jnp.take_along_axis(flat, idx, axis=1)
    return vals, idx
